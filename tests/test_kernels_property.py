"""Hypothesis property tests on kernel invariants.

Shapes are drawn adversarially (non-multiples of tile granules, tiny and
skewed dims) — padding/masking correctness is exactly where tiled kernels
break."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref

settings.register_profile("kernels", max_examples=20, deadline=None)
settings.load_profile("kernels")

KEY = jax.random.PRNGKey(7)


@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       mode=st.sampled_from(["abstract", "native"]))
def test_gemm_any_shape(m, k, n, mode):
    ka, kb = jax.random.split(KEY)
    a = jax.random.normal(ka, (m, k), jnp.float32)
    b = jax.random.normal(kb, (k, n), jnp.float32)
    got = ops.matmul(a, b, mode=mode)
    np.testing.assert_allclose(got, ref.gemm(a, b), rtol=1e-4, atol=1e-4)


@given(n=st.integers(1, 1 << 18),
       mode=st.sampled_from(["abstract", "abstract+shuffle", "native"]))
def test_reduction_any_length(n, mode):
    x = jax.random.normal(KEY, (n,), jnp.float32)
    got = ops.reduce_sum(x, mode=mode)
    np.testing.assert_allclose(got, ref.reduce_sum(x), rtol=1e-4, atol=1e-2)


@given(n=st.integers(1, 1 << 16), bins=st.sampled_from([128, 256]),
       mode=st.sampled_from(["abstract", "native"]))
def test_histogram_total_and_values(n, bins, mode):
    v = jax.random.randint(KEY, (n,), -3, bins + 3, jnp.int32)
    got = np.asarray(ops.histogram(v, bins, mode=mode))
    want = np.asarray(ref.histogram(v, bins))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == n                      # conservation


@given(sq=st.integers(1, 300), skv_extra=st.integers(0, 200),
       h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       mode=st.sampled_from(["abstract", "native"]))
def test_attention_any_seq(sq, skv_extra, h, g, mode):
    """Causal flash attention == dense oracle for ragged seq lengths and
    GQA group sizes, including prefix (cache) offsets."""
    skv = sq + skv_extra
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (1, h * g, sq, 32), jnp.float32)
    k = jax.random.normal(kk, (1, h, skv, 32), jnp.float32)
    v = jax.random.normal(kv, (1, h, skv, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, mode=mode,
                              block_q=128, block_kv=128)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@given(rows=st.integers(1, 100), d=st.sampled_from([128, 256, 384]))
def test_rmsnorm_rows(rows, d):
    kx, kw = jax.random.split(KEY)
    x = jax.random.normal(kx, (rows, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    for mode in ("abstract", "native"):
        got = ops.rmsnorm(x, w, mode=mode)
        np.testing.assert_allclose(got, ref.rmsnorm(x, w), rtol=1e-5,
                                   atol=1e-5)
    # scale invariance: rmsnorm(c·x) == rmsnorm(x)
    got2 = ops.rmsnorm(3.7 * x, w, mode="native")
    np.testing.assert_allclose(got2, ref.rmsnorm(x, w), rtol=1e-4,
                               atol=1e-4)
