"""Model-layer correctness: SSD vs naive recurrence, chunked attention vs
dense oracle, MoE routing invariants, decode == prefill continuation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.kernels import ref
from repro.models import build_model, common, mlp, ssd
from repro.models.attention import chunked_attention, decode_attention, update_cache
from repro.models.config import (HybridConfig, ModelConfig, MoEConfig,
                                 ParallelConfig, SSMConfig)

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention used by models
# ---------------------------------------------------------------------------


class TestChunkedAttention:
    @pytest.mark.parametrize("sq,skv,h,hkv", [
        (64, 64, 4, 4), (100, 100, 4, 2), (128, 256, 8, 2), (7, 7, 2, 1)])
    @pytest.mark.parametrize("exact", [False, True])
    def test_vs_dense_oracle(self, sq, skv, h, hkv, exact):
        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (2, h, sq, 32), jnp.float32)
        k = jax.random.normal(kk, (2, hkv, skv, 32), jnp.float32)
        v = jax.random.normal(kv, (2, hkv, skv, 32), jnp.float32)
        got = chunked_attention(q, k, v, causal=True, kv_offset=skv - sq,
                                chunk_q=32, chunk_kv=64, exact_causal=exact)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_non_causal(self):
        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (1, 4, 50, 32), jnp.float32)
        k = jax.random.normal(kk, (1, 4, 80, 32), jnp.float32)
        v = jax.random.normal(kv, (1, 4, 80, 32), jnp.float32)
        got = chunked_attention(q, k, v, causal=False, chunk_q=16,
                                chunk_kv=32)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_decode_matches_last_row_of_prefill(self):
        kq, kk, kv = jax.random.split(KEY, 3)
        s = 33
        q = jax.random.normal(kq, (2, 4, s, 16), jnp.float32)
        k = jax.random.normal(kk, (2, 2, s, 16), jnp.float32)
        v = jax.random.normal(kv, (2, 2, s, 16), jnp.float32)
        full = ref.attention(q, k, v, causal=True)
        # decode the last position against a padded cache
        cache_len = 64
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, cache_len - s), (0, 0)))
        pos = jnp.full((2,), s - 1, jnp.int32)
        got = decode_attention(q[:, :, -1:, :] * (16 ** -0.5) / (16 ** -0.5),
                               kc, vc, pos)
        np.testing.assert_allclose(got[:, :, 0], full[:, :, -1],
                                   rtol=2e-3, atol=2e-3)

    def test_update_cache_writes_one_slot(self):
        cache = jnp.zeros((2, 2, 8, 4))
        new = jnp.ones((2, 2, 1, 4))
        pos = jnp.array([3, 5], jnp.int32)
        out = update_cache(cache, new, pos)
        assert float(out[0, :, 3].sum()) == 8.0
        assert float(out[1, :, 5].sum()) == 8.0
        assert float(out.sum()) == 16.0


# ---------------------------------------------------------------------------
# SSD (mamba2): chunked scan vs naive recurrence
# ---------------------------------------------------------------------------


def naive_ssd(x, dt, A, B_mat, C_mat):
    """Direct recurrence oracle: h <- exp(dt·A)·h + dt·(B ⊗ x); y = C·h."""
    b, l, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    hg = h // g
    xf = x.astype(jnp.float32).reshape(b, l, g, hg, p)
    dtf = dt.astype(jnp.float32).reshape(b, l, g, hg)
    state = jnp.zeros((b, g, hg, n, p), jnp.float32)
    ys = []
    for t in range(l):
        da = jnp.exp(dtf[:, t] * A.reshape(g, hg))
        upd = jnp.einsum("bgn,bgh,bghp->bghnp", B_mat[:, t].astype(jnp.float32),
                         dtf[:, t], xf[:, t])
        state = da[..., None, None] * state + upd
        ys.append(jnp.einsum("bgn,bghnp->bghp",
                             C_mat[:, t].astype(jnp.float32), state))
    y = jnp.stack(ys, axis=1).reshape(b, l, h, p)
    return y, state


class TestSSD:
    @pytest.mark.parametrize("l,chunk", [(16, 4), (32, 8), (17, 8), (8, 16)])
    def test_chunked_scan_matches_recurrence(self, l, chunk):
        b, h, p, g, n = 2, 4, 8, 2, 6
        ks = jax.random.split(KEY, 4)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B_mat = jax.random.normal(ks[3], (b, l, g, n), jnp.float32) * 0.5
        C_mat = jax.random.normal(ks[0], (b, l, g, n), jnp.float32) * 0.5
        y, state = ssd.ssd_scan(x, dt, A, B_mat, C_mat, chunk)
        y_ref, state_ref = naive_ssd(x, dt, A, B_mat, C_mat)
        np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(state, state_ref, rtol=1e-4, atol=1e-4)

    def test_decode_continues_scan_state(self):
        """prefill state + decode steps == longer scan."""
        b, l, h, p, g, n = 1, 12, 2, 4, 1, 4
        extra = 3
        ks = jax.random.split(KEY, 5)
        lt = l + extra
        x = jax.random.normal(ks[0], (b, lt, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, lt, h))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B_mat = jax.random.normal(ks[3], (b, lt, g, n)) * 0.5
        C_mat = jax.random.normal(ks[4], (b, lt, g, n)) * 0.5
        y_full, state_full = ssd.ssd_scan(x, dt, A, B_mat, C_mat, 4)
        _, state = ssd.ssd_scan(x[:, :l], dt[:, :l], A, B_mat[:, :l],
                                C_mat[:, :l], 4)
        ys = []
        for t in range(l, lt):
            state, y_t = ssd.ssd_decode_step(
                state, x[:, t], dt[:, t], A, B_mat[:, t], C_mat[:, t])
            ys.append(y_t)
        np.testing.assert_allclose(state, state_full, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(jnp.stack(ys, 1), y_full[:, l:],
                                   rtol=1e-4, atol=1e-4)

    def test_initial_state_threading(self):
        """scan(x1) then scan(x2, init=state1) == scan(x1 ++ x2)."""
        b, l, h, p, g, n = 1, 16, 2, 4, 1, 4
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B_mat = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
        C_mat = jax.random.normal(ks[4], (b, l, g, n)) * 0.5
        y_full, s_full = ssd.ssd_scan(x, dt, A, B_mat, C_mat, 4)
        half = l // 2
        y1, s1 = ssd.ssd_scan(x[:, :half], dt[:, :half], A, B_mat[:, :half],
                              C_mat[:, :half], 4)
        y2, s2 = ssd.ssd_scan(x[:, half:], dt[:, half:], A, B_mat[:, half:],
                              C_mat[:, half:], 4, initial_state=s1)
        np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(s2, s_full, rtol=1e-4, atol=1e-4)

    def test_conv_activation_prefill_matches_decode_path(self):
        """ISSUE 9 precision-drift regression: prefill used to cast the
        conv output to the storage dtype BEFORE the silu while decode
        applied silu in f32 then cast — under bf16 storage the same token
        got numerically different activations per path.  Both paths must
        now silu in f32 with one cast, so the prefill activation of the
        last token equals the decode-path activation of that token far
        inside bf16 rounding (the pre-fix drift was ~bf16 eps)."""
        width, c, l = 4, 8, 10
        ks = jax.random.split(KEY, 3)
        xw = jax.random.normal(ks[0], (1, l, c)).astype(jnp.bfloat16)
        w = (jax.random.normal(ks[1], (width, c)) * 0.5
             ).astype(jnp.bfloat16)
        bias = (jax.random.normal(ks[2], (c,)) * 0.1).astype(jnp.bfloat16)
        prefill = jax.nn.silu(
            ssd._causal_conv(xw, w, bias)).astype(xw.dtype)
        # the decode path for the final token: tap window einsum in f32
        window = xw[:, l - width:, :]
        conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          w.astype(jnp.float32)) \
            + bias.astype(jnp.float32)
        decode = jax.nn.silu(conv).astype(xw.dtype)
        np.testing.assert_allclose(
            np.asarray(prefill[:, -1], np.float32),
            np.asarray(decode, np.float32), rtol=1e-5, atol=1e-6)

    def test_library_chunk_resolver_threads_tuning_op(self, monkeypatch):
        """ISSUE 9 regression: the library row used to drop ``op=`` when
        resolving its chunk, so with a second ssd op space in the table a
        library fallback would read the wrong slice.  The ``tuning_op``
        argname must reach :func:`resolve_chunk` verbatim."""
        from repro.kernels import ssd as kernel_ssd
        seen = {}
        real = kernel_ssd.resolve_chunk

        def spy(mode, seq, p, n, chunk=None, plan_dialect=None,
                op="ssd_scan"):
            seen["op"] = op
            return real(mode, seq, p, n, chunk, plan_dialect, op=op)

        monkeypatch.setattr(kernel_ssd, "resolve_chunk", spy)
        b, l, h, p, g, n = 1, 8, 2, 4, 1, 4
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))) * 0.5
        A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        B_mat = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
        C_mat = jax.random.normal(ks[4], (b, l, g, n)) * 0.5
        kernel_ssd._ssd_scan_library(x, dt, A, B_mat, C_mat,
                                     tuning_op="ssd_scan_probe")
        assert seen["op"] == "ssd_scan_probe"


# ---------------------------------------------------------------------------
# MoE routing invariants
# ---------------------------------------------------------------------------


class TestMoE:
    def _route(self, g=2, s=64, e=8, k=2, cf=1.25):
        moe = MoEConfig(num_experts=e, top_k=k, capacity_factor=cf,
                        group_size=s)
        logits = jax.random.normal(KEY, (g, s, e), jnp.float32)
        return mlp.route(logits, moe), moe

    def test_dispatch_is_binary_and_capacity_bounded(self):
        (dispatch, combine, aux), moe = self._route()
        d = np.asarray(dispatch)
        assert set(np.unique(d)) <= {0.0, 1.0}
        # each expert's capacity slot holds at most one token
        assert (d.sum(axis=1) <= 1.0 + 1e-6).all()

    def test_each_token_routed_at_most_topk(self):
        (dispatch, _, _), moe = self._route()
        per_token = np.asarray(dispatch).sum(axis=(2, 3))
        assert (per_token <= moe.top_k + 1e-6).all()

    def test_combine_weights_bounded_by_one(self):
        (_, combine, _), _ = self._route()
        c = np.asarray(combine).sum(axis=(2, 3))
        assert (c <= 1.0 + 1e-5).all()

    def test_zero_capacity_pressure_drops_nothing(self):
        """With capacity ≥ tokens·topk/experts · big factor, every token
        keeps all top-k slots."""
        (dispatch, _, _), moe = self._route(cf=8.0)
        per_token = np.asarray(dispatch).sum(axis=(2, 3))
        np.testing.assert_allclose(per_token, moe.top_k)

    @given(k=st.integers(1, 4), e=st.sampled_from([8, 16]))
    @settings(max_examples=10, deadline=None)
    def test_routing_properties(self, k, e):
        (dispatch, combine, aux), moe = self._route(e=e, k=k, cf=2.0)
        assert float(aux) > 0.0
        d = np.asarray(dispatch)
        assert (d.sum(axis=(2, 3)) <= k + 1e-6).all()


# ---------------------------------------------------------------------------
# Decode == prefill-continuation, per family
# ---------------------------------------------------------------------------


def _tiny(family):
    if family == "dense":
        return ModelConfig(name="t", family="dense", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2,
                           d_ff=128, vocab_size=128, dtype="float32")
    if family == "moe":
        return ModelConfig(name="t", family="moe", num_layers=2,
                           d_model=64, num_heads=4, num_kv_heads=2,
                           d_ff=64, vocab_size=128, dtype="float32",
                           moe=MoEConfig(num_experts=4, top_k=2,
                                         group_size=16,
                                         capacity_factor=8.0))
    if family == "ssm":
        return ModelConfig(name="t", family="ssm", num_layers=2,
                           d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                           vocab_size=128, dtype="float32",
                           ssm=SSMConfig(state_dim=16, head_dim=16,
                                         chunk_size=8), subquadratic=True)
    if family == "hybrid":
        return ModelConfig(name="t", family="hybrid", num_layers=4,
                           d_model=64, num_heads=4, num_kv_heads=4,
                           d_ff=128, vocab_size=128, dtype="float32",
                           ssm=SSMConfig(state_dim=16, head_dim=16,
                                         chunk_size=8),
                           hybrid=HybridConfig(attn_every=2),
                           subquadratic=True)
    raise ValueError(family)


@pytest.mark.parametrize("family", ["dense", "moe", "ssm", "hybrid"])
def test_decode_matches_prefill_continuation(family):
    """prefill(t0..t8) then decode(t9) == prefill(t0..t9) logits."""
    cfg = _tiny(family)
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size, jnp.int32)

    logits_full, _ = model.prefill(params, {"tokens": toks})

    logits_pre, cache = model.prefill(params, {"tokens": toks[:, :-1]})
    if family in ("dense", "moe"):
        # grow the cache to hold the extra token
        def grow(x):
            if x.ndim >= 4:    # [L,B,Hkv,S,hd]
                pad = [(0, 0)] * x.ndim
                pad[3] = (0, 4)
                return jnp.pad(x, pad)
            return x
        cache = {"k": grow(cache["k"]), "v": grow(cache["v"]),
                 "pos": cache["pos"]}
    elif family == "hybrid":
        def grow_kv(x):
            pad = [(0, 0)] * x.ndim
            pad[3] = (0, 4)
            return jnp.pad(x, pad)
        cache = dict(cache, attn_k=grow_kv(cache["attn_k"]),
                     attn_v=grow_kv(cache["attn_v"]))
    logits_dec, _ = model.decode_step(params, toks[:, -1], cache)
    np.testing.assert_allclose(logits_dec, logits_full, rtol=2e-3,
                               atol=2e-3)


def test_encdec_decode_matches_prefill_continuation():
    from repro.models.config import EncDecConfig
    cfg = ModelConfig(name="t", family="encdec", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128,
                      vocab_size=128, dtype="float32", norm="layernorm",
                      act="gelu", max_seq_len=32,
                      encdec=EncDecConfig(encoder_layers=2, num_frames=8))
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(KEY, (2, 10), 0, 128, jnp.int32)
    frames = jax.random.normal(KEY, (2, 8, 64), jnp.float32)

    logits_full, _ = model.prefill(params, {"tokens": toks,
                                            "frames": frames})
    logits_pre, cache = model.prefill(params, {"tokens": toks[:, :-1],
                                               "frames": frames})
    def grow(x):
        pad = [(0, 0)] * x.ndim
        pad[3] = (0, 4)
        return jnp.pad(x, pad)
    cache = dict(cache, k=grow(cache["k"]), v=grow(cache["v"]))
    logits_dec, _ = model.decode_step(params, toks[:, -1], cache)
    np.testing.assert_allclose(logits_dec, logits_full, rtol=2e-3,
                               atol=2e-3)
