"""The lane-shuffle primitive layer (§VII.C as an API) and the shared
pipeline planner: exchange semantics, tree-reduce identities, Eq. 1
block sizing, and the scratch-traffic deltas every kernel now reports."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (TARGET, lane_shuffle_down, lane_shuffle_up,
                        lane_shuffle_xor, lane_tree_reduce, fold_rows,
                        row_reduce_shuffle, plan_row_pipeline,
                        tree_stages, scratch_tree_bytes)
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(11)
LANES = TARGET.W


class TestShuffleSemantics:
    def test_down_up_are_inverse_rotations(self):
        x = jax.random.normal(KEY, (4, 128), jnp.float32)
        np.testing.assert_array_equal(
            lane_shuffle_up(lane_shuffle_down(x, 5), 5), x)

    def test_down_matches_indexing(self):
        x = jnp.arange(128.0)[None, :]
        got = lane_shuffle_down(x, 3)
        want = x[0, (jnp.arange(128) + 3) % 128][None, :]
        np.testing.assert_array_equal(got, want)

    def test_xor_is_an_involution(self):
        x = jax.random.normal(KEY, (2, 128), jnp.float32)
        for mask in (1, 2, 16, 64):
            np.testing.assert_array_equal(
                lane_shuffle_xor(lane_shuffle_xor(x, mask), mask), x)

    def test_xor_matches_indexing(self):
        x = jnp.arange(128.0)[None, :]
        got = lane_shuffle_xor(x, 8)
        want = x[0, jnp.arange(128) ^ 8][None, :]
        np.testing.assert_array_equal(got, want)

    def test_xor_rejects_bad_masks(self):
        x = jnp.zeros((1, 128))
        for mask in (0, 3, 128, -2):
            with pytest.raises(ValueError):
                lane_shuffle_xor(x, mask)

    def test_tree_reduce_is_an_allreduce(self):
        """After the rotate tree EVERY lane holds the full reduction."""
        x = jax.random.normal(KEY, (3, 128), jnp.float32)
        got = lane_tree_reduce(x)
        want = jnp.sum(x, axis=-1, keepdims=True)
        np.testing.assert_allclose(got, jnp.broadcast_to(want, got.shape),
                                   rtol=1e-5, atol=1e-5)

    def test_tree_reduce_max(self):
        x = jax.random.normal(KEY, (2, 64), jnp.float32)
        got = lane_tree_reduce(x, jnp.maximum)
        want = jnp.max(x, axis=-1, keepdims=True)
        np.testing.assert_array_equal(got, jnp.broadcast_to(want, got.shape))

    def test_tree_reduce_rejects_non_pow2(self):
        with pytest.raises(ValueError):
            lane_tree_reduce(jnp.zeros((1, 96)))

    def test_row_reduce_folds_multi_vreg_rows(self):
        for d in (128, 256, 384, 512):
            x = jax.random.normal(KEY, (5, d), jnp.float32)
            np.testing.assert_allclose(
                row_reduce_shuffle(x), jnp.sum(x, axis=-1, keepdims=True),
                rtol=1e-5, atol=1e-4)
            np.testing.assert_array_equal(
                row_reduce_shuffle(x, jnp.maximum),
                jnp.max(x, axis=-1, keepdims=True))

    def test_fold_rows_shape_and_value(self):
        x = jnp.ones((2, 3 * LANES))
        acc = fold_rows(x)
        assert acc.shape == (2, LANES)
        np.testing.assert_array_equal(acc, jnp.full((2, LANES), 3.0))


class TestPipelinePlan:
    def test_occupancy_algebra(self):
        """O = floor(S / (n_buffers × block_bytes)) — Eq. 1 rederived."""
        plan = plan_row_pipeline(1 << 20, 512, mode="native", n_buffers=2)
        assert plan.occupancy == TARGET.S // (2 * plan.block_bytes)
        assert plan.occupancy >= 2          # min_occupancy honored

    def test_block_cap_and_grid_cover(self):
        plan = plan_row_pipeline(1000, 512, mode="native",
                                 max_block_rows=64)
        assert plan.block_rows <= 64
        assert plan.grid[0] * plan.block_rows == plan.padded_rows
        assert plan.padded_rows >= 1000

    def test_small_inputs_do_not_overpad(self):
        plan = plan_row_pipeline(5, 512, mode="abstract")
        assert plan.padded_rows == 8        # one sublane granule

    def test_pow2_blocks(self):
        plan = plan_row_pipeline(24, 512, mode="abstract",
                                 max_block_rows=32, pow2_blocks=True)
        assert plan.block_rows & (plan.block_rows - 1) == 0

    def test_native_only_gets_pipeline_annotations(self):
        nat = plan_row_pipeline(1024, 512, mode="native")
        abs_ = plan_row_pipeline(1024, 512, mode="abstract")
        shf = plan_row_pipeline(1024, 512, mode="abstract+shuffle")
        assert nat.compiler_params is not None
        assert abs_.compiler_params is None and shf.compiler_params is None


class TestScratchTrafficDeltas:
    """Acceptance: each rewritten kernel reports ZERO scratch round-trips
    in abstract+shuffle mode and a positive count in abstract mode."""

    def test_rmsnorm(self):
        from repro.kernels.rmsnorm import structural_cost
        c_abs = structural_cost(4096, 4096, "abstract")
        c_shf = structural_cost(4096, 4096, "abstract+shuffle")
        c_nat = structural_cost(4096, 4096, "native")
        assert c_shf["scratch_round_trips_per_block"] == 0
        assert c_shf["scratch_bytes_total"] == 0
        assert c_shf["lane_shuffles_per_block"] == tree_stages(LANES)
        assert c_abs["scratch_round_trips_per_block"] > 0
        assert c_abs["scratch_bytes_total"] > 0
        assert c_nat["scratch_round_trips_per_block"] == 0
        assert c_abs["hbm_bytes"] == c_shf["hbm_bytes"]   # only delta: scratch

    def test_attention(self):
        from repro.kernels.attention import structural_cost
        args = (1, 8, 4096, 4096, 128, True)
        c_abs = structural_cost(*args, "abstract")
        c_shf = structural_cost(*args, "abstract+shuffle")
        assert c_shf["scratch_round_trips_per_block"] == 0
        assert c_shf["scratch_bytes_total"] == 0
        assert c_shf["lane_shuffles_per_block"] == 2 * tree_stages(LANES)
        assert c_abs["scratch_round_trips_per_block"] == \
            2 * tree_stages(LANES)                 # row-max + row-sum
        assert c_abs["scratch_bytes_total"] > 0
        # shuffle does not unlock grid-level block-skip (native feature)
        assert c_shf["skip_fraction"] == 0.0

    def test_histogram(self):
        from repro.kernels.histogram import structural_cost
        c_abs = structural_cost(1 << 24, 256, "abstract")
        c_shf = structural_cost(1 << 24, 256, "abstract+shuffle")
        assert c_shf["scratch_round_trips_per_block"] == 0
        assert c_shf["scratch_bytes_total"] == 0
        assert c_shf["lane_shuffles_per_block"] == tree_stages(LANES)
        assert c_abs["scratch_round_trips_per_block"] > 0
        assert c_abs["scratch_bytes_total"] > 0
        # shuffle mode privatizes per sublane row, like native
        assert c_shf["private_histograms_per_block"] > 1

    def test_reduction_unchanged_mechanism(self):
        from repro.kernels.reduction import structural_cost
        c_abs = structural_cost(1 << 24, "abstract")
        c_shf = structural_cost(1 << 24, "abstract+shuffle")
        assert c_abs["scratch_round_trips_per_block"] == tree_stages(LANES)
        assert c_shf["scratch_round_trips_per_block"] == 0

    def test_cost_vocabulary(self):
        assert tree_stages(128) == 7
        assert scratch_tree_bytes(128) == sum(
            3 * (128 >> k) * 4 for k in range(1, 8))
        with pytest.raises(ValueError):
            tree_stages(100)


class TestShuffleModeEquivalence:
    """Acceptance: abstract+shuffle numerically matches the library
    reference at atol <= 1e-5 (histogram: exact)."""

    @pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (16, 384)])
    def test_rmsnorm(self, shape):
        kx, kw = jax.random.split(KEY)
        x = jax.random.normal(kx, shape, jnp.float32)
        w = jax.random.normal(kw, (shape[-1],), jnp.float32) + 1.0
        want = ops.rmsnorm(x, w, mode="library")
        for mode in ("abstract", "abstract+shuffle", "native"):
            got = ops.rmsnorm(x, w, mode=mode)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("sq,skv,hkv", [(128, 128, 4), (200, 328, 2)])
    def test_attention(self, sq, skv, hkv):
        kq, kk, kv = jax.random.split(KEY, 3)
        q = jax.random.normal(kq, (1, 4, sq, 64), jnp.float32)
        k = jax.random.normal(kk, (1, hkv, skv, 64), jnp.float32)
        v = jax.random.normal(kv, (1, hkv, skv, 64), jnp.float32)
        want = ops.flash_attention(q, k, v, causal=True, mode="library")
        for mode in ("abstract", "abstract+shuffle", "native"):
            got = ops.flash_attention(q, k, v, causal=True, mode=mode,
                                      block_q=128, block_kv=128)
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("n", [4096, 50000])
    def test_histogram(self, n):
        vals = jax.random.randint(KEY, (n,), -3, 260, jnp.int32)
        want = np.asarray(ops.histogram(vals, 256, mode="library"))
        for mode in ("abstract", "abstract+shuffle", "native"):
            got = np.asarray(ops.histogram(vals, 256, mode=mode))
            np.testing.assert_array_equal(got, want)

    def test_reduction(self):
        x = jax.random.normal(KEY, (70001,), jnp.float32)
        want = ops.reduce_sum(x, mode="library")
        got = ops.reduce_sum(x, mode="abstract+shuffle")
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
