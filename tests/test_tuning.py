"""Cost-model autotuner (ISSUE 3 tentpole, autotuning half).

Covers: candidate-grid legality under the Eq. 1 occupancy algebra,
structural ranking, bucket round-tripping, the ``tuned=`` plan override
(including its refusal to break the occupancy invariant), table
lookup/persistence, the CI sync check, and measured re-ranking.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (REGISTRY, TARGET, TPU_V5E, UISA_UNIVERSAL10,
                        plan_row_pipeline, tuning)
from repro.core.pipeline import SUBLANES
from repro.kernels import ops, ref  # noqa: F401 (installs op spaces)

KEY = jax.random.PRNGKey(5)


class TestCandidates:
    def test_rowwise_candidates_all_legal(self):
        cands = tuning.rowwise_candidates(4096, 4096, TPU_V5E,
                                          max_block_rows=64)
        assert cands
        for c in cands:
            assert c.block_rows % SUBLANES == 0
            assert TPU_V5E.buffer_occupancy(
                c.block_rows * 4096, c.n_buffers) == c.occupancy
            assert c.occupancy >= 2
            assert c.block_rows <= 64 * tuning.CAP_CORRIDOR

    def test_rank_prefers_fewer_steps_then_depth(self):
        cands = tuning.rowwise_candidates(4096, 4096, TPU_V5E,
                                          max_block_rows=64)
        best = cands[0]
        assert best.grid_steps == min(c.grid_steps for c in cands)
        same_steps = [c for c in cands if c.grid_steps == best.grid_steps]
        assert best.n_buffers == max(c.n_buffers for c in same_steps)

    def test_tiny_budget_floor_candidate(self):
        """A scratchpad too small for any legal point still yields the
        floor plan rather than an empty grid."""
        cands = tuning.rowwise_candidates(1024, 4096, UISA_UNIVERSAL10)
        assert cands[-1].block_rows == SUBLANES or \
            all(c.block_rows == SUBLANES for c in cands)

    def test_gemm_candidates_fit_budget(self):
        for params in tuning.gemm_candidates(1024, 1024, 1024, TPU_V5E):
            bm, bn, bk = params["block"]
            working = (bm * bk + bk * bn) * 4 + bm * bn * 4
            assert TPU_V5E.buffer_occupancy(working, 2) >= 2

    def test_attention_candidates_ranked_by_steps(self):
        cands = tuning.attention_candidates(1024, 1024, 64, TPU_V5E)
        assert cands
        steps = [-(-1024 // c["block_q"]) * -(-1024 // c["block_kv"])
                 for c in cands]
        assert steps == sorted(steps)


class TestBuckets:
    def test_bucket_round_trip(self):
        b = tuning.rowwise_bucket(1000, 3000)
        rep = tuning.parse_bucket(b)
        assert rep == {"rows": 1024, "rb": 4096}
        g = tuning.parse_bucket(tuning.gemm_bucket(300, 1024, 65))
        assert g == {"m": 512, "n": 1024, "k": 128}

    def test_malformed_bucket_rejected(self):
        with pytest.raises(ValueError):
            tuning.parse_bucket("rows:nonsense")


class TestTunedPlan:
    def test_tuned_override_applies(self):
        plan = plan_row_pipeline(4096, 4096, mode="native",
                                 max_block_rows=64,
                                 tuned={"block_rows": 256, "n_buffers": 4})
        assert plan.block_rows == 256        # supersedes the static cap
        assert plan.n_buffers == 4
        assert plan.padded_rows % plan.block_rows == 0

    def test_tuned_override_respects_occupancy_invariant(self):
        """An entry that would drop occupancy below the floor degrades to
        the heuristic block instead of emitting an illegal plan."""
        heur = plan_row_pipeline(4096, 4096, mode="native",
                                 max_block_rows=64)
        huge = TARGET.S // 4096              # occupancy 0 at n_buffers=2
        plan = plan_row_pipeline(4096, 4096, mode="native",
                                 max_block_rows=64,
                                 tuned={"block_rows": huge})
        assert plan.block_rows == heur.block_rows

    def test_tuned_plan_consults_table(self):
        table = tuning.TuningTable({})
        table.record("rmsnorm", "native", TARGET.name,
                     tuning.rowwise_bucket(4096, 4096),
                     {"block_rows": 128, "n_buffers": 3})
        plan = tuning.tuned_plan("rmsnorm", 4096, 4096, mode="native",
                                 max_block_rows=64, table=table)
        assert (plan.block_rows, plan.n_buffers) == (128, 3)
        # missing entry -> pure heuristic
        miss = tuning.tuned_plan("rmsnorm", 4096, 8192, mode="native",
                                 max_block_rows=64, table=table)
        assert miss.block_rows <= 64

    def test_committed_entries_change_the_plan(self):
        """The committed table's bench-shape winners really are consulted
        (the tuned path is live, not dead code)."""
        entry = tuning.TUNING_TABLE.lookup(
            "rmsnorm", "native", TARGET.name,
            tuning.rowwise_bucket(1024, 4096))
        assert entry is not None
        plan = tuning.tuned_plan("rmsnorm", 1024, 4096, mode="native",
                                 max_block_rows=64)
        assert plan.block_rows == entry["block_rows"]
        assert plan.n_buffers == entry["n_buffers"]

    def test_tuned_kernel_numerics_unchanged(self):
        """A tuned staging point changes the plan, never the math."""
        x = jax.random.normal(KEY, (1024, 1024), jnp.float32)
        w = jnp.ones((1024,), jnp.float32)
        got = ops.rmsnorm(x, w, mode="native")
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.rmsnorm(x, w)),
                                   rtol=1e-4, atol=1e-4)


class TestTableSync:
    def test_committed_table_in_sync(self):
        failures = tuning.check_table(REGISTRY)
        assert failures == [], failures

    def test_stale_op_fails(self):
        table = tuning.TuningTable({
            "no_such_op|native|tpu-v5e|rows64:rb512":
                {"block_rows": 8, "n_buffers": 2, "source": "structural"}})
        assert any("not registered" in f
                   for f in tuning.check_table(REGISTRY, table))

    def test_off_grid_params_fail(self):
        bucket = tuning.rowwise_bucket(1024, 4096)
        table = tuning.TuningTable({
            f"rmsnorm|native|tpu-v5e|{bucket}":
                {"block_rows": 12345, "n_buffers": 2,
                 "source": "structural"}})
        assert any("outside the legal candidate grid" in f
                   for f in tuning.check_table(REGISTRY, table))

    def test_unknown_dialect_fails(self):
        table = tuning.TuningTable({
            "rmsnorm|native|no-such-dialect|rows64:rb512":
                {"block_rows": 8, "n_buffers": 2, "source": "structural"}})
        assert any("unknown dialect" in f
                   for f in tuning.check_table(REGISTRY, table))


class TestAutotune:
    def test_structural_winner_recorded(self):
        table = tuning.TuningTable({})
        bucket = tuning.rowwise_bucket(1024, 4096)
        winner = tuning.autotune_entry(table, "rmsnorm", "native", bucket)
        entry = table.lookup("rmsnorm", "native", TARGET.name, bucket)
        assert entry is not None and entry["source"] == "structural"
        assert {k: v for k, v in entry.items() if k != "source"} == winner

    def test_measured_rerank_picks_fastest(self):
        calls = []

        def build_fn(params):
            calls.append(dict(params))
            # fabricate: smaller blocks "measure" faster here
            delay = params["block_rows"]

            def run():
                import time
                time.sleep(delay * 1e-5)
                return np.zeros(())
            return run

        table = tuning.TuningTable({})
        bucket = tuning.rowwise_bucket(256, 4096)
        winner = tuning.autotune_entry(table, "rmsnorm", "native", bucket,
                                       build_fn=build_fn, iters=1,
                                       warmup=0, top_k=3)
        assert len(calls) == 3
        assert winner["block_rows"] == min(c["block_rows"] for c in calls)
        entry = table.lookup("rmsnorm", "native", TARGET.name, bucket)
        assert entry["source"] == "measured"

    def test_table_save_load_round_trip(self, tmp_path):
        table = tuning.TuningTable({})
        table.record("rmsnorm", "native", TARGET.name, "rows64:rb512",
                     {"block_rows": 16, "n_buffers": 2})
        path = table.save(str(tmp_path / "t.json"))
        loaded = tuning.TuningTable.load(path)
        assert loaded.entries == table.entries
