"""ISSUE 5: fusion-legal parameter layouts end-to-end.

Pins the layout subsystem at every layer it crosses:

- the ``ParamLayout`` planner (policy-driven, init-time);
- the layout-agnostic accessors (either stored layout, same numbers);
- decode-legality: the decode tick fuses exactly when the concatenated
  tensor is *persisted* (zero weight-traffic overhead), and stays on the
  PR 4 unfused path for legacy params;
- structural pinning: the fused decode rows save exactly the activation
  round trip — no weight term appears or disappears;
- checkpoint migration: legacy -> concat -> legacy is bitwise on weights,
  both through ``restore`` templates and ``save(migrate_to=)``;
- the jit-cache-key fix: two policies at identical shapes bind *their
  own* dialect's staging plans (plan_dialect is a static kernel arg).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, layout_of, migrate_layout
from repro.core.registry import REGISTRY, ExecutionPolicy
from repro.kernels import ops as kernel_ops
from repro.models import build_model, common, mlp, transformer
from repro.models.config import (LEGACY_LAYOUT, ModelConfig, MoEConfig,
                                 ParallelConfig, ParamLayout)

KEY = jax.random.PRNGKey(0)


def tiny_cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def models_pair(cfg=None):
    """(legacy-layout model, concat-layout model) over the same config."""
    cfg = cfg or tiny_cfg()
    plain = build_model(cfg, ParallelConfig(remat="none"))
    fused = build_model(cfg, ParallelConfig(remat="none",
                                            fuse_epilogues=True))
    return plain, fused


class TestPlanner:
    def test_default_policy_plans_legacy(self):
        plain, fused = models_pair()
        assert plain.param_layout == LEGACY_LAYOUT
        assert fused.param_layout == ParamLayout(attn_qkv=True,
                                                 mlp_swiglu=True)

    def test_auto_mode_plans_concat(self):
        cfg = tiny_cfg()
        m = build_model(cfg, ParallelConfig(remat="none", isa_mode="auto"))
        assert m.param_layout.attn_qkv and m.param_layout.mlp_swiglu

    def test_gelu_gets_no_swiglu_tensor(self):
        cfg = tiny_cfg(act="gelu")
        m = build_model(cfg, ParallelConfig(remat="none",
                                            fuse_epilogues=True))
        assert m.param_layout.attn_qkv and not m.param_layout.mlp_swiglu
        p = m.init_params(KEY)
        assert "wig" not in p["blocks"]["mlp"]
        assert "wqkv" in p["blocks"]["attn"]

    def test_layernorm_stays_legacy(self):
        cfg = tiny_cfg(norm="layernorm")
        m = build_model(cfg, ParallelConfig(remat="none",
                                            fuse_epilogues=True))
        assert m.param_layout == LEGACY_LAYOUT

    def test_specs_follow_the_layout(self):
        plain, fused = models_pair()
        legacy_specs = plain.param_specs()["blocks"]["attn"]
        concat_specs = fused.param_specs()["blocks"]["attn"]
        assert "wq" in legacy_specs and "wqkv" not in legacy_specs
        assert "wqkv" in concat_specs and "wq" not in concat_specs


class TestAccessors:
    def test_same_seed_same_weights_either_layout(self):
        cfg = tiny_cfg()
        legacy, _ = transformer.init_attn(KEY, cfg, jnp.float32)
        concat, _ = transformer.init_attn(
            KEY, cfg, jnp.float32, ParamLayout(attn_qkv=True))
        widths = transformer._qkv_widths(cfg)
        for got, want in zip(
                common.split_param(concat, "wqkv", ("wq", "wk", "wv"),
                                   widths),
                (legacy["wq"], legacy["wk"], legacy["wv"])):
            assert jnp.array_equal(got, want)
        cat = common.concat_param(legacy, "wqkv", ("wq", "wk", "wv"))
        assert jnp.array_equal(cat, concat["wqkv"])

    def test_stored_concat_gate(self):
        cfg = tiny_cfg()
        legacy, _ = mlp.init_mlp(KEY, cfg.d_model, cfg.d_ff, "silu",
                                 jnp.float32)
        concat, _ = mlp.init_mlp(KEY, cfg.d_model, cfg.d_ff, "silu",
                                 jnp.float32,
                                 ParamLayout(mlp_swiglu=True))
        assert not common.stored_concat(legacy, "wig")
        assert common.stored_concat(concat, "wig")
        wi, wg = mlp._wi_wg(concat)
        assert jnp.array_equal(wi, legacy["wi"])
        assert jnp.array_equal(wg, legacy["wg"])


def _greedy_decode(model, params, prompt, steps=4, cache_len=16):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks})
    pad = cache_len - cache["k"].shape[3]
    cache = {"k": jnp.pad(cache["k"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
             "pos": cache["pos"]}
    out = [int(jnp.argmax(logits[0]))]
    for _ in range(steps):
        lg, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


class TestDecodeLayoutEquivalence:
    """Every (policy, stored layout) quadrant decodes the same tokens —
    including the all-fusions-on concat quadrant with the Pallas decode
    attention epilogue."""

    @pytest.mark.parametrize("cfg", [
        tiny_cfg(),
        tiny_cfg(name="moe-shared", family="moe",
                 moe=MoEConfig(num_experts=4, top_k=1, group_size=64,
                               shared_experts=1)),
    ], ids=["dense", "moe-shared"])
    def test_quadrants_match(self, cfg):
        plain, fused = models_pair(cfg)
        pallas = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True, use_pallas_attn=True))
        p_legacy = plain.init_params(KEY)
        p_concat = fused.init_params(KEY)
        prompt = [3, 5, 7]
        want = _greedy_decode(plain, p_legacy, prompt)
        assert _greedy_decode(plain, p_concat, prompt) == want
        assert _greedy_decode(fused, p_legacy, prompt) == want
        assert _greedy_decode(fused, p_concat, prompt) == want
        assert _greedy_decode(pallas, p_concat, prompt) == want

    def test_decode_fusion_gates_on_persisted_layout(self, monkeypatch):
        """Concat params fuse q/k/v + swiglu at decode; legacy params
        keep the PR 4 unfused decode (the per-call concat tax is a net
        loss at decode rows, so the gate must stay shut)."""
        cfg = tiny_cfg()
        plain, fused = models_pair(cfg)
        p_legacy = plain.init_params(KEY)
        p_concat = fused.init_params(KEY)
        calls = []
        for name in ("fused_rmsnorm_matmul", "fused_rmsnorm_swiglu"):
            orig = getattr(kernel_ops, name)
            def spy(*a, _name=name, _orig=orig, **k):
                calls.append(_name)
                return _orig(*a, **k)
            monkeypatch.setattr(kernel_ops, name, spy)

        cache = plain.init_cache(1, 8)
        kv = (cache["k"][0], cache["v"][0])      # layer 0: [B,Hkv,S,hd]
        x_t = jnp.zeros((1, 1, cfg.d_model), jnp.float32)
        pos = jnp.zeros((1,), jnp.int32)
        block_legacy = jax.tree.map(lambda a: a[0], p_legacy["blocks"])
        block_concat = jax.tree.map(lambda a: a[0], p_concat["blocks"])

        transformer.block_decode(block_legacy, x_t, cfg, kv, pos, None,
                                 policy=fused.policy)
        assert calls == []          # legacy layout: gates shut (PR 4)
        transformer.block_decode(block_concat, x_t, cfg, kv, pos, None,
                                 policy=fused.policy)
        assert "fused_rmsnorm_matmul" in calls      # q/k/v prologue
        assert "fused_rmsnorm_swiglu" in calls      # ln2 -> [wi|wg]


class TestDecodeStructuralCost:
    """The decode-shaped fused rows save exactly one activation round
    trip — zero weight-traffic overhead vs the unfused decode path (the
    weight term appears identically on both sides and cancels)."""

    @pytest.mark.parametrize("rows", [1, 8, 128])
    def test_qkv_prologue_saving_is_activation_only(self, rows):
        d, n = 1024, 3 * 1024
        itemsize = 4
        for mode in REGISTRY.modes("rmsnorm_matmul"):
            cost = REGISTRY.structural_cost("rmsnorm_matmul", mode,
                                            rows=rows, d=d, n=n)
            saved = cost["hbm_bytes_unfused_pair"] - cost["hbm_bytes"]
            if mode == "library":
                assert saved == 0
            else:
                assert saved == 2 * rows * d * itemsize
                # scale-invariance of the weight term: the saving never
                # grows with the weight size (d*n), only with rows*d
                assert saved < d * n * itemsize

    @pytest.mark.parametrize("rows", [1, 8, 128])
    def test_swiglu_saving_is_activation_only(self, rows):
        d = f = 1024
        itemsize = 4
        for mode in REGISTRY.modes("rmsnorm_swiglu"):
            cost = REGISTRY.structural_cost("rmsnorm_swiglu", mode,
                                            rows=rows, d=d, f=f)
            saved = cost["hbm_bytes_unfused_pair"] - cost["hbm_bytes"]
            assert saved == (0 if mode == "library"
                             else 2 * rows * d * itemsize)

    def test_decode_attention_epilogue_saving(self):
        b, h, skv, d, n = 128, 8, 32768, 128, 1024
        itemsize = 4
        for mode in REGISTRY.modes("flash_attention_matmul"):
            cost = REGISTRY.structural_cost(
                "flash_attention_matmul", mode, b=b, h=h, sq=1, skv=skv,
                d=d, n=n, causal=False)
            saved = cost["hbm_bytes_unfused_pair"] - cost["hbm_bytes"]
            assert saved == (0 if mode == "library"
                             else 2 * b * 1 * h * d * itemsize)

    def test_fused_decode_beats_unfused_pair(self):
        """At the serve tick's shapes the fused rows are strictly cheaper
        in HBM bytes than the unfused pair they replace."""
        for op, shape in (("rmsnorm_matmul", dict(rows=128, d=1024,
                                                  n=3072)),
                          ("rmsnorm_swiglu", dict(rows=128, d=1024,
                                                  f=1024)),
                          ("flash_attention_matmul",
                           dict(b=128, h=8, sq=1, skv=32768, d=128,
                                n=1024, causal=False))):
            cost = REGISTRY.structural_cost(op, "native", **shape)
            assert cost["hbm_bytes"] < cost["hbm_bytes_unfused_pair"], op


class TestCheckpointMigration:
    def test_round_trip_bitwise(self, tmp_path):
        plain, fused = models_pair()
        p_legacy = plain.init_params(jax.random.PRNGKey(7))
        ck = CheckpointManager(str(tmp_path))
        ck.save(0, p_legacy)
        assert ck.manifest(0)["param_layout"] == "legacy"

        tmpl_c = jax.eval_shape(fused.init_params, KEY)
        p_concat = ck.restore(0, tmpl_c)           # legacy -> concat
        assert "wqkv" in p_concat["blocks"]["attn"]
        ck.save(1, p_concat)
        assert ck.manifest(1)["param_layout"] == "concat"

        tmpl_l = jax.eval_shape(plain.init_params, KEY)
        p_back = ck.restore(1, tmpl_l)             # concat -> legacy
        for a, b in zip(jax.tree_util.tree_leaves(p_legacy),
                        jax.tree_util.tree_leaves(p_back)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_save_migrate_to_emits_legacy(self, tmp_path):
        """A concat-layout process saves back out in per-matrix form."""
        plain, fused = models_pair()
        p_concat = fused.init_params(jax.random.PRNGKey(7))
        tmpl_l = jax.eval_shape(plain.init_params, KEY)
        ck = CheckpointManager(str(tmp_path))
        ck.save(0, p_concat, migrate_to=tmpl_l)
        assert ck.manifest(0)["param_layout"] == "legacy"
        restored = ck.restore(0, tmpl_l)
        want = plain.init_params(jax.random.PRNGKey(7))
        for a, b in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_migrate_layout_rejects_width_mismatch(self):
        flat = {"blocks/attn/wqkv": np.zeros((4, 10), np.float32)}
        shapes = {"blocks/attn/wq": (4, 4), "blocks/attn/wk": (4, 4),
                  "blocks/attn/wv": (4, 4)}
        with pytest.raises(ValueError):
            migrate_layout(flat, shapes)

    def test_layout_of(self):
        assert layout_of(["blocks/attn/wq", "embed"]) == "legacy"
        assert layout_of(["blocks/attn/wqkv"]) == "concat"
        assert layout_of(["blocks/mlp/wig"]) == "concat"

    def test_train_shardings_carry_layout(self):
        """train/step.py threads the layout plan next to the sharding
        trees (train->serve handoff metadata)."""
        from repro.train.step import _train_shardings
        _, fused = models_pair()
        # no mesh: shardings are None and the layout rides on the model
        assert _train_shardings(fused, None, None) is None
        assert dataclasses.asdict(fused.param_layout) == {
            "attn_qkv": True, "mlp_swiglu": True}


class TestPrecisionMigration:
    """ISSUE 7 satellite: checkpoint precision migration.  f32 -> int8
    -> f32 through migrate_layout is idempotent after the first
    quantization — the second round trip is bitwise on the int8 bytes
    AND the scales (power-of-two at-rest scales make requantization a
    fixed point) — and the manifest records the precision."""

    def _model_and_templates(self):
        _, fused = models_pair()
        params = fused.init_params(jax.random.PRNGKey(7))
        qtmpl = jax.eval_shape(lambda: common.quantize_params(params))
        ftmpl = jax.eval_shape(fused.init_params, KEY)
        return params, qtmpl, ftmpl

    def test_second_round_trip_bitwise_stable(self, tmp_path):
        params, qtmpl, ftmpl = self._model_and_templates()
        ck = CheckpointManager(str(tmp_path), keep=10)
        ck.save(0, params)
        assert ck.manifest(0)["precision"] == "f32"
        q1 = ck.restore(0, qtmpl)            # quantize-on-restore
        assert q1["blocks"]["attn"]["wqkv"].dtype == jnp.int8
        ck.save(1, q1)
        assert ck.manifest(1)["precision"] == "int8"
        f1 = ck.restore(1, ftmpl)            # dequantize-on-restore
        # first trip is lossy but bounded (tolerance policy, conftest)
        from conftest import tolerance_for
        for a, b in zip(jax.tree_util.tree_leaves(f1),
                        jax.tree_util.tree_leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       **tolerance_for("int8", ref=b))
        # second trip: requantizing the dequantized weights reproduces
        # the SAME int8 bytes and scales, bit for bit
        ck.save(2, f1)
        q2 = ck.restore(2, qtmpl)
        for a, b in zip(jax.tree_util.tree_leaves(q1),
                        jax.tree_util.tree_leaves(q2)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_quantize_on_save_matches_restore_path(self, tmp_path):
        """save(migrate_to=<int8 template>) writes the same quantized
        leaves restore-into-int8-template would produce."""
        params, qtmpl, _ = self._model_and_templates()
        ck = CheckpointManager(str(tmp_path), keep=10)
        ck.save(0, params, migrate_to=qtmpl)
        assert ck.manifest(0)["precision"] == "int8"
        ck.save(1, params)
        via_save = ck.restore(0, qtmpl)      # already int8: passthrough
        via_restore = ck.restore(1, qtmpl)   # quantized at restore
        for a, b in zip(jax.tree_util.tree_leaves(via_save),
                        jax.tree_util.tree_leaves(via_restore)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_int8_concat_restores_into_legacy_f32(self, tmp_path):
        """Precision and layout migration compose: an int8 concat
        checkpoint dequantizes FIRST, then splits toward a legacy
        per-matrix f32 template (scales never split)."""
        plain, _ = models_pair()
        params, qtmpl, _ = self._model_and_templates()
        ck = CheckpointManager(str(tmp_path), keep=10)
        ck.save(0, params, migrate_to=qtmpl)
        tmpl_l = jax.eval_shape(plain.init_params, KEY)
        restored = ck.restore(0, tmpl_l)
        attn = restored["blocks"]["attn"]
        assert {"wq", "wk", "wv"} <= set(attn)
        assert attn["wq"].dtype == jnp.float32
        from conftest import tolerance_for
        want = plain.init_params(jax.random.PRNGKey(7))
        np.testing.assert_allclose(
            np.asarray(restored["blocks"]["attn"]["wq"]),
            np.asarray(want["blocks"]["attn"]["wq"]),
            **tolerance_for("int8", ref=want["blocks"]["attn"]["wq"]))

    def test_quantized_params_decode_close_to_f32(self):
        """Model-level: the quantized tree the precision policy serves
        produces logits within the int8 tolerance of the f32 tree (the
        serve-tick equivalence claim at its smallest reproduction)."""
        from conftest import tolerance_for
        cfg = tiny_cfg()
        par = ParallelConfig(remat="none", isa_mode="auto",
                             weight_precision="int8")
        model = build_model(cfg, par)
        params = model.init_params(jax.random.PRNGKey(7))
        qparams = common.quantize_params(params)
        cache_f = model.init_cache(2, 16)
        cache_q = model.init_cache(2, 16)
        toks = jnp.array([3, 5], jnp.int32)
        ref_model = build_model(cfg, ParallelConfig(remat="none",
                                                    isa_mode="auto"))
        want, _ = ref_model.decode_step(params, toks, cache_f)
        got, _ = model.decode_step(qparams, toks, cache_q)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **tolerance_for("int8", ref=want))


class TestMixedDialectPlans:
    """The PR 4 jit-cache-key gap, closed: two policies at identical
    shapes bind *different* staging plans because plan_dialect is a
    static kernel argument (part of the jit cache key)."""

    def test_two_dialects_two_plans_one_shape(self, monkeypatch):
        from repro.kernels import rmsnorm as rms_mod
        records = []
        orig = rms_mod.tuned_plan

        def spy(op, rows, rb, **kw):
            plan = orig(op, rows, rb, **kw)
            records.append((kw.get("dialect"), plan.block_rows))
            return plan

        monkeypatch.setattr(rms_mod, "tuned_plan", spy)
        # a shape no other test traces, so both policies trace freshly
        x = jax.random.normal(KEY, (88, 2048), jnp.float32)
        w = jnp.ones((2048,), jnp.float32)
        pol_a = ExecutionPolicy(mode="abstract", dialect="tpu-v5e")
        pol_b = ExecutionPolicy(mode="abstract",
                                dialect="uisa-universal10")
        out_a = kernel_ops.rmsnorm(x, w, policy=pol_a)
        out_b = kernel_ops.rmsnorm(x, w, policy=pol_b)
        assert len(records) == 2
        (dial_a, block_a), (dial_b, block_b) = records
        assert dial_a == "tpu-v5e" and dial_b == "uisa-universal10"
        # identical shapes, different staging plans — the foreign
        # dialect's 48 KB scratchpad forces a smaller row block
        assert block_a != block_b
        # numerics are plan-invariant
        assert jnp.allclose(out_a, out_b, atol=1e-5)
        # the same policy again is a cache hit: no retrace, no new plan
        kernel_ops.rmsnorm(x, w, policy=pol_a)
        assert len(records) == 2
