"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_memory_per_module():
    """Drop XLA's compiled-executable caches once a module finishes.

    The full suite compiles hundreds of distinct programs in one
    process; letting every executable stay resident can segfault the
    CPU backend's JIT late in the run.  Compiled programs are never
    shared across test modules (each builds its own tiny models), so
    clearing between modules costs nothing but the crash."""
    yield
    jax.clear_caches()


#: Shared per-precision numeric tolerance policy (ISSUE 7): every suite
#: that checks a lowering against the f32 library reference draws its
#: bounds from this one table instead of ad-hoc per-test constants.
#: ``None``/"f32" is the f32-kernel-vs-f32-library bound (accumulation
#: order only).  "int8" bounds quantized rows against the *f32*
#: reference: per-output-channel symmetric int8 carries ~0.4-1.7% max
#: relative error at conformance shapes (measured across all three
#: quantized fused ops, dense/decode/paged), so 2e-2 relative — plus an
#: absolute leg for elements crossing zero, because quantization error
#: is proportional to the quantized channel's *dynamic range*, not the
#: element's magnitude.  The atol leg therefore scales with the
#: reference tensor: ``atol_scale x max|ref|`` when the reference is
#: supplied (swiglu compounds two quantized projections, so its error
#: tracks the O(100) intermediates; a flat constant would either fail
#: it or be vacuous for O(1) weight round-trips), falling back to the
#: flat ``atol`` when it is not.
TOLERANCES = {
    None: dict(rtol=2e-4, atol=2e-4),
    "f32": dict(rtol=2e-4, atol=2e-4),
    #: sequential f32-accumulator kernels (ISSUE 8: the fused SSD scan
    #: carries its [N,P] state in VMEM across every chunk step): both
    #: sides accumulate in f32, but the kernel's per-chunk dot order and
    #: exp(decay) association differ from the jnp chunk path, and the
    #: drift compounds with sequence length rather than staying at the
    #: single-reduction bound above.
    "f32_accum": dict(rtol=1e-3, atol=1e-3),
    "int8": dict(rtol=2e-2, atol=2e-2, atol_scale=2e-1),
}


def tolerance_for(precision=None, ref=None) -> dict:
    """The atol/rtol kwargs the given ExecutionPolicy precision earns.

    ``ref`` (the comparison's reference tensor, or any leaf sequence of
    them) widens range-relative precisions' atol to
    ``atol_scale x max|ref|``."""
    tol = dict(TOLERANCES[precision])
    scale = tol.pop("atol_scale", None)
    if scale is not None and ref is not None:
        leaves = jax.tree.leaves(ref)
        ref_max = max((float(np.max(np.abs(np.asarray(l, np.float32))))
                       for l in leaves if np.asarray(l).size), default=0.0)
        tol["atol"] = max(tol["atol"], scale * ref_max)
    return tol


def assert_allclose(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)


def assert_close_for(a, b, precision=None):
    """assert_allclose at the shared tolerance policy's bounds (``b`` is
    the reference and anchors any range-relative atol)."""
    tol = tolerance_for(precision, ref=b)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), **tol)
