"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the real
single CPU device; only launch/dryrun.py forces 512 placeholder devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def assert_allclose(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=rtol, atol=atol)
