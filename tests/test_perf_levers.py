"""Correctness of the §Perf levers: every optimization must preserve
model semantics (tested here) before its roofline effect counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.attention import (dequantize_kv, quantize_kv,
                                    update_cache_int8)
from repro.models.config import ModelConfig, ParallelConfig
from repro.parallel.sharding import sanitize_sharding

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    base = dict(name="t", family="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


class TestCausalFolding:
    def test_loss_identical(self):
        """causal_folding changes which blocks are *visited*, never the
        math: losses must match to fp tolerance."""
        cfg = _cfg()
        toks = jax.random.randint(KEY, (2, 48), 0, 128, jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        m0 = build_model(cfg, ParallelConfig(remat="none",
                                             causal_folding=False,
                                             attn_chunk_q=16,
                                             attn_chunk_kv=16))
        m1 = build_model(cfg, ParallelConfig(remat="none",
                                             causal_folding=True,
                                             attn_chunk_q=16,
                                             attn_chunk_kv=16))
        p = m0.init_params(KEY)
        l0, _ = m0.loss_fn(p, batch)
        l1, _ = m1.loss_fn(p, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


class TestPallasAttnPath:
    def test_model_loss_matches_jnp_path(self):
        """The framework's Pallas flash kernel (interpret mode on CPU)
        is numerically interchangeable with the jnp chunked path inside
        the full model."""
        cfg = _cfg()
        toks = jax.random.randint(KEY, (1, 32), 0, 128, jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        m_jnp = build_model(cfg, ParallelConfig(remat="none"))
        m_pal = build_model(cfg, ParallelConfig(remat="none",
                                                use_pallas_attn=True))
        p = m_jnp.init_params(KEY)
        l0, _ = m_jnp.loss_fn(p, batch)
        l1, _ = m_pal.loss_fn(p, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)


class TestKvConstraintLever:
    def test_loss_identical(self):
        cfg = _cfg()
        toks = jax.random.randint(KEY, (2, 32), 0, 128, jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        m0 = build_model(cfg, ParallelConfig(remat="none"))
        m1 = build_model(cfg, ParallelConfig(remat="none",
                                             constrain_kv_pre_repeat=False,
                                             rs_outputs=True))
        p = m0.init_params(KEY)
        np.testing.assert_allclose(float(m0.loss_fn(p, batch)[0]),
                                   float(m1.loss_fn(p, batch)[0]),
                                   rtol=1e-6)


class TestInt8KvCache:
    def test_quantize_roundtrip_error(self):
        x = jax.random.normal(KEY, (2, 4, 16, 32)) * 3.0
        q, s = quantize_kv(x)
        deq = dequantize_kv(q, s, jnp.float32)
        err = jnp.max(jnp.abs(deq - x))
        assert float(err) <= float(jnp.max(s)) * 0.5 + 1e-6

    def test_scale_per_token(self):
        x = jnp.ones((1, 1, 4, 8)).at[0, 0, 2].mul(100.0)
        q, s = quantize_kv(x)
        assert s.shape == (1, 1, 4, 1)
        assert float(s[0, 0, 2, 0]) > float(s[0, 0, 0, 0]) * 50

    def test_update_writes_one_slot(self):
        cq = jnp.zeros((2, 2, 8, 4), jnp.int8)
        cs = jnp.full((2, 2, 8, 1), 1e-8, jnp.float32)
        new = jnp.ones((2, 2, 1, 4)) * 2.0
        pos = jnp.array([1, 6], jnp.int32)
        cq2, cs2 = update_cache_int8(cq, cs, new, pos)
        assert int(cq2[0, 0, 1, 0]) == 127
        assert int(cq2[0, 0, 0, 0]) == 0
        np.testing.assert_allclose(float(cs2[1, 0, 6, 0]), 2.0 / 127,
                                   rtol=1e-5)

    def test_decode_matches_bf16_cache(self):
        cfg = _cfg()
        toks = jax.random.randint(KEY, (2, 12), 0, 128, jnp.int32)
        m_bf = build_model(cfg, ParallelConfig(remat="none"))
        m_q8 = build_model(cfg, ParallelConfig(remat="none",
                                               kv_cache_int8=True))
        p = m_bf.init_params(KEY)
        _, c_b = m_bf.prefill(p, {"tokens": toks[:, :-1]})
        _, c_q = m_q8.prefill(p, {"tokens": toks[:, :-1]})

        def grow(x):
            pad = [(0, 0)] * x.ndim
            pad[3] = (0, 4)
            return jnp.pad(x, pad)
        c_b = {"k": grow(c_b["k"]), "v": grow(c_b["v"]), "pos": c_b["pos"]}
        c_q = {"k": grow(c_q["k"]), "k_scale": grow(c_q["k_scale"]),
               "v": grow(c_q["v"]), "v_scale": grow(c_q["v_scale"]),
               "pos": c_q["pos"]}
        l_b, _ = m_bf.decode_step(p, toks[:, -1], c_b)
        l_q, nc = m_q8.decode_step(p, toks[:, -1], c_q)
        cos = float(jnp.sum(l_b * l_q)
                    / (jnp.linalg.norm(l_b) * jnp.linalg.norm(l_q)))
        assert cos > 0.999, cos
        assert nc["k"].dtype == jnp.int8

    def test_cache_specs_cover_int8_leaves(self):
        cfg = _cfg()
        m = build_model(cfg, ParallelConfig(kv_cache_int8=True))
        cache = jax.eval_shape(lambda: m.init_cache(2, 16))
        specs = m.cache_specs()
        assert set(cache.keys()) == set(specs.keys())


class TestSanitizeSharding:
    def _mesh(self):
        from repro.launch.mesh import make_mesh
        return make_mesh((1, 1), ("data", "model"))

    def test_drops_non_dividing_axis(self):
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = self._mesh()
        sh = NamedSharding(mesh, P("model", None))
        sds = jax.ShapeDtypeStruct((40, 8), jnp.float32)
        # model axis size 1 divides everything on a 1x1 mesh: kept
        out = sanitize_sharding(sh, sds)
        assert out.spec[0] == "model"

    def test_tuple_prefix_kept(self):
        import numpy as np_
        from jax.sharding import NamedSharding, PartitionSpec as P
        # synthetic mesh sizes via devices reshape not possible on 1 CPU;
        # emulate with the (1,1) mesh — exact divisibility logic is
        # exercised in the dry-run (512-device subprocess test)
        mesh = self._mesh()
        sh = NamedSharding(mesh, P(("data", "model"),))
        sds = jax.ShapeDtypeStruct((7,), jnp.float32)
        out = sanitize_sharding(sh, sds)
        assert out is not None
