"""Per-assigned-architecture smoke tests (REDUCED same-family configs).

One forward/train step on CPU per arch: asserts output shapes, finite
loss, finite gradients.  Full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) — also sanity-checked here via
eval_shape, which is allocation-free.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build_model
from repro.models.config import ParallelConfig, SHAPES, shape_applicable
from repro.train import OptConfig, build_train_step, init_opt_state

KEY = jax.random.PRNGKey(0)


def _smoke_batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family in ("encdec", "audio"):
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encdec.num_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (b, cfg.vlm.num_patches, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch):
        cfg = configs.get_reduced(arch)
        model = build_model(cfg, ParallelConfig(remat="none"))
        params = model.init_params(KEY)
        loss, metrics = model.loss_fn(params, _smoke_batch(cfg))
        assert loss.shape == ()
        assert np.isfinite(float(loss)), (arch, float(loss))

    def test_one_train_step_no_nans(self, arch):
        cfg = configs.get_reduced(arch)
        model = build_model(cfg, ParallelConfig(remat="none"))
        opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
        step_fn, _ = build_train_step(model, opt_cfg)
        params = model.init_params(KEY)
        opt_state = init_opt_state(params, opt_cfg)
        new_params, new_opt, metrics = jax.jit(step_fn)(
            params, opt_state, _smoke_batch(cfg))
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        assert float(metrics["grad_norm"]) > 0.0
        for leaf in jax.tree.leaves(new_params):
            assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch
        assert int(new_opt["step"]) == 1

    def test_decode_roundtrip(self, arch):
        cfg = configs.get_reduced(arch)
        model = build_model(cfg, ParallelConfig(remat="none"))
        params = model.init_params(KEY)
        batch = _smoke_batch(cfg)
        logits, cache = model.prefill(params, batch)
        assert logits.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        if cfg.family in ("dense", "moe", "vlm"):
            # prefill cache seq == prompt (+patches); rebuild at capacity
            cache2 = model.init_cache(2, 32)
            cache2 = {**cache2, "pos": cache["pos"]}
            logits2, cache3 = model.decode_step(
                params, jnp.ones((2,), jnp.int32), cache2)
        else:
            logits2, cache3 = model.decode_step(
                params, jnp.ones((2,), jnp.int32), cache)
        assert logits2.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits2, np.float32)).all()

    def test_full_config_eval_shape(self, arch):
        """Full-size config builds a parameter tree symbolically and its
        size matches the analytic param_count within tolerance."""
        cfg = configs.get_config(arch)
        model = build_model(cfg, ParallelConfig())
        tree = configs.params_specs(model)
        n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        est = cfg.param_count()
        assert abs(n - est) / est < 0.15, (arch, n, est)

    def test_shape_applicability(self, arch):
        cfg = configs.get_config(arch)
        long = SHAPES["long_500k"]
        assert shape_applicable(cfg, long) == cfg.subquadratic
        for name in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(cfg, SHAPES[name])
