"""HLO analyzer accounting: trip counts, dtype split, AR->RS pricing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.roofline.hlo_parser import HloModule, analyze_hlo


def _mesh4():
    return jax.make_mesh((4,), ("m",))


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >=4 devices (run under dryrun env)")
class TestMultiDevice:
    pass


class TestSingleDevice:
    def test_trip_count_exact(self):
        def f(x, ws):
            def body(h, w):
                return jnp.dot(h, w,
                               preferred_element_type=jnp.float32), None
            return jax.lax.scan(body, x, ws)[0]
        x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
        ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        a = analyze_hlo(compiled.as_text(), 1)
        assert a["flops"] == pytest.approx(7 * 2 * 256 ** 3, rel=0.02)

    def test_f32_share_tracked(self):
        def f(x):
            return x @ x
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        compiled = jax.jit(f).lower(x).compile()
        a = analyze_hlo(compiled.as_text(), 1)
        # no collectives on one device
        assert a["collectives"]["n_ops"] == 0
        assert a["collectives"]["total_wire_bytes"] == 0


class TestRsPricing:
    """Synthetic HLO text: AR consumed only by a slice-sized fusion is
    priced as reduce-scatter (the TPU ReduceScatterCreator pattern)."""

    HLO_RS = """
HloModule test

ENTRY %main (p0: f32[16,1024]) -> f32[16,64] {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%p0), replica_groups=[1,16]<=[16], to_apply=%add
  ROOT %fusion.1 = f32[16,64]{1,0} fusion(%all-reduce.1), kind=kLoop, calls=%fused
}
"""

    HLO_AR = """
HloModule test

ENTRY %main (p0: f32[16,1024]) -> f32[16,1024] {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(%p0), replica_groups=[1,16]<=[16], to_apply=%add
  ROOT %neg.1 = f32[16,1024]{1,0} negate(%all-reduce.1)
}
"""

    def test_sliced_consumer_priced_as_rs(self):
        a = analyze_hlo(self.HLO_RS, 16)
        assert "all-reduce(->rs)" in a["collectives"]["by_op"]
        bytes_full = 16 * 1024 * 4
        expect = bytes_full * 15 / 16            # RS, f32 halving applies
        got = a["collectives"]["by_op"]["all-reduce(->rs)"]["wire_bytes"]
        assert got == pytest.approx(expect, rel=1e-6)

    def test_full_consumer_stays_ar(self):
        a = analyze_hlo(self.HLO_AR, 16)
        assert "all-reduce" in a["collectives"]["by_op"]
        bytes_full = 16 * 1024 * 4
        expect = 2 * bytes_full * 15 / 16
        got = a["collectives"]["by_op"]["all-reduce"]["wire_bytes"]
        assert got == pytest.approx(expect, rel=1e-6)

    def test_f32_correction_halves_total(self):
        a = analyze_hlo(self.HLO_AR, 16)
        raw = a["collectives"]["raw_wire_bytes_cpu_f32"]
        corr = a["collectives"]["total_wire_bytes"]
        assert corr == pytest.approx(raw / 2, rel=1e-6)
