"""Cross-dialect conformance suite (ISSUE 4 satellite).

Every registered dialect × every registered op is a tier-1 test target:
under ``mode="auto"`` the registry must resolve a *contract-legal*
variant (HetGPU/arXiv:2506.15993: cross-vendor compatibility dies in
exactly the untested dialect corners), and that variant's interpret-mode
output must match the ``library`` reference within dtype tolerance — the
correctness claim the registry makes is checked where it is made, not
only on ``tpu-v5e``.

Property tests (hypothesis, optional via tests/_hypothesis_stub.py) pin
the fused-op cost accounting at randomized Eq. 1-legal shapes: a fused
lowering is strictly cheaper in HBM bytes than its unfused pair, and a
declared fallback is never cheaper than the variant it replaces (no
free-lunch fallbacks) — the arXiv:2208.11174 lesson that structural cost
models drift unless pinned by measurement-shaped tests.

Set ``REPRO_DIALECT=<name>`` to restrict the dialect axis (the CI matrix
runs a dedicated ``uisa-universal10`` job so the no-shuffle profile is
exercised on every PR).
"""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.core import (DIALECTS, ExecutionPolicy, IsaMode,
                        LoweringFallbackWarning, REGISTRY, get_dialect)
from repro.core.registry import cost_key
from repro.kernels import ops
from repro.kernels.fused import FUSED_OPS

settings.register_profile("conformance", max_examples=20, deadline=None)
settings.load_profile("conformance")

_ENV_DIALECT = os.environ.get("REPRO_DIALECT")
DIALECT_NAMES = [_ENV_DIALECT] if _ENV_DIALECT else sorted(DIALECTS)

KEY = jax.random.PRNGKey(17)

# ---------------------------------------------------------------------------
# One executable case per registered op (small shapes: the suite runs the
# full dialect × op matrix in interpret mode).  Deliberately ragged sizes —
# padding/masking is where foreign-dialect lowerings break first.
# ---------------------------------------------------------------------------

_k = jax.random.split(KEY, 10)
_X = jax.random.normal(_k[0], (16, 200), jnp.float32)
_W = jax.random.normal(_k[1], (200,), jnp.float32) + 1.0
_R = jax.random.normal(_k[2], (16, 200), jnp.float32)
_P = jax.random.normal(_k[3], (200, 96), jnp.float32)
_WCAT = jax.random.normal(_k[4], (200, 2 * 96), jnp.float32)
_A = jax.random.normal(_k[5], (96, 72), jnp.float32)
_B = jax.random.normal(_k[6], (72, 56), jnp.float32)
_RED = jax.random.normal(_k[7], (3000,), jnp.float32)
_HIST = jax.random.randint(_k[8], (2048,), 0, 32, jnp.int32)
_Q = jax.random.normal(_k[0], (1, 4, 96, 32), jnp.float32)
_KV_K = jnp.repeat(jax.random.normal(_k[1], (1, 2, 96, 32), jnp.float32),
                   2, axis=1)
_KV_V = jnp.repeat(jax.random.normal(_k[2], (1, 2, 96, 32), jnp.float32),
                   2, axis=1)
_WO = jax.random.normal(_k[9], (4 * 32, 80), jnp.float32)

CASES = {
    "gemm": lambda pol: ops.matmul(_A, _B, policy=pol),
    "reduction": lambda pol: ops.reduce_sum(_RED, policy=pol),
    "histogram": lambda pol: ops.histogram(_HIST, 32, policy=pol),
    "rmsnorm": lambda pol: ops.rmsnorm(_X, _W, policy=pol),
    "flash_attention": lambda pol: ops.flash_attention(
        _Q, _KV_K, _KV_V, causal=True, policy=pol),
    "rmsnorm_matmul": lambda pol: ops.fused_rmsnorm_matmul(
        _X, _W, _P, policy=pol),
    "add_rmsnorm": lambda pol: ops.fused_add_rmsnorm(
        _X, _R, _W, policy=pol),
    "flash_attention_matmul": lambda pol: ops.fused_flash_attention_matmul(
        _Q, _KV_K, _KV_V, _WO, causal=True, policy=pol),
    "rmsnorm_swiglu": lambda pol: ops.fused_rmsnorm_swiglu(
        _X, _W, _WCAT, policy=pol),
}


def test_every_registered_op_has_a_conformance_case():
    """A newly registered op cannot dodge the dialect matrix."""
    assert set(CASES) == set(REGISTRY.ops())


def _select_auto(op, dialect_name):
    pol = ExecutionPolicy(mode="auto", dialect=dialect_name)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", LoweringFallbackWarning)
        return REGISTRY.select(op, pol, shape=ops.PROBE_SHAPES[op])


@pytest.mark.parametrize("dialect_name", DIALECT_NAMES)
@pytest.mark.parametrize("op", sorted(CASES))
class TestConformance:
    def test_auto_resolves_contract_legal_variant(self, op, dialect_name):
        """auto must land on a variant whose contract validates on THIS
        dialect (library as the recorded escape), never on a variant
        pinned to a foreign target."""
        dialect = get_dialect(dialect_name)
        low = _select_auto(op, dialect_name)
        assert (REGISTRY.legal(op, low.mode, dialect)
                or low.mode is IsaMode.LIBRARY), (op, low.mode.value)
        if low.target is not None:
            assert low.target == dialect.name, \
                f"{op}: {low.target}-pinned variant leaked to {dialect.name}"
        if not dialect.has_lane_shuffle:
            assert low.mode is not IsaMode.ABSTRACT_SHUFFLE, op

    def test_auto_output_matches_library_reference(self, op, dialect_name):
        """The selected variant computes the same numbers as the jnp
        library row — the registry's correctness claim, checked on every
        dialect instead of spot-checked on the target."""
        run = CASES[op]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = run(ExecutionPolicy(mode="auto", dialect=dialect_name))
            want = run(ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                       dialect=dialect_name))
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Fused-op cost properties at randomized Eq. 1-legal shapes
# ---------------------------------------------------------------------------

_POW2_ROWS = (64, 128, 256, 512, 1024, 2048)
_POW2_DIMS = (128, 256, 512, 1024)
_SEQS = (256, 512, 1024, 2048)


def _fused_shape(op, rows, d, n, seq):
    if op == "rmsnorm_matmul":
        return dict(rows=rows, d=d, n=n)
    if op == "add_rmsnorm":
        return dict(rows=rows, d=d)
    if op == "rmsnorm_swiglu":
        return dict(rows=rows, d=d, f=n)
    if op == "flash_attention_matmul":
        return dict(b=1, h=4, sq=seq, skv=seq, d=64, n=n, causal=True)
    raise ValueError(op)


def _check_fused_cheaper_than_pair(rows, d, n, seq):
    for op in FUSED_OPS:
        shape = _fused_shape(op, rows, d, n, seq)
        for mode in REGISTRY.modes(op):
            cost = REGISTRY.structural_cost(op, mode, **shape)
            pair = cost["hbm_bytes_unfused_pair"]
            if mode == "library":
                # the library row IS the unfused pair
                assert cost["hbm_bytes"] == pair, (op, shape)
            else:
                assert cost["hbm_bytes"] < pair, (op, mode, shape)
                assert cost["hbm_bytes"] > 0, (op, mode, shape)


def _check_fallbacks_never_cheaper(rows, d, n, seq):
    for op in FUSED_OPS:
        shape = _fused_shape(op, rows, d, n, seq)
        for mode in REGISTRY.modes(op):
            fb = REGISTRY.fallback_for(op, mode)
            if fb is None:
                continue
            primary = cost_key(REGISTRY.structural_cost(op, mode, **shape),
                               IsaMode(mode))
            fallback = cost_key(
                REGISTRY.structural_cost(op, fb.to.value, **shape), fb.to)
            assert fallback >= primary, (op, mode, fb.to.value, shape)


@given(rows=st.sampled_from(_POW2_ROWS), d=st.sampled_from(_POW2_DIMS),
       n=st.sampled_from(_POW2_DIMS), seq=st.sampled_from(_SEQS))
def test_fused_cheaper_than_pair_property(rows, d, n, seq):
    """Randomized: every fused lowering's hbm_bytes is strictly below the
    unfused pair's sum — the round-trip saving cannot evaporate at any
    Eq. 1-legal shape."""
    _check_fused_cheaper_than_pair(rows, d, n, seq)


@given(rows=st.sampled_from(_POW2_ROWS), d=st.sampled_from(_POW2_DIMS),
       n=st.sampled_from(_POW2_DIMS), seq=st.sampled_from(_SEQS))
def test_declared_fallbacks_never_cheaper_property(rows, d, n, seq):
    """Randomized: a declared fallback costs at least as much as the
    variant it replaces (in cost_key order) — degrading is honest, never
    a secret win that would make the primary registration pointless."""
    _check_fallbacks_never_cheaper(rows, d, n, seq)


@pytest.mark.parametrize("rows,d,n,seq",
                         [(64, 128, 128, 256), (1024, 1024, 512, 1024),
                          (2048, 256, 1024, 2048)])
def test_fused_cost_properties_fixed_points(rows, d, n, seq):
    """Example-based floor under the hypothesis properties: the same
    invariants hold at fixed representative shapes even when hypothesis
    is not installed (the stub skips only the randomized versions)."""
    _check_fused_cheaper_than_pair(rows, d, n, seq)
    _check_fallbacks_never_cheaper(rows, d, n, seq)
