"""Cross-dialect conformance suite (ISSUE 4 satellite).

Every registered dialect × every registered op is a tier-1 test target:
under ``mode="auto"`` the registry must resolve a *contract-legal*
variant (HetGPU/arXiv:2506.15993: cross-vendor compatibility dies in
exactly the untested dialect corners), and that variant's interpret-mode
output must match the ``library`` reference within dtype tolerance — the
correctness claim the registry makes is checked where it is made, not
only on ``tpu-v5e``.

Property tests (hypothesis, optional via tests/_hypothesis_stub.py) pin
the fused-op cost accounting at randomized Eq. 1-legal shapes: a fused
lowering is strictly cheaper in HBM bytes than its unfused pair, and a
declared fallback is never cheaper than the variant it replaces (no
free-lunch fallbacks) — the arXiv:2208.11174 lesson that structural cost
models drift unless pinned by measurement-shaped tests.

Set ``REPRO_DIALECT=<name>`` to restrict the dialect axis (the CI matrix
runs a dedicated ``uisa-universal10`` job so the no-shuffle profile is
exercised on every PR).  Set ``REPRO_PRECISION=int8`` to run the same
matrix under an int8 ExecutionPolicy: every op with a registered
precision variant resolves to its quantized twin, and outputs are held
to the shared int8 tolerance policy (tests/conftest.py::TOLERANCES)
against the *f32* library reference — the dedicated CI job for the
quantized dialect axis.
"""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tolerance_for

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.core import (DIALECTS, ExecutionPolicy, IsaMode,
                        LoweringFallbackWarning, REGISTRY, get_dialect)
from repro.core.registry import cost_key
from repro.kernels import ops
from repro.kernels.fused import FUSED_OPS
from repro.serve import PagePool

settings.register_profile("conformance", max_examples=20, deadline=None)
settings.load_profile("conformance")

_ENV_DIALECT = os.environ.get("REPRO_DIALECT")
DIALECT_NAMES = [_ENV_DIALECT] if _ENV_DIALECT else sorted(DIALECTS)

#: the env-restricted precision axis (None = f32 policies, "int8" = the
#: quantized-dialect CI job); quantized ops are additionally always
#: covered by their own _q8 CASES rows below, on every run
_ENV_PRECISION = os.environ.get("REPRO_PRECISION")


def _with_precision(pol: ExecutionPolicy,
                    precision: str) -> ExecutionPolicy:
    return dataclasses.replace(pol, precision=precision)

KEY = jax.random.PRNGKey(17)

# ---------------------------------------------------------------------------
# One executable case per registered op (small shapes: the suite runs the
# full dialect × op matrix in interpret mode).  Deliberately ragged sizes —
# padding/masking is where foreign-dialect lowerings break first.
# ---------------------------------------------------------------------------

_k = jax.random.split(KEY, 10)
_X = jax.random.normal(_k[0], (16, 200), jnp.float32)
_W = jax.random.normal(_k[1], (200,), jnp.float32) + 1.0
_R = jax.random.normal(_k[2], (16, 200), jnp.float32)
_P = jax.random.normal(_k[3], (200, 96), jnp.float32)
_WCAT = jax.random.normal(_k[4], (200, 2 * 96), jnp.float32)
_A = jax.random.normal(_k[5], (96, 72), jnp.float32)
_B = jax.random.normal(_k[6], (72, 56), jnp.float32)
_RED = jax.random.normal(_k[7], (3000,), jnp.float32)
_HIST = jax.random.randint(_k[8], (2048,), 0, 32, jnp.int32)
_Q = jax.random.normal(_k[0], (1, 4, 96, 32), jnp.float32)
_KV_K = jnp.repeat(jax.random.normal(_k[1], (1, 2, 96, 32), jnp.float32),
                   2, axis=1)
_KV_V = jnp.repeat(jax.random.normal(_k[2], (1, 2, 96, 32), jnp.float32),
                   2, axis=1)
_WO = jax.random.normal(_k[9], (4 * 32, 80), jnp.float32)
# ssd_scan (ISSUE 8): L=96 is deliberately NOT a multiple of the chunk
# (64) — the matrix row exercises the padding path on every dialect
_SSD_KEYS = jax.random.split(_k[5], 5)
_SSD_X = jax.random.normal(_SSD_KEYS[0], (2, 96, 4, 16), jnp.float32)
_SSD_DT = jax.nn.softplus(jax.random.normal(
    _SSD_KEYS[1], (2, 96, 4), jnp.float32))
_SSD_A = -jnp.exp(jax.random.normal(_SSD_KEYS[2], (4,), jnp.float32) * 0.5)
_SSD_B = jax.random.normal(_SSD_KEYS[3], (2, 96, 2, 32), jnp.float32) * 0.3
_SSD_C = jax.random.normal(_SSD_KEYS[4], (2, 96, 2, 32), jnp.float32) * 0.3

CASES = {
    "gemm": lambda pol: ops.matmul(_A, _B, policy=pol),
    "reduction": lambda pol: ops.reduce_sum(_RED, policy=pol),
    "histogram": lambda pol: ops.histogram(_HIST, 32, policy=pol),
    "rmsnorm": lambda pol: ops.rmsnorm(_X, _W, policy=pol),
    "flash_attention": lambda pol: ops.flash_attention(
        _Q, _KV_K, _KV_V, causal=True, policy=pol),
    "rmsnorm_matmul": lambda pol: ops.fused_rmsnorm_matmul(
        _X, _W, _P, policy=pol),
    "add_rmsnorm": lambda pol: ops.fused_add_rmsnorm(
        _X, _R, _W, policy=pol),
    "flash_attention_matmul": lambda pol: ops.fused_flash_attention_matmul(
        _Q, _KV_K, _KV_V, _WO, causal=True, policy=pol),
    "rmsnorm_swiglu": lambda pol: ops.fused_rmsnorm_swiglu(
        _X, _W, _WCAT, policy=pol),
    # quantized variants (ISSUE 7): the same hot pairs under an int8
    # ExecutionPolicy — auto resolves the _q8 registry row, weights are
    # quantized on the fly, and the pass criterion is the shared int8
    # tolerance against the f32 library reference (conftest.TOLERANCES)
    "rmsnorm_matmul_q8": lambda pol: ops.fused_rmsnorm_matmul(
        _X, _W, _P, policy=_with_precision(pol, "int8")),
    "rmsnorm_swiglu_q8": lambda pol: ops.fused_rmsnorm_swiglu(
        _X, _W, _WCAT, policy=_with_precision(pol, "int8")),
    "flash_attention_matmul_q8":
        lambda pol: ops.fused_flash_attention_matmul(
            _Q, _KV_K, _KV_V, _WO, causal=True,
            policy=_with_precision(pol, "int8")),
    # the fused chunked SSD scan (ISSUE 8): one Pallas grid, state
    # carried in VMEM, vs the jnp chunk path as the library reference
    "ssd_scan": lambda pol: ops.fused_ssd_scan(
        _SSD_X, _SSD_DT, _SSD_A, _SSD_B, _SSD_C, chunk=64, policy=pol),
    # the batched decode recurrence (ISSUE 9): one serve-batch tick (the
    # [:, 0] token slices of the scan operands against the _SSD_H0 state)
    # vs the jnp einsum trio as the library reference; b=2 deliberately
    # does not divide the larger block_b candidates — the matrix row
    # exercises the batch-padding path on every dialect
    "ssd_decode": lambda pol: ops.fused_ssd_decode(
        _SSD_H0, _SSD_X[:, 0], _SSD_DT[:, 0], _SSD_A, _SSD_B[:, 0],
        _SSD_C[:, 0], policy=pol),
    # tensor-parallel twins (ISSUE 10): same impls as their bases (the
    # twin rows change the cost model, not the program — GSPMD owns the
    # physical sharding), dispatched by twin name through the generic
    # run_op helper so the matrix pins the twins' own contracts/fallbacks
    "gemm_tp": lambda pol: ops.run_op("gemm_tp", _A, _B, policy=pol),
    "rmsnorm_matmul_tp": lambda pol: ops.run_op(
        "rmsnorm_matmul_tp", _X, _W, _P, policy=pol),
    "rmsnorm_swiglu_tp": lambda pol: ops.run_op(
        "rmsnorm_swiglu_tp", _X, _W, _WCAT, policy=pol),
    "flash_attention_matmul_tp": lambda pol: ops.run_op(
        "flash_attention_matmul_tp", _Q, _KV_K, _KV_V, _WO, causal=True,
        policy=pol),
}

#: ops whose fused lowering is a *sequential* f32 accumulator rather
#: than a single reduction — they earn the wider f32_accum bounds
_TOL_BUCKETS = {"ssd_scan": "f32_accum"}


#: each op's f32 reference case and tolerance bucket: a _q8 row is held
#: to the int8 bounds against its BASE op's library output
def _reference_case(op):
    if op.endswith("_q8"):
        return CASES[op[:-3]], "int8"
    if op.endswith("_tp"):
        # the TP twin runs the base impl — the base library case is its
        # reference at the base op's tolerance bucket
        base = op[:-len("_tp")]
        bucket = "int8" if _ENV_PRECISION == "int8" \
            else _TOL_BUCKETS.get(base)
        return CASES[base], bucket
    if _ENV_PRECISION == "int8":
        return CASES[op], "int8"
    return CASES[op], _TOL_BUCKETS.get(op)


def test_every_registered_op_has_a_conformance_case():
    """A newly registered op cannot dodge the dialect matrix."""
    assert set(CASES) == set(REGISTRY.ops())


def _matrix_policy(mode, dialect_name):
    """The policy one matrix cell runs under: REPRO_PRECISION threads the
    env-restricted precision axis into every cell (the int8 CI job)."""
    pol = ExecutionPolicy(mode=mode, dialect=dialect_name)
    if _ENV_PRECISION:
        pol = _with_precision(pol, _ENV_PRECISION)
    return pol


def _select_auto(op, dialect_name):
    pol = _matrix_policy("auto", dialect_name)
    if op.endswith("_q8"):
        pol = _with_precision(pol, "int8")
        op = op[:-3]                  # select retargets base -> _q8
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", LoweringFallbackWarning)
        return REGISTRY.select(op, pol, shape=ops.PROBE_SHAPES[op])


@pytest.mark.parametrize("dialect_name", DIALECT_NAMES)
@pytest.mark.parametrize("op", sorted(CASES))
class TestConformance:
    def test_auto_resolves_contract_legal_variant(self, op, dialect_name):
        """auto must land on a variant whose contract validates on THIS
        dialect (library as the recorded escape), never on a variant
        pinned to a foreign target.  A _q8 matrix row additionally pins
        the precision retarget: an int8 policy must actually land on the
        quantized twin (not silently serve the f32 row)."""
        dialect = get_dialect(dialect_name)
        low = _select_auto(op, dialect_name)
        if op.endswith("_q8") and low.mode is not IsaMode.LIBRARY:
            assert low.op == op, \
                f"int8 policy resolved {low.op}, not the quantized twin"
        assert (REGISTRY.legal(low.op, low.mode, dialect)
                or low.mode is IsaMode.LIBRARY), (op, low.mode.value)
        if low.target is not None:
            assert low.target == dialect.name, \
                f"{op}: {low.target}-pinned variant leaked to {dialect.name}"
        if not dialect.has_lane_shuffle:
            assert low.mode is not IsaMode.ABSTRACT_SHUFFLE, op

    def test_auto_output_matches_library_reference(self, op, dialect_name):
        """The selected variant computes the same numbers as the **f32**
        jnp library row — the registry's correctness claim, checked on
        every dialect instead of spot-checked on the target.  Bounds come
        from the shared per-precision tolerance policy (conftest):
        quantized rows earn the int8 bounds, everything else the f32
        accumulation-order bounds."""
        run = CASES[op]
        ref_run, tol_bucket = _reference_case(op)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = run(_matrix_policy("auto", dialect_name))
            want = ref_run(ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                           dialect=dialect_name))
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       **tolerance_for(tol_bucket, ref=w))


# ---------------------------------------------------------------------------
# Paged decode shape of flash_attention_matmul (ISSUE 6): same op, new
# shape — k/v are page pools gathered through a block table with per-slot
# pos frontiers and dead-block skip.  Not a separate registry op, so it
# rides next to CASES rather than inside it; the dialect matrix still
# covers it in full.
# ---------------------------------------------------------------------------

_PG_PS, _PG_MAXP, _PG_POOL = 128, 2, 7      # lane-multiple page size:
_PG_KEYS = jax.random.split(_k[4], 4)        # legal for ALL modes
_PG_Q = jax.random.normal(_PG_KEYS[0], (2, 4, 1, 32), jnp.float32)
_PG_K = jax.random.normal(_PG_KEYS[1], (_PG_POOL, 2, _PG_PS, 32),
                          jnp.float32)
_PG_V = jax.random.normal(_PG_KEYS[2], (_PG_POOL, 2, _PG_PS, 32),
                          jnp.float32)
_PG_WO = jax.random.normal(_PG_KEYS[3], (4 * 32, 80), jnp.float32)
# slot 0: two live pages (non-contiguous ids); slot 1: second entry is
# the sentinel — its frontier stops inside page 0, exercising the skip
_PG_TBL = jnp.array([[4, 6], [1, _PG_POOL]], jnp.int32)
_PG_POS = jnp.array([200, 60], jnp.int32)


@pytest.mark.parametrize("dialect_name", DIALECT_NAMES)
class TestPagedDecodeConformance:
    def _run(self, pol):
        return ops.fused_flash_attention_matmul(
            _PG_Q, _PG_K, _PG_V, _PG_WO, pos=_PG_POS,
            block_tables=_PG_TBL, policy=pol)

    def test_paged_auto_matches_masked_softmax_library(self, dialect_name):
        """The paged decode shape resolves and computes the same numbers
        as the gather + masked-softmax jnp library row — on every
        dialect, including table gather, sentinel clamp, and dead-block
        skip."""
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = self._run(ExecutionPolicy(mode="auto",
                                            dialect=dialect_name))
            want = self._run(ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                             dialect=dialect_name))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_paged_int8_kv_matches_f32_library(self, dialect_name):
        """The int8 paged cache shape (ISSUE 7): pools quantized per
        (token, head) with scale pools riding the same block table, the
        kernel dequantizing gathered pages in VMEM.  Output must match
        the f32 pools through the f32 library row within the shared int8
        tolerance — on every dialect, including the sentinel/dead-block
        corners the f32 paged test pins."""
        from repro.models.attention import quantize_kv
        k_q, k_s = quantize_kv(_PG_K)
        v_q, v_s = quantize_kv(_PG_V)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = ops.fused_flash_attention_matmul(
                _PG_Q, k_q, v_q, _PG_WO, pos=_PG_POS,
                block_tables=_PG_TBL, k_scale=k_s, v_scale=v_s,
                policy=_with_precision(
                    ExecutionPolicy(mode="auto", dialect=dialect_name),
                    "int8"))
            want = self._run(ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                             dialect=dialect_name))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **tolerance_for("int8", ref=want))

    def test_paged_int8_cost_undercuts_f32_kv_stream(self, dialect_name):
        """The quantized variant's registered structural cost must carry
        the predicted kv-stream cut: int8 pages cost (d + 4)/page-token
        per direction against f32's 4d — at least 2x less for any d >= 8,
        and the full hbm_bytes undercuts the f32 row."""
        pol = _with_precision(
            ExecutionPolicy(mode="auto", dialect=dialect_name), "int8")
        shape = dict(b=2, h=4, sq=1, skv=_PG_MAXP * _PG_PS, d=32, n=80,
                     causal=False, page_size=_PG_PS, pages_occupied=4)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            low = REGISTRY.select("flash_attention_matmul", pol,
                                  shape=shape)
            base = REGISTRY.select(
                "flash_attention_matmul",
                ExecutionPolicy(mode="auto", dialect=dialect_name),
                shape=shape)
        qc = low.structural_cost(**shape)
        fc = base.structural_cost(**shape)
        assert qc["kv_precision"] == "int8"
        assert qc["kv_stream_bytes"] * 2 <= fc["kv_stream_bytes"], \
            (qc["kv_stream_bytes"], fc["kv_stream_bytes"])
        assert qc["hbm_bytes"] < fc["hbm_bytes"]

    def test_paged_cost_registered_for_resolved_mode(self, dialect_name):
        """Every dialect's auto-resolved variant carries the paged cost
        columns (page_size/pages_occupied), scaling with occupancy."""
        pol = ExecutionPolicy(mode="auto", dialect=dialect_name)
        shape = dict(b=2, h=4, sq=1, skv=_PG_MAXP * _PG_PS, d=32, n=80,
                     causal=False, page_size=_PG_PS)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            low = REGISTRY.select("flash_attention_matmul", pol,
                                  shape=dict(shape, pages_occupied=4))
        half = low.structural_cost(**dict(shape, pages_occupied=2))
        full = low.structural_cost(**dict(shape, pages_occupied=4))
        assert half["page_size"] == _PG_PS
        assert half["hbm_bytes"] < full["hbm_bytes"]
        assert half["blocks_visited"] < full["blocks_visited"]


# ---------------------------------------------------------------------------
# SSD scan corner shapes (ISSUE 8): the CASES row above covers the padding
# path under auto-vs-library; these pin the carried-state seam — a non-None
# initial_state must flow through the VMEM carry identically to the jnp
# chunk path's scan carry, and the emitted final state must be the decode
# seed on both paths.
# ---------------------------------------------------------------------------

_SSD_H0 = jax.random.normal(_SSD_KEYS[2], (2, 2, 2, 32, 16),
                            jnp.float32) * 0.5


@pytest.mark.parametrize("dialect_name", DIALECT_NAMES)
class TestSSDScanConformance:
    def _run(self, pol, **kw):
        return ops.fused_ssd_scan(_SSD_X, _SSD_DT, _SSD_A, _SSD_B,
                                  _SSD_C, policy=pol, **kw)

    def _pair(self, dialect_name, **kw):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = self._run(ExecutionPolicy(mode="auto",
                                            dialect=dialect_name), **kw)
            want = self._run(ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                             dialect=dialect_name), **kw)
        return got, want

    def test_initial_state_carries_through_vmem(self, dialect_name):
        """Prefill continuation: a non-None initial_state [B,G,Hg,N,P]
        seeds the VMEM state scratch and must produce the same (y,
        final_state) as the jnp scan carry — the chunked-prefill resume
        path on every dialect."""
        got, want = self._pair(dialect_name, initial_state=_SSD_H0,
                               chunk=64)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w),
                **tolerance_for("f32_accum", ref=w))

    def test_final_state_is_f32_decode_seed(self, dialect_name):
        """The emitted state is the decode cache seed: f32, shaped
        [B,G,Hg,N,P], regardless of the activation dtype."""
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            _, state = self._run(ExecutionPolicy(mode="auto",
                                                 dialect=dialect_name),
                                 chunk=64)
        assert state.dtype == jnp.float32
        assert state.shape == (2, 2, 2, 32, 16)

    def test_chunk_multiple_seq_matches_library(self, dialect_name):
        """The complement of the ragged CASES row: an exactly
        chunk-multiple sequence (no padding lane anywhere) still agrees
        with the library reference."""
        lx = _SSD_X[:, :64]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            got = ops.fused_ssd_scan(
                lx, _SSD_DT[:, :64], _SSD_A, _SSD_B[:, :64],
                _SSD_C[:, :64], chunk=32,
                policy=ExecutionPolicy(mode="auto", dialect=dialect_name))
            want = ops.fused_ssd_scan(
                lx, _SSD_DT[:, :64], _SSD_A, _SSD_B[:, :64],
                _SSD_C[:, :64], chunk=32,
                policy=ExecutionPolicy(mode=IsaMode.LIBRARY.value,
                                       dialect=dialect_name))
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w),
                **tolerance_for("f32_accum", ref=w))

    def test_auto_never_shuffles_on_no_shuffle_dialect(self, dialect_name):
        """The §VII.C seam: the decay prefix scan's cross-lane stage must
        resolve to the scratchpad ladder (not LANE_SHUFFLE) wherever the
        dialect lacks warp shuffles."""
        pol = ExecutionPolicy(mode="auto", dialect=dialect_name)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            low = REGISTRY.select("ssd_scan", pol,
                                  shape=ops.PROBE_SHAPES["ssd_scan"])
        if not get_dialect(dialect_name).has_lane_shuffle:
            assert low.mode is not IsaMode.ABSTRACT_SHUFFLE


@pytest.mark.parametrize("dialect_name", DIALECT_NAMES)
class TestSSDDecodeConformance:
    """ISSUE 9: the batched decode recurrence's corner shapes — the CASES
    row covers auto-vs-library at b=2; these pin the state seam and the
    §VII.C mode split of the C·h contraction."""

    def _run(self, pol, **kw):
        return ops.fused_ssd_decode(
            _SSD_H0, _SSD_X[:, 0], _SSD_DT[:, 0], _SSD_A, _SSD_B[:, 0],
            _SSD_C[:, 0], policy=pol, **kw)

    def test_updated_state_is_f32_decode_cache(self, dialect_name):
        """The emitted state re-enters the decode cache next tick: f32,
        shaped [B,G,Hg,N,P], regardless of the activation dtype — on
        every dialect's auto winner."""
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            state, y = self._run(ExecutionPolicy(mode="auto",
                                                 dialect=dialect_name))
        assert state.dtype == jnp.float32
        assert state.shape == _SSD_H0.shape
        assert y.shape == _SSD_X[:, 0].shape

    def test_explicit_block_b_matches_library(self, dialect_name):
        """A block_b that does NOT divide the batch (3 over b=2 caps to
        2; 1 runs one slot per program) still agrees with the jnp trio —
        the batch-padding lanes must contribute nothing."""
        for bb in (1, 3):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", LoweringFallbackWarning)
                got = self._run(ExecutionPolicy(mode="auto",
                                                dialect=dialect_name),
                                block_b=bb)
                want = self._run(ExecutionPolicy(
                    mode=IsaMode.LIBRARY.value, dialect=dialect_name))
            for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
                np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                           **tolerance_for(None, ref=w))

    def test_auto_never_shuffles_on_no_shuffle_dialect(self, dialect_name):
        """The §VII.C seam: the C·h cross-lane contraction must resolve
        to the scratchpad ladder (not LANE_SHUFFLE) wherever the dialect
        lacks warp shuffles."""
        pol = ExecutionPolicy(mode="auto", dialect=dialect_name)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            low = REGISTRY.select("ssd_decode", pol,
                                  shape=ops.PROBE_SHAPES["ssd_decode"])
        if not get_dialect(dialect_name).has_lane_shuffle:
            assert low.mode is not IsaMode.ABSTRACT_SHUFFLE


class TestPagePoolInvariants:
    """ISSUE 6 satellite: prefix-sharing refcount invariants — a page is
    freed only at refcount 0, and the copy-on-write discipline (fresh
    tail pages) never aliases a shared page."""

    def test_free_only_at_refcount_zero(self):
        pool = PagePool(num_pages=4, page_size=8)
        (pid,) = pool.alloc(1)
        pool.retain(pid)                       # two holders
        assert pool.refcount[pid] == 2
        pool.release(pid)                      # one left: NOT freed
        assert pool.refcount[pid] == 1
        assert pool.free_pages == 3
        pool.release(pid)                      # refcount 0: freed
        assert pid not in pool.refcount
        assert pool.free_pages == 4

    def test_prefix_index_cleared_when_page_freed(self):
        pool = PagePool(num_pages=2, page_size=4)
        (pid,) = pool.alloc(1)
        h = PagePool.prefix_hashes([1, 2, 3, 4], 4)[0]
        pool.publish_prefix(h, pid)
        assert pool.lookup_prefix(h) == pid
        pool.release(pid)
        assert pool.lookup_prefix(h) is None   # no dangling shared entry

    def test_chain_hash_requires_full_leading_match(self):
        """A chain hash folds in its predecessor: page 2 of [A,B] never
        collides with page 2 of [C,B], so a hit guarantees the whole
        leading path matches."""
        a = PagePool.prefix_hashes([1, 2, 3, 4], 2)
        b = PagePool.prefix_hashes([9, 9, 3, 4], 2)
        assert a[0] != b[0]
        assert a[1] != b[1]                    # same bytes, different chain
        assert a == PagePool.prefix_hashes([1, 2, 3, 4, 5], 2)

    def test_copy_on_write_never_aliases_shared_page(self):
        """Engine-level: two same-prompt admissions share full prefix
        pages but each owns a fresh tail — the only page decode ever
        writes.  (The engine caps sharing at reserve-1 pages, so even a
        prompt filling its whole reservation keeps an exclusive tail.)"""
        import jax as _jax
        from repro.models import build_model
        from repro.models.config import ModelConfig, ParallelConfig
        from repro.serve import BatchedEngine, Request, ServeConfig
        cfg = ModelConfig(name="t", family="dense", num_layers=1,
                          d_model=32, num_heads=2, num_kv_heads=1,
                          d_ff=64, vocab_size=64, dtype="float32")
        model = build_model(cfg, ParallelConfig(remat="none"))
        params = model.init_params(_jax.random.PRNGKey(3))
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=32, eos_id=-1, page_size=8))
        prompt = list(range(2, 18))            # 16 tokens = 2 full pages
        r0 = Request(rid=0, prompt=list(prompt), max_new_tokens=6)
        r1 = Request(rid=1, prompt=list(prompt), max_new_tokens=6)
        assert eng.admit([r0, r1]) == 2
        p0, p1 = eng._slot_pages
        assert p0[:2] == p1[:2]                # both full pages shared
        assert all(eng.pool.refcount[p] == 2 for p in p0[:2])
        tail0, tail1 = set(p0[2:]), set(p1[2:])
        assert tail0 and tail1 and tail0.isdisjoint(tail1)
        assert all(eng.pool.refcount[p] == 1 for p in tail0 | tail1)


# ---------------------------------------------------------------------------
# Fused-op cost properties at randomized Eq. 1-legal shapes
# ---------------------------------------------------------------------------

_POW2_ROWS = (64, 128, 256, 512, 1024, 2048)
_POW2_DIMS = (128, 256, 512, 1024)
_SEQS = (256, 512, 1024, 2048)


def _fused_shape(op, rows, d, n, seq):
    if op.endswith("_q8"):            # quantized twin: same shape space
        op = op[:-3]
    if op == "rmsnorm_matmul":
        return dict(rows=rows, d=d, n=n)
    if op == "add_rmsnorm":
        return dict(rows=rows, d=d)
    if op == "rmsnorm_swiglu":
        return dict(rows=rows, d=d, f=n)
    if op == "flash_attention_matmul":
        return dict(b=1, h=4, sq=seq, skv=seq, d=64, n=n, causal=True)
    if op == "ssd_scan":
        return dict(b=1, seq=seq, h=4, p=64, g=1, n=n)
    if op == "ssd_decode":
        return dict(b=8, h=4, p=64, g=1, n=n)
    raise ValueError(op)


def _check_fused_cheaper_than_pair(rows, d, n, seq):
    for op in FUSED_OPS:
        shape = _fused_shape(op, rows, d, n, seq)
        for mode in REGISTRY.modes(op):
            cost = REGISTRY.structural_cost(op, mode, **shape)
            pair = cost["hbm_bytes_unfused_pair"]
            if mode == "library":
                # the library row IS the unfused pair
                assert cost["hbm_bytes"] == pair, (op, shape)
            else:
                assert cost["hbm_bytes"] < pair, (op, mode, shape)
                assert cost["hbm_bytes"] > 0, (op, mode, shape)


def _check_fallbacks_never_cheaper(rows, d, n, seq):
    for op in FUSED_OPS:
        shape = _fused_shape(op, rows, d, n, seq)
        for mode in REGISTRY.modes(op):
            fb = REGISTRY.fallback_for(op, mode)
            if fb is None:
                continue
            primary = cost_key(REGISTRY.structural_cost(op, mode, **shape),
                               IsaMode(mode))
            fallback = cost_key(
                REGISTRY.structural_cost(op, fb.to.value, **shape), fb.to)
            assert fallback >= primary, (op, mode, fb.to.value, shape)


@given(rows=st.sampled_from(_POW2_ROWS), d=st.sampled_from(_POW2_DIMS),
       n=st.sampled_from(_POW2_DIMS), seq=st.sampled_from(_SEQS))
def test_fused_cheaper_than_pair_property(rows, d, n, seq):
    """Randomized: every fused lowering's hbm_bytes is strictly below the
    unfused pair's sum — the round-trip saving cannot evaporate at any
    Eq. 1-legal shape."""
    _check_fused_cheaper_than_pair(rows, d, n, seq)


@given(rows=st.sampled_from(_POW2_ROWS), d=st.sampled_from(_POW2_DIMS),
       n=st.sampled_from(_POW2_DIMS), seq=st.sampled_from(_SEQS))
def test_declared_fallbacks_never_cheaper_property(rows, d, n, seq):
    """Randomized: a declared fallback costs at least as much as the
    variant it replaces (in cost_key order) — degrading is honest, never
    a secret win that would make the primary registration pointless."""
    _check_fallbacks_never_cheaper(rows, d, n, seq)


@pytest.mark.parametrize("rows,d,n,seq",
                         [(1, 512, 512, 512),      # decode rows
                          (64, 128, 128, 256), (1024, 1024, 512, 1024)])
@pytest.mark.parametrize("base", ["rmsnorm_matmul", "rmsnorm_swiglu",
                                  "flash_attention_matmul"])
def test_quantized_weight_stream_undercuts_f32(base, rows, d, n, seq):
    """The acceptance bound of the quantized variants: at every mode and
    shape, the registered weight stream is at least 2x below the f32
    row's (int8 bytes + one f32 scale row vs f32 bytes), and total
    hbm_bytes strictly undercuts — the registry-level guarantee the
    bench ``--compare`` gate re-checks against emitted artifacts."""
    shape = _fused_shape(base, rows, d, n, seq)
    for mode in REGISTRY.modes(base + "_q8"):
        qc = REGISTRY.structural_cost(base + "_q8", mode, **shape)
        fc = REGISTRY.structural_cost(base, mode, **shape)
        assert qc["weight_precision"] == "int8"
        assert qc["weight_stream_bytes"] * 2 <= fc["weight_stream_bytes"], \
            (base, mode, qc["weight_stream_bytes"],
             fc["weight_stream_bytes"])
        assert qc["hbm_bytes"] < fc["hbm_bytes"], (base, mode)
        # the saving claimed against the unfused pair is the SAME saving
        # (fusion) — quantization moves both sides of the ledger equally
        assert (fc["hbm_bytes_unfused_pair"] - fc["hbm_bytes"]
                == qc["hbm_bytes_unfused_pair"] - qc["hbm_bytes"]), \
            (base, mode)


@pytest.mark.parametrize("rows,d,n,seq",
                         [(64, 128, 128, 256), (1024, 1024, 512, 1024),
                          (2048, 256, 1024, 2048)])
def test_fused_cost_properties_fixed_points(rows, d, n, seq):
    """Example-based floor under the hypothesis properties: the same
    invariants hold at fixed representative shapes even when hypothesis
    is not installed (the stub skips only the randomized versions)."""
    _check_fused_cheaper_than_pair(rows, d, n, seq)
    _check_fallbacks_never_cheaper(rows, d, n, seq)
