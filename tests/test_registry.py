"""Lowering registry + execution policy (ISSUE 2).

Covers: registration-time contract rejection, auto selection (shuffle
variant when the dialect has lane shuffle, scratch-tree otherwise, jnp
library when no portable lowering is legal), declared fallbacks replacing
silent mode rewrites, and policy threading through the model stack (same
outputs under abstract / native / library policies within tolerance).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionPolicy, IsaMode, KernelContract,
                        LoweringFallbackWarning, Primitive, REGISTRY,
                        TARGET, UISA_UNIVERSAL10, UnsupportedLowering,
                        use_policy)
from repro.core.primitives import ContractViolation
from repro.kernels import ops, ref
from repro.kernels.ops import PROBE_SHAPES as AUTO_SHAPES

KEY = jax.random.PRNGKey(3)


@pytest.fixture
def scratch_op():
    """A throwaway op name, always unregistered afterwards."""
    name = "test_scratch_op"
    yield name
    REGISTRY.unregister(name)


# ---------------------------------------------------------------------------
# Registration-time contract rejection
# ---------------------------------------------------------------------------


class TestRegistration:
    def test_out_of_budget_contract_rejected(self, scratch_op):
        bad = KernelContract(
            kernel=scratch_op, mode=IsaMode.ABSTRACT,
            primitives=frozenset({Primitive.LANE_SHUFFLE}))
        with pytest.raises(ContractViolation):
            REGISTRY.register(scratch_op, IsaMode.ABSTRACT,
                              lambda *a, **k: None, contract=bad)

    def test_contract_drift_rejected(self, scratch_op):
        other = KernelContract(
            kernel="some_other_op", mode=IsaMode.ABSTRACT,
            primitives=frozenset({Primitive.LOCKSTEP_GROUP}))
        with pytest.raises(ContractViolation):
            REGISTRY.register(scratch_op, IsaMode.ABSTRACT,
                              lambda *a, **k: None, contract=other)
        mode_drift = KernelContract(
            kernel=scratch_op, mode=IsaMode.NATIVE,
            primitives=frozenset(Primitive))
        with pytest.raises(ContractViolation):
            REGISTRY.register(scratch_op, IsaMode.ABSTRACT,
                              lambda *a, **k: None, contract=mode_drift)

    def test_non_library_requires_contract(self, scratch_op):
        with pytest.raises(ContractViolation):
            REGISTRY.register(scratch_op, IsaMode.ABSTRACT,
                              lambda *a, **k: None)

    def test_duplicate_registration_rejected(self, scratch_op):
        REGISTRY.register(scratch_op, IsaMode.LIBRARY,
                          lambda x, **k: x)
        with pytest.raises(ValueError):
            REGISTRY.register(scratch_op, IsaMode.LIBRARY,
                              lambda x, **k: x)

    def test_impl_must_accept_plan_dialect(self, scratch_op):
        """The dispatch layer injects plan_dialect= into every impl call;
        an impl that cannot take it fails at registration, not at first
        dispatch."""
        with pytest.raises(ContractViolation):
            REGISTRY.register(scratch_op, IsaMode.LIBRARY, lambda x: x)

    def test_all_kernels_registered(self):
        assert set(REGISTRY.ops()) >= {"gemm", "reduction", "histogram",
                                       "flash_attention", "rmsnorm"}
        # gemm has no shuffle variant — by registration, not by rewrite
        assert REGISTRY.modes("gemm") == ("abstract", "native", "library")
        for op in ("reduction", "rmsnorm", "histogram", "flash_attention"):
            assert REGISTRY.modes(op) == ("abstract", "abstract+shuffle",
                                          "native", "library")


# ---------------------------------------------------------------------------
# Auto selection (the Table V discipline as runtime behavior)
# ---------------------------------------------------------------------------


class TestAutoSelection:
    def test_shuffle_variant_when_dialect_has_lane_shuffle(self):
        assert TARGET.has_lane_shuffle
        pol = ExecutionPolicy(mode="auto", dialect=TARGET.name)
        for op in ("reduction", "rmsnorm", "histogram", "flash_attention"):
            low = REGISTRY.select(op, pol, shape=AUTO_SHAPES[op])
            assert low.mode is IsaMode.ABSTRACT_SHUFFLE, (op, low.mode)

    def test_scratch_tree_when_dialect_lacks_lane_shuffle(self):
        assert not UISA_UNIVERSAL10.has_lane_shuffle
        pol = ExecutionPolicy(mode="auto", dialect=UISA_UNIVERSAL10.name)
        for op in ("reduction", "rmsnorm", "histogram", "flash_attention"):
            low = REGISTRY.select(op, pol, shape=AUTO_SHAPES[op])
            assert low.mode is IsaMode.ABSTRACT, (op, low.mode)

    def test_auto_legal_everywhere(self):
        """Acceptance: an auto policy resolves a legal variant for every
        op on both the target and a no-shuffle dialect."""
        for dialect in (TARGET, UISA_UNIVERSAL10):
            pol = ExecutionPolicy(mode="auto", dialect=dialect.name)
            for op in REGISTRY.ops():
                low = REGISTRY.select(op, pol,
                                      shape=AUTO_SHAPES.get(op, {}))
                assert REGISTRY.legal(op, low.mode, dialect) \
                    or low.mode is IsaMode.LIBRARY, (op, low.mode)

    def test_library_fallback_when_no_portable_lowering(self, scratch_op):
        """Missing-primitive case: an op with only a shuffle lowering must
        fall back to the jnp reference on a no-shuffle dialect."""
        contract = KernelContract(
            kernel=scratch_op, mode=IsaMode.ABSTRACT_SHUFFLE,
            primitives=frozenset({Primitive.LOCKSTEP_GROUP,
                                  Primitive.LANE_SHUFFLE}))
        REGISTRY.register(scratch_op, IsaMode.ABSTRACT_SHUFFLE,
                          lambda x, **k: ("shuffle", x), contract=contract)
        REGISTRY.register(scratch_op, IsaMode.LIBRARY,
                          lambda x, **k: ("library", x))
        n0 = len(REGISTRY.fallback_events)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", LoweringFallbackWarning)
            low = REGISTRY.select(scratch_op, ExecutionPolicy(
                mode="auto", dialect=UISA_UNIVERSAL10.name))
        assert low.mode is IsaMode.LIBRARY
        ev = REGISTRY.fallback_events[n0]
        assert ev.op == scratch_op and ev.requested == "auto" \
            and ev.used == "library"

    def test_auto_matches_reference(self):
        x = jax.random.normal(KEY, (3000,), jnp.float32)
        got = ops.reduce_sum(x, policy=ExecutionPolicy(mode="auto"))
        np.testing.assert_allclose(got, ref.reduce_sum(x), rtol=1e-5,
                                   atol=1e-4)


# ---------------------------------------------------------------------------
# Declared fallbacks (the gemm abstract+shuffle satellite)
# ---------------------------------------------------------------------------


class TestDeclaredFallback:
    def test_gemm_shuffle_request_is_declared_warned_recorded(self):
        ka, kb = jax.random.split(KEY)
        a = jax.random.normal(ka, (64, 32), jnp.float32)
        b = jax.random.normal(kb, (32, 48), jnp.float32)
        n0 = len(REGISTRY.fallback_events)
        with pytest.warns(LoweringFallbackWarning):
            got = ops.matmul(a, b, mode="abstract+shuffle")
        np.testing.assert_allclose(got, ref.gemm(a, b), rtol=1e-4,
                                   atol=1e-4)
        ev = REGISTRY.fallback_events[n0]
        assert ev.op == "gemm"
        assert ev.requested == "abstract+shuffle" and ev.used == "abstract"

    def test_undeclared_illegal_mode_raises(self):
        # shuffle reduction on a no-shuffle dialect: no declared fallback
        pol = ExecutionPolicy(mode="abstract+shuffle",
                              dialect=UISA_UNIVERSAL10.name)
        with pytest.raises(UnsupportedLowering):
            REGISTRY.select("reduction", pol, shape=AUTO_SHAPES["reduction"])

    def test_unknown_mode_rejected(self):
        with pytest.raises(ValueError):
            ExecutionPolicy(mode="warp_specialized")
        a = jnp.ones((8, 8))
        with pytest.raises(ValueError):
            ops.matmul(a, a, mode="warp_specialized")


# ---------------------------------------------------------------------------
# Policy threading through the model stack
# ---------------------------------------------------------------------------


def _tiny_model(isa_mode=None):
    from repro.models.config import ModelConfig, ParallelConfig
    from repro.models.transformer import TransformerLM
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=128,
                      qk_norm=True, dtype="float32")
    par = ParallelConfig(remat="none", isa_mode=isa_mode)
    return TransformerLM(cfg, par)


class TestPolicyThreading:
    def test_model_outputs_agree_across_policies(self):
        """abstract vs native policies: every norm hot spot lowers through
        a different kernel variant yet the model output is unchanged."""
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % 128,
                 "labels": jnp.arange(32).reshape(2, 16) % 128}
        ref_model = _tiny_model(None)      # seed default: library norms
        params = ref_model.init_params(jax.random.PRNGKey(0))
        want, _ = ref_model.loss_fn(params, batch)
        for isa_mode in ("abstract", "native"):
            model = _tiny_model(isa_mode)
            assert model.policy.mode == isa_mode
            got, _ = model.loss_fn(params, batch)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_no_literal_modes_above_kernels(self):
        """Call sites above repro/kernels thread policies, not strings."""
        import pathlib
        root = pathlib.Path(__file__).resolve().parent.parent \
            / "src" / "repro"
        offenders = []
        for sub in ("models", "train", "serve", "launch", "data",
                    "parallel", "checkpoint"):
            for path in (root / sub).rglob("*.py"):
                if path.name == "config.py":
                    # ParallelConfig.execution_policy IS the one
                    # resolution point where mode literals are decided
                    continue
                text = path.read_text()
                for i, line in enumerate(text.splitlines(), 1):
                    if "mode=\"native\"" in line or "mode='native'" in line \
                            or "mode=\"abstract" in line:
                        offenders.append(f"{path}:{i}: {line.strip()}")
        assert not offenders, offenders

    def test_with_policy_and_ambient_override(self):
        model = _tiny_model(None)
        lib = model.policy
        assert lib.mode == "library" and lib.kernel_mode == "native"
        m2 = model.with_policy(ExecutionPolicy(mode="abstract"))
        assert m2.policy.mode == "abstract"
        assert model.policy.mode == "library"      # original untouched
        # ambient use_policy reaches common.rmsnorm when no explicit policy
        from repro.models import common
        x = jax.random.normal(KEY, (4, 128), jnp.float32)
        w = jnp.ones((128,), jnp.float32)
        with use_policy(ExecutionPolicy(mode="abstract")):
            got = common.rmsnorm(x, w)
        np.testing.assert_allclose(got, ref.rmsnorm(x, w), rtol=1e-5,
                                   atol=1e-5)

    def test_engine_accepts_policy(self):
        from repro.serve.engine import BatchedEngine, Request, ServeConfig
        model = _tiny_model(None)
        params = model.init_params(jax.random.PRNGKey(1))
        eng = BatchedEngine(model, params,
                            ServeConfig(batch_slots=2, max_seq_len=32,
                                        max_new_tokens=4),
                            policy=ExecutionPolicy(mode="library"))
        assert eng.policy.mode == "library"
        done = eng.run([Request(rid=0, prompt=[3, 5, 7],
                                max_new_tokens=4)])
        assert done[0].generated


# ---------------------------------------------------------------------------
# Contract legality across all registered dialects (CI drift guard)
# ---------------------------------------------------------------------------


class TestCrossDialectLegality:
    def test_validate_contracts_script(self):
        """The cross-dialect legality/auto-resolvability check lives ONCE,
        in scripts/validate_contracts.py (the CI step); this test runs it
        so local pytest and CI cannot drift apart."""
        import pathlib
        import runpy
        script = pathlib.Path(__file__).resolve().parent.parent \
            / "scripts" / "validate_contracts.py"
        mod = runpy.run_path(str(script))
        assert mod["main"]() == 0
