"""Real-mesh checks for the collective cost model and the qkv sharding
rule (ISSUE 10).  These need more than one device, so a single
subprocess probe runs under ``REPRO_SIM_DEVICES=4`` (the hostdev helper
installs ``--xla_force_host_platform_device_count`` before jax wakes
up) and reports JSON; the tests here pin its numbers:

- ``ambient_mesh_axes`` falls back to an *entered* ``jax.sharding.Mesh``
  (not just the contextvar), so ``"auto"`` retargets to the TP twin
  inside a plain ``with mesh:`` block.
- Acceptance: ``roofline.analysis.parse_collectives`` on a really
  lowered TP program reports exactly the wire bytes
  ``core.dialect.collective_cost`` models (``collective_bytes``) — the
  ring formulas agree on both the column-parallel all-gather and the
  row-parallel all-reduce, payload for payload.
- The ``qkv_heads`` rule is layout-neutral: prefill logits with the
  persisted [wq|wk|wv] concat sharded over the model axis match the
  meshless reference; when a segment's head count does not divide the
  axis the rule replicates instead (never a wrong answer).
"""
import json
import os
import subprocess
import sys

import pytest

import repro
from repro.core.dialect import collective_cost, get_dialect

M, K, N = 128, 512, 1024
ITEM = 4                                   # float32

_PROBE = """
import json
from repro.launch.hostdev import ensure_host_devices
installed = ensure_host_devices()
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

out = {"installed": installed, "n_devices": jax.device_count()}

from repro.core.registry import (AUTO_POLICY, REGISTRY, ambient_mesh_axes,
                                 tp_axis_size)
from repro.kernels import ops  # noqa: F401  (installs every variant)
from repro.roofline.analysis import parse_collectives

mesh4 = jax.make_mesh((4,), ("model",))
out["ambient_no_mesh"] = ambient_mesh_axes()
with mesh4:
    out["ambient"] = ambient_mesh_axes()
    out["tp"] = tp_axis_size()
    out["auto_kernel"] = REGISTRY.select(
        "gemm", AUTO_POLICY,
        shape=dict(m=128, n=4096, k=4096)).contract.kernel
out["auto_kernel_no_mesh"] = REGISTRY.select(
    "gemm", AUTO_POLICY, shape=dict(m=128, n=4096, k=4096)).contract.kernel

# --- the two TP matmul strategies the twins model, really lowered ---
M, K, N = __M__, __K__, __N__
x = jnp.ones((M, K), jnp.float32)
w = jnp.ones((K, N), jnp.float32)

col = shard_map(                  # column-parallel: all-gather the output
    lambda x, w: jax.lax.all_gather(x @ w, "model", axis=1, tiled=True),
    mesh=mesh4, in_specs=(P(None, None), P(None, "model")),
    out_specs=P(None, None), check_rep=False)
out["col"] = parse_collectives(
    jax.jit(col).lower(x, w).compile().as_text(), 4)

row = shard_map(                  # row-parallel: all-reduce the partials
    lambda x, w: jax.lax.psum(x @ w, "model"),
    mesh=mesh4, in_specs=(P(None, "model"), P("model", None)),
    out_specs=P(None, None), check_rep=False)
out["row"] = parse_collectives(
    jax.jit(row).lower(x, w).compile().as_text(), 4)

# --- qkv_heads layout equivalence ---
from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig
from repro.launch.mesh import make_ctx, make_mesh
from repro.parallel.sharding import sanitize_tree, tree_shardings

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                  dtype="float32")
par = ParallelConfig(remat="none")
ref_model = build_model(cfg, par)
params = ref_model.init_params(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 2, cfg.vocab_size)
ref_logits = np.asarray(ref_model.prefill(params, {"tokens": toks})[0])

for shape in [(2, 2), (1, 4)]:
    mesh = make_mesh(shape, ("data", "model"))
    ctx = make_ctx(mesh, par, cfg)
    t = shape[1]
    out[f"qkv_shardable_{t}way"] = ctx.qkv_heads_shardable
    out[f"qkv_spec_{t}way"] = str(ctx.spec(("embed", "qkv_heads")))
    model_tp = build_model(cfg, par, ctx)
    sh = sanitize_tree(tree_shardings(ctx, model_tp.param_specs()), params)
    p_sh = jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        params, sh,
        is_leaf=lambda v: v is None or not isinstance(v, (dict, list)))
    with mesh:
        lg = np.asarray(model_tp.prefill(p_sh, {"tokens": toks})[0])
    out[f"qkv_maxdiff_{t}way"] = float(np.abs(lg - ref_logits).max())

print("PROBE_JSON " + json.dumps(out))
""".replace("__M__", str(M)).replace("__K__", str(K)) \
    .replace("__N__", str(N))


@pytest.fixture(scope="module")
def probe(tmp_path_factory):
    """One 4-device subprocess; every test reads its JSON report."""
    script = tmp_path_factory.mktemp("mesh_probe") / "probe.py"
    script.write_text(_PROBE)
    env = dict(os.environ)
    env["REPRO_SIM_DEVICES"] = "4"
    env["PYTHONPATH"] = os.path.dirname(repro.__path__[0])
    res = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines()
            if l.startswith("PROBE_JSON ")][-1]
    return json.loads(line[len("PROBE_JSON "):])


class TestAmbientMesh:
    def test_hostdev_installs_four_devices(self, probe):
        assert probe["installed"] == 4 and probe["n_devices"] == 4

    def test_entered_mesh_is_the_ambient_fallback(self, probe):
        """No contextvar set: a plain ``with mesh:`` block is enough for
        the registry to see the axes (and nothing leaks outside it)."""
        assert probe["ambient_no_mesh"] == {}
        assert probe["ambient"] == {"model": 4}
        assert probe["tp"] == 4

    def test_auto_retargets_inside_real_mesh_context(self, probe):
        """Tentpole, end to end: the same select() call answers the TP
        twin inside the mesh and the replicated base outside it."""
        assert probe["auto_kernel"] == "gemm_tp"
        assert probe["auto_kernel_no_mesh"] == "gemm"


class TestParsedVsModeledCollectives:
    """Acceptance: parse_collectives on the lowered program reports the
    bytes collective_cost models — exactly, not within tolerance: both
    sides implement the same ring formulas on the same payload."""

    def test_column_parallel_all_gather_bytes_match(self, probe):
        recs = [r for r in probe["col"] if r["op"] == "all-gather"]
        assert len(recs) == 1
        modeled = collective_cost("all_gather", M * N * ITEM, 4,
                                  get_dialect("tpu-v5e"))
        assert int(recs[0]["wire_bytes"]) == modeled.wire_bytes
        assert int(recs[0]["result_bytes"]) == modeled.payload_bytes
        assert recs[0]["group_size"] == modeled.group

    def test_row_parallel_all_reduce_bytes_match(self, probe):
        recs = [r for r in probe["row"] if r["op"] == "all-reduce"]
        assert len(recs) == 1
        modeled = collective_cost("all_reduce", M * N * ITEM, 4,
                                  get_dialect("tpu-v5e"))
        assert int(recs[0]["wire_bytes"]) == modeled.wire_bytes
        assert int(recs[0]["result_bytes"]) == modeled.payload_bytes

    def test_no_stray_collectives(self, probe):
        """Each strategy lowers to exactly its one modeled collective —
        the cost dicts carry one term because the programs do."""
        assert len(probe["col"]) == 1 and len(probe["row"]) == 1


class TestQkvHeadsRule:
    def test_divisible_heads_shard_over_model(self, probe):
        assert probe["qkv_shardable_2way"] is True
        assert probe["qkv_spec_2way"] == "PartitionSpec('data', 'model')"

    def test_non_divisible_heads_replicate(self, probe):
        """4 heads / 2 KV heads on a 4-way axis: a shard boundary would
        cut across the q/k/v seams, so the rule replicates."""
        assert probe["qkv_shardable_4way"] is False
        assert probe["qkv_spec_4way"] == "PartitionSpec('data', None)"

    @pytest.mark.parametrize("t", [2, 4])
    def test_layout_neutral_logits(self, probe, t):
        """Sharded or replicated, the persisted concat's prefill logits
        match the meshless reference."""
        assert probe[f"qkv_maxdiff_{t}way"] < 1e-4
