"""Per-kernel shape/dtype sweeps: every Pallas variant (interpret=True)
allclose against the ref.py jnp oracle — the paper's Table V kernels plus
the framework hot-spots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref

KEY = jax.random.PRNGKey(42)


def keys(n):
    return jax.random.split(KEY, n)


# ---------------------------------------------------------------------------
# GEMM (Table V row 1)
# ---------------------------------------------------------------------------


GEMM_SHAPES = [(128, 128, 128), (256, 512, 128), (384, 128, 640),
               (100, 130, 50), (1, 128, 257), (512, 512, 512)]


class TestGemm:
    @pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
    @pytest.mark.parametrize("mode", ["abstract", "native", "library"])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, m, k, n, mode, dtype):
        ka, kb = keys(2)
        a = jax.random.normal(ka, (m, k), dtype)
        b = jax.random.normal(kb, (k, n), dtype)
        got = ops.matmul(a, b, mode=mode)
        want = ref.gemm(a, b)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(got, want, rtol=tol, atol=tol)

    def test_abstract_blocks_ignore_mxu_query(self):
        from repro.kernels.gemm import abstract_block_shape, native_block_shape
        ab = abstract_block_shape()
        nb = native_block_shape()
        assert ab[0] == ab[1] == ab[2]       # square, budget-derived
        assert nb[0] % 128 == 0 and nb[2] % 128 == 0

    def test_structural_cost_reports_traffic(self):
        from repro.kernels.gemm import structural_cost
        c_abs = structural_cost(4096, 4096, 4096, "abstract")
        c_nat = structural_cost(4096, 4096, 4096, "native")
        assert c_nat["mxu_aligned"]
        assert c_abs["flops"] == c_nat["flops"] == 2 * 4096 ** 3


# ---------------------------------------------------------------------------
# Reduction (Table V row 2 — the shuffle-insight kernel)
# ---------------------------------------------------------------------------


class TestReduction:
    @pytest.mark.parametrize("n", [128, 4096, 65536, 1 << 18, 999, 70001])
    @pytest.mark.parametrize(
        "mode", ["abstract", "abstract+shuffle", "native", "library"])
    def test_matches_oracle(self, n, mode):
        x = jax.random.normal(KEY, (n,), jnp.float32)
        got = ops.reduce_sum(x, mode=mode)
        want = ref.reduce_sum(x)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)

    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.int32])
    def test_dtype_sweep(self, dtype):
        if dtype == jnp.int32:
            x = jax.random.randint(KEY, (10000,), -5, 5, dtype)
        else:
            x = jax.random.normal(KEY, (10000,), dtype)
        got = ops.reduce_sum(x, mode="abstract+shuffle")
        np.testing.assert_allclose(got, ref.reduce_sum(x), rtol=1e-3,
                                   atol=1e-2)

    def test_shuffle_eliminates_scratch_roundtrips(self):
        """§VII.C mechanism: abstract pays log2(W) scratchpad round-trips;
        shuffle pays zero."""
        from repro.kernels.reduction import structural_cost
        c_abs = structural_cost(1 << 24, "abstract")
        c_shf = structural_cost(1 << 24, "abstract+shuffle")
        assert c_abs["scratch_round_trips_per_block"] == 7   # log2(128)
        assert c_shf["scratch_round_trips_per_block"] == 0
        assert c_shf["lane_shuffles_per_block"] == 7
        assert c_abs["scratch_bytes_total"] > 0
        assert c_shf["scratch_bytes_total"] == 0
        # identical HBM traffic: the *only* delta is the scratch traffic
        assert c_abs["hbm_bytes"] == c_shf["hbm_bytes"]


# ---------------------------------------------------------------------------
# Histogram (Table V row 3 — atomics divergence)
# ---------------------------------------------------------------------------


class TestHistogram:
    @pytest.mark.parametrize("n", [4096, 50000, 1 << 17])
    @pytest.mark.parametrize("bins", [128, 256])
    @pytest.mark.parametrize(
        "mode", ["abstract", "abstract+shuffle", "native", "library"])
    def test_matches_oracle(self, n, bins, mode):
        v = jax.random.randint(KEY, (n,), 0, bins, jnp.int32)
        got = ops.histogram(v, bins, mode=mode)
        want = ref.histogram(v, bins)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_out_of_range_clipped(self):
        v = jnp.array([-5, 0, 255, 300], jnp.int32)
        got = ops.histogram(v, 256, mode="abstract")
        want = ref.histogram(v, 256)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_counts_sum_to_n(self):
        n = 33333
        v = jax.random.randint(KEY, (n,), 0, 256, jnp.int32)
        for mode in ("abstract", "native"):
            assert int(jnp.sum(ops.histogram(v, 256, mode=mode))) == n

    def test_native_privatizes_through_mxu(self):
        from repro.kernels.histogram import structural_cost
        c_nat = structural_cost(1 << 24, 256, "native")
        c_abs = structural_cost(1 << 24, 256, "abstract")
        assert c_nat["private_histograms_per_block"] > 1
        assert c_abs["private_histograms_per_block"] == 1
        assert c_nat["mxu_routed"] and not c_abs["mxu_routed"]
        assert c_nat["atomic_free"] and c_abs["atomic_free"]


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------


ATTN_SHAPES = [
    # (b, h, hkv, sq, skv, d, causal)
    (1, 4, 4, 128, 128, 64, True),
    (2, 8, 2, 256, 256, 64, True),       # GQA
    (1, 4, 1, 128, 384, 128, True),      # MQA + cache offset
    (1, 2, 2, 200, 200, 64, True),       # ragged
    (2, 4, 4, 128, 128, 64, False),
]


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,hkv,sq,skv,d,causal", ATTN_SHAPES)
    @pytest.mark.parametrize("mode", ["abstract", "abstract+shuffle",
                                      "native"])
    def test_matches_oracle(self, b, h, hkv, sq, skv, d, causal, mode):
        kq, kk, kv = keys(3)
        q = jax.random.normal(kq, (b, h, sq, d), jnp.float32)
        k = jax.random.normal(kk, (b, hkv, skv, d), jnp.float32)
        v = jax.random.normal(kv, (b, hkv, skv, d), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=causal, mode=mode,
                                  block_q=128, block_kv=128)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        kq, kk, kv = keys(3)
        q = jax.random.normal(kq, (1, 4, 128, 64), jnp.bfloat16)
        k = jax.random.normal(kk, (1, 4, 128, 64), jnp.bfloat16)
        v = jax.random.normal(kv, (1, 4, 128, 64), jnp.bfloat16)
        got = ops.flash_attention(q, k, v, mode="native")
        want = ref.attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_block_skip_saves_half_the_blocks(self):
        from repro.kernels.attention import structural_cost
        c_nat = structural_cost(1, 8, 4096, 4096, 128, True, "native")
        c_abs = structural_cost(1, 8, 4096, 4096, 128, True, "abstract")
        assert c_abs["skip_fraction"] == 0.0
        assert 0.35 < c_nat["skip_fraction"] < 0.5   # ~upper triangle
        assert c_nat["flops"] < c_abs["flops"]


# ---------------------------------------------------------------------------
# RMSNorm (fused-epilogue example)
# ---------------------------------------------------------------------------


class TestRmsnorm:
    @pytest.mark.parametrize("shape", [(4, 128), (2, 7, 256), (64, 512)])
    @pytest.mark.parametrize(
        "mode", ["abstract", "abstract+shuffle", "native", "library"])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, mode, dtype):
        kx, kw = keys(2)
        x = jax.random.normal(kx, shape, dtype)
        w = jax.random.normal(kw, (shape[-1],), dtype) + 1.0
        got = ops.rmsnorm(x, w, mode=mode)
        want = ref.rmsnorm(x, w)
        tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=tol, atol=tol)
