"""No-op hypothesis shim (optional dev dep — see requirements-dev.txt).

When ``hypothesis`` is not installed, test modules fall back to this shim
so that *only* the property tests skip; every example-based test in the
same module still collects and runs.  The shim mirrors exactly the API
surface the test suite uses: ``given``, ``settings`` (as decorator and as
profile registry), and the ``strategies`` namespace (whose strategy
constructors are evaluated at decoration time, hence must exist).
"""
import pytest


class _Strategies:
    def __getattr__(self, name):
        return lambda *args, **kwargs: None


st = _Strategies()


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -r "
                   "requirements-dev.txt)")(fn)
    return deco


class settings:
    def __init__(self, *_args, **_kwargs):
        pass

    def __call__(self, fn):
        return fn

    @staticmethod
    def register_profile(*_args, **_kwargs):
        pass

    @staticmethod
    def load_profile(*_args, **_kwargs):
        pass
