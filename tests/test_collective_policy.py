"""Mesh-sensitive auto selection (ISSUE 10).

Pins the tentpole acceptance criterion: the same op at the same shape
selects a *different* lowering under two mesh configurations — TP-fused
on a small ring (sharded weight streams beat the all-gather), replicated
on a large one (more hops, thinner shards) — with both costs recomputed
by hand from the dialect's interconnect profile, not by trusting the
cost functions under test.
"""
import math

import pytest

from repro.core.dialect import (NO_INTERCONNECT_BYTES, TARGET,
                                collective_cost, get_dialect)
from repro.core.registry import (AUTO_POLICY, REGISTRY, ExecutionPolicy,
                                 ambient_mesh_axes, cost_key,
                                 tp_axis_size, use_mesh_axes)
from repro.kernels import ops  # noqa: F401  (installs every variant)
from repro.kernels.collective import TP_COSTS

# the decode-regime projection shape the crossover is pinned at: small
# row count (a serve batch), large contraction/output dims — the regime
# where the weight stream dominates and TP has something to save
SHAPE = dict(m=128, n=4096, k=4096)
SMALL_TP, LARGE_TP = 4, 64


# ---------------------------------------------------------------------------
# ambient mesh plumbing
# ---------------------------------------------------------------------------

def test_ambient_mesh_axes_default_empty():
    assert ambient_mesh_axes() == {}
    assert tp_axis_size() == 1


def test_use_mesh_axes_scopes_the_axis():
    with use_mesh_axes({"data": 2, "model": 8}):
        assert tp_axis_size() == 8
        with use_mesh_axes({"model": 4}):
            assert tp_axis_size() == 4
        assert tp_axis_size() == 8
    assert tp_axis_size() == 1


# ---------------------------------------------------------------------------
# the collective cost model itself, recomputed by hand
# ---------------------------------------------------------------------------

def test_ring_all_gather_terms_by_hand():
    """wire = S·(G-1)/G, hops = G-1, HBM-equivalent = wire·(hbm/link)
    + hops·latency·hbm — recomputed from the dialect constants."""
    dialect = TARGET  # tpu-v5e
    link = dialect.interconnect
    payload, group = 2_097_152, 4
    cc = collective_cost("all_gather", payload, group, dialect)
    wire = payload * (group - 1) // group
    assert cc.wire_bytes == wire
    assert cc.hops == group - 1
    expected = (wire * dialect.hbm_bandwidth / link.link_bandwidth
                + cc.hops * link.hop_latency_s * dialect.hbm_bandwidth)
    assert cc.hbm_equiv_bytes == int(math.ceil(expected))


def test_ring_all_reduce_doubles_the_wire():
    dialect = TARGET
    cc = collective_cost("all_reduce", 1 << 20, 8, dialect)
    assert cc.wire_bytes == 2 * (1 << 20) * 7 // 8
    assert cc.hops == 2 * 7


def test_group_of_one_is_free():
    cc = collective_cost("all_gather", 1 << 30, 1, TARGET)
    assert cc.wire_bytes == 0 and cc.hops == 0
    assert cc.hbm_equiv_bytes == 0


def test_no_interconnect_dialect_prices_collectives_prohibitively():
    """apple-g13 declares no interconnect: a TP twin can never win."""
    g13 = get_dialect("apple-g13")
    assert g13.interconnect is None
    cc = collective_cost("all_gather", 4096, 4, g13)
    assert cc.hbm_equiv_bytes == NO_INTERCONNECT_BYTES


# ---------------------------------------------------------------------------
# the crossover, recomputed by hand
# ---------------------------------------------------------------------------

def _hand_costs(tp):
    """Replicated-vs-TP hbm+collective totals for the abstract gemm row
    at SHAPE, from first principles (tile model + ring model)."""
    m, n, k = SHAPE["m"], SHAPE["n"], SHAPE["k"]
    base = REGISTRY.structural_cost("gemm", "abstract", **SHAPE)
    bm = base["block"][0]
    rereads = max(1, -(-m // bm))
    itemsize = 4
    ws_full = k * n * itemsize * rereads
    ws_shard = k * (-(-n // tp)) * itemsize * rereads
    tp_hbm = base["hbm_bytes"] - (ws_full - ws_shard)
    # ring all-gather of the [m, n] output across tp devices
    payload = m * n * itemsize
    wire = payload * (tp - 1) // tp
    hops = tp - 1
    link = TARGET.interconnect
    equiv = int(math.ceil(wire * TARGET.hbm_bandwidth / link.link_bandwidth
                          + hops * link.hop_latency_s
                          * TARGET.hbm_bandwidth))
    return base["hbm_bytes"], tp_hbm + equiv


def test_hand_model_matches_registered_tp_cost():
    for tp in (SMALL_TP, LARGE_TP):
        _, hand_total = _hand_costs(tp)
        cost = REGISTRY.structural_cost("gemm_tp", "abstract",
                                        tp=tp, **SHAPE)
        assert (cost["hbm_bytes"] + cost["collective_hbm_equiv_bytes"]
                == hand_total)


def test_crossover_exists_between_the_two_meshes():
    """The hand-recomputed totals themselves flip between the meshes —
    the selection flip below is forced by arithmetic, not by accident."""
    base_small, tp_small = _hand_costs(SMALL_TP)
    base_large, tp_large = _hand_costs(LARGE_TP)
    assert tp_small < base_small, "TP must win the small ring"
    assert tp_large > base_large, "replicated must win the large ring"


def test_auto_is_mesh_sensitive():
    """Same op, same shape, two meshes -> two different lowerings."""
    with use_mesh_axes({"model": SMALL_TP}):
        small = REGISTRY.select("gemm", AUTO_POLICY, shape=SHAPE)
    with use_mesh_axes({"model": LARGE_TP}):
        large = REGISTRY.select("gemm", AUTO_POLICY, shape=SHAPE)
    assert small.op == "gemm_tp", "small mesh must pick the TP twin"
    assert large.op == "gemm", "large mesh must pick replicated"


def test_no_mesh_keeps_the_replicated_lowering():
    low = REGISTRY.select("gemm", AUTO_POLICY, shape=SHAPE)
    assert low.op == "gemm"


def test_pinned_mode_never_retargets_to_the_twin():
    """TP retarget is an auto-ranking decision only: a policy pinning an
    explicit mode keeps the base op."""
    with use_mesh_axes({"model": SMALL_TP}):
        low = REGISTRY.select("gemm", ExecutionPolicy(mode="native"),
                              shape=SHAPE)
    assert low.op == "gemm"


def test_no_interconnect_mesh_never_picks_tp():
    pol = ExecutionPolicy(mode="auto", dialect="apple-g13")
    with use_mesh_axes({"model": SMALL_TP}):
        low = REGISTRY.select("gemm", pol, shape=SHAPE)
    assert low.op == "gemm"


# ---------------------------------------------------------------------------
# twin cost invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("twin", sorted(TP_COSTS))
def test_tp_cost_degenerates_at_axis_size_one(twin):
    """tp=1: no shard saving, no collective — byte-identical ranking."""
    base = twin[:-len("_tp")]
    shape = ops.PROBE_SHAPES[twin]
    for mode in REGISTRY.modes(twin):
        b = REGISTRY.structural_cost(base, mode, **shape)
        t = REGISTRY.structural_cost(twin, mode, tp=1, **shape)
        assert t["hbm_bytes"] == b["hbm_bytes"], (twin, mode)
        assert t["collective_hbm_equiv_bytes"] == 0
        assert cost_key(t, REGISTRY.variant(twin, mode).mode)[:3] \
            == cost_key(b, REGISTRY.variant(base, mode).mode)[:3]


@pytest.mark.parametrize("twin", sorted(TP_COSTS))
def test_tp_cost_preserves_the_fused_pair_identity(twin):
    """hbm == unfused_pair - saved survives the shard re-pricing."""
    shape = ops.PROBE_SHAPES[twin]
    for mode in REGISTRY.modes(twin):
        t = REGISTRY.structural_cost(twin, mode, tp=SMALL_TP, **shape)
        if "hbm_bytes_unfused_pair" in t:
            assert t["hbm_bytes"] == (t["hbm_bytes_unfused_pair"]
                                      - t["hbm_bytes_saved"]), (twin, mode)
        assert t["collective_bytes"] > 0
        assert t["tp_axis"] == SMALL_TP


def test_every_declared_twin_is_registered_both_ways():
    pairs = REGISTRY.collective_variants()
    assert set(pairs.values()) == set(TP_COSTS)
    for base, twin in pairs.items():
        assert set(REGISTRY.modes(twin)) == set(REGISTRY.modes(base))
