"""Distribution layer: sharding rules, cell builder, HLO analyzer,
roofline math, elastic resharding restore.

These run on the single real CPU device using 1x1 meshes (sharding code
paths execute; splitting is degenerate).  The multi-device SPMD proof is
the dry-run (launch/dryrun.py, 512 forced host devices) — exercised here
via a subprocess smoke on a reduced cell.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.mesh import make_mesh, make_ctx
from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig, SHAPES, ShapeConfig
from repro.parallel.sharding import ShardCtx, shard, tree_shardings
from repro.roofline import analysis as roofline
from repro.roofline.hlo_parser import analyze_hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestShardingRules:
    def _ctx(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        return ShardCtx(mesh=mesh)

    def test_spec_resolution(self):
        ctx = self._ctx()
        spec = ctx.spec(("act_batch", "act_seq", "act_embed"))
        assert spec[0] in ("data", ("data",))   # pod absent on this mesh
        assert spec[1] == "model"
        assert spec[2] is None

    def test_duplicate_axis_degrades_to_replicated(self):
        ctx = self._ctx()
        spec = ctx.spec(("q_heads", "mlp"))   # both -> model
        assert spec[0] == "model" and spec[1] is None

    def test_no_mesh_is_identity(self):
        ctx = ShardCtx(mesh=None)
        x = jnp.ones((4, 4))
        assert shard(x, ("act_batch", "act_embed"), ctx) is x

    def test_param_specs_cover_every_leaf(self):
        """Every arch's param tree has a logical spec for every leaf with
        matching rank (+1 for the scanned layer axis)."""
        for arch in configs.ARCHS:
            cfg = configs.get_reduced(arch)
            model = build_model(cfg, ParallelConfig())
            params = jax.eval_shape(
                lambda m=model: m.init_params(jax.random.PRNGKey(0)))
            specs = model.param_specs()
            flat_p = dict(jax.tree_util.tree_flatten_with_path(params)[0])
            flat_s = dict(jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, tuple))[0])
            assert flat_p.keys() == flat_s.keys(), arch
            for k, leaf in flat_p.items():
                assert len(flat_s[k]) == len(leaf.shape), (arch, k)

    def test_cache_specs_cover_every_leaf(self):
        for arch in configs.ARCHS:
            cfg = configs.get_reduced(arch)
            model = build_model(cfg, ParallelConfig())
            cache = jax.eval_shape(lambda m=model: m.init_cache(2, 16))
            specs = model.cache_specs()
            flat_c = dict(jax.tree_util.tree_flatten_with_path(cache)[0])
            flat_s = dict(jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, tuple))[0])
            assert flat_c.keys() == flat_s.keys(), arch
            for k, leaf in flat_c.items():
                assert len(flat_s[k]) == len(leaf.shape), (arch, k)


class TestCellBuilder:
    def test_all_cells_buildable_reduced(self):
        """build_cell assembles fn+specs+shardings for every runnable
        (arch, shape-kind) without lowering."""
        mesh = make_mesh((1, 1), ("data", "model"))
        small = {
            "train_4k": ShapeConfig("train_4k", "train", 32, 4),
            "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32, 2),
            "decode_32k": ShapeConfig("decode_32k", "decode", 32, 4),
        }
        from repro.launch.cells import build_cell
        for arch in configs.ARCHS:
            for shape_name, sc in small.items():
                cell = build_cell(arch, shape_name, mesh, reduced=True,
                                  shape_cfg=sc)
                assert cell.kind in ("train", "prefill", "decode")

    def test_long_500k_rejected_for_full_attention(self):
        from repro.launch.cells import build_cell
        mesh = make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError):
            build_cell("qwen3-32b", "long_500k", mesh, reduced=True)

    def test_reduced_cell_lowers_and_compiles(self):
        """End-to-end lower+compile on the real device (1x1 mesh)."""
        from repro.launch.cells import build_cell
        mesh = make_mesh((1, 1), ("data", "model"))
        sc = ShapeConfig("train_4k", "train", 32, 4)
        cell = build_cell("granite-8b", "train_4k", mesh, reduced=True,
                          shape_cfg=sc)
        with mesh:
            compiled = cell.lower().compile()
        assert compiled.cost_analysis() is not None


class TestHloParser:
    def test_counts_loop_iterations(self):
        def f(x, ws):
            def body(h, w):
                return jnp.dot(h, w, preferred_element_type=jnp.float32), None
            return jax.lax.scan(body, x, ws)[0]
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        a = analyze_hlo(compiled.as_text(), 1)
        expect = 12 * 2 * 128 ** 3
        assert abs(a["flops"] - expect) / expect < 0.05

    def test_nested_scan_multiplies(self):
        def f(x, ws):
            def outer(h, w):
                def inner(h2, _):
                    return jnp.dot(h2, w,
                                   preferred_element_type=jnp.float32), None
                h2, _ = jax.lax.scan(inner, h, None, length=3)
                return h2, None
            return jax.lax.scan(outer, x, ws)[0]
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        a = analyze_hlo(compiled.as_text(), 1)
        expect = 5 * 3 * 2 * 64 ** 3
        assert abs(a["flops"] - expect) / expect < 0.05

    def test_bytes_nonzero_and_dominated_by_args(self):
        def f(x):
            return x * 2.0 + 1.0
        x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        compiled = jax.jit(f).lower(x).compile()
        a = analyze_hlo(compiled.as_text(), 1)
        assert a["hbm_bytes"] >= 2 * 1024 * 1024 * 4   # read + write


class TestRooflineMath:
    def test_terms_and_dominance(self):
        t = roofline.roofline_terms(
            flops_per_chip=197e12, bytes_per_chip=819e9,
            wire_bytes_per_chip=50e9, chips=256, mflops=197e12 * 256)
        assert t["t_compute_s"] == pytest.approx(1.0)
        assert t["t_memory_s"] == pytest.approx(1.0)
        assert t["t_collective_s"] == pytest.approx(1.0)
        assert t["roofline_fraction"] == pytest.approx(1.0)

    def test_model_flops_kinds(self):
        cfg = configs.get_config("granite-8b")
        n = cfg.active_param_count()
        tr = roofline.model_flops(cfg, SHAPES["train_4k"])
        pf = roofline.model_flops(cfg, SHAPES["prefill_32k"])
        dc = roofline.model_flops(cfg, SHAPES["decode_32k"])
        assert tr == pytest.approx(6 * n * 4096 * 256)
        assert pf == pytest.approx(2 * n * 32768 * 32)
        assert dc == pytest.approx(2 * n * 128)

    def test_analytic_bytes_decode_dominated_by_cache(self):
        cfg = configs.get_config("mistral-large-123b")
        b = roofline.analytic_hbm_bytes(cfg, SHAPES["decode_32k"], 256)
        assert b["cache"] > 0.3 * b["total"]

    def test_analytic_bytes_train_has_optimizer_traffic(self):
        cfg = configs.get_config("granite-8b")
        b = roofline.analytic_hbm_bytes(cfg, SHAPES["train_4k"], 256)
        assert b["optimizer"] > 0 and b["weights"] > 0 and b["acts"] > 0


class TestElasticRestore:
    def test_restore_onto_different_mesh(self, tmp_path):
        """Save under a 1x1 'data,model' mesh, restore under a 1-axis
        mesh — the elastic-restart path (device_put against new
        shardings)."""
        from repro.checkpoint import CheckpointManager
        cfg = ModelConfig(name="t", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=256, dtype="float32")
        par = ParallelConfig()
        mesh1 = make_mesh((1, 1), ("data", "model"))
        ctx1 = make_ctx(mesh1, par)
        model = build_model(cfg, par, ctx1)
        params = model.init_params(jax.random.PRNGKey(0))
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"params": params})

        mesh2 = make_mesh((1,), ("data",))
        ctx2 = ShardCtx(mesh=mesh2)
        sh2 = tree_shardings(ctx2, model.param_specs())
        got = mgr.restore(1, {"params": params},
                          shardings={"params": sh2})
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(got["params"])[0]),
            np.asarray(jax.tree.leaves(params)[0]))


@pytest.mark.slow
class TestDryRunSubprocess:
    def test_multi_pod_mesh_in_subprocess(self):
        """512 forced devices + production meshes, reduced config, tiny
        shape — proves the dryrun entrypoint works end to end."""
        code = (
            "import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=512'\n"
            "import jax\n"
            "from repro.launch.mesh import make_production_mesh\n"
            "from repro.launch.cells import build_cell\n"
            "from repro.models.config import ShapeConfig\n"
            "for multi in (False, True):\n"
            "    mesh = make_production_mesh(multi_pod=multi)\n"
            "    sc = ShapeConfig('train_4k', 'train', 64, 32)\n"
            "    cell = build_cell('granite-8b', 'train_4k', mesh,\n"
            "                      reduced=True, shape_cfg=sc)\n"
            "    with mesh:\n"
            "        compiled = cell.lower().compile()\n"
            "    assert compiled is not None\n"
            "print('DRYRUN_SMOKE_OK')\n")
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=900)
        assert "DRYRUN_SMOKE_OK" in out.stdout, out.stderr[-2000:]
