"""Fleet-level serving (ISSUE 10): the data-parallel CellRouter over N
BatchedEngine cells must be token-for-token equivalent to a single cell,
keep every cell's zero-per-tick-transfer invariant (one stacked harvest
for the whole fleet in sync()), admit by least-loaded page budget with
prefix-sharing affinity, and accept strictly more concurrent requests
than one cell holding the same total page budget."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig
from repro.serve import (BatchedEngine, CellRouter, Request, ServeConfig,
                         make_cells)

KEY = jax.random.PRNGKey(0)
CACHE_LEN = 32


def tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, dtype="float32")
    return build_model(cfg, ParallelConfig(remat="none")), cfg


def sequential_decode(model, params, prompt, max_new, eos):
    """Ground truth: hand-rolled prefill + one-at-a-time greedy decode
    (same helper the single-engine equivalence suite pins against)."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks})
    pad = CACHE_LEN - cache["k"].shape[3]
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "pos": cache["pos"],
    }
    out = [int(jnp.argmax(logits[0]))]
    while out[-1] != eos and len(out) < max_new:
        lg, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.fixture(scope="module")
def model_and_params():
    model, cfg = tiny_model()
    return model, model.init_params(KEY), cfg


def _prompts(cfg, n, rng_key=KEY):
    keys = jax.random.split(rng_key, n)
    return [[int(t) for t in jax.random.randint(
        k, (3 + i % 3,), 2, cfg.vocab_size)] for i, k in enumerate(keys)]


def _cell_of(router: CellRouter, req: Request):
    """Index of the cell whose slots hold ``req`` (None if unplaced)."""
    for i, c in enumerate(router.cells):
        if req in c.slots:
            return i
    return None


class TestRouterTokenEquivalence:
    """Same requests in, same tokens out — regardless of cell count."""

    @pytest.mark.parametrize("n_cells", [1, 2, 3])
    def test_paged_fleet_matches_sequential(self, model_and_params,
                                            n_cells):
        """6 requests over n cells × 2 slots: admissions spread across
        the fleet mid-stream, yet every request matches its solo
        decode (placement must never leak into tokens)."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 6)
        max_news = [4, 7, 5, 6, 4, 6]
        want = [sequential_decode(model, params, p, m, eos=-1)
                for p, m in zip(prompts, max_news)]
        router = make_cells(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=8), n_cells)
        done = router.run(
            [Request(rid=i, prompt=p, max_new_tokens=m)
             for i, (p, m) in enumerate(zip(prompts, max_news))])
        assert len(done) == 6
        for r in done:
            assert not r.rejected
            assert r.generated == want[r.rid], r.rid

    def test_dense_fleet_matches_sequential(self, model_and_params):
        """The router's dense (non-paged) path: load is free slots."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 4)
        want = [sequential_decode(model, params, p, 5, eos=-1)
                for p in prompts]
        router = make_cells(model, params, ServeConfig(
            batch_slots=1, max_seq_len=CACHE_LEN, eos_id=-1), 2)
        done = router.run([Request(rid=i, prompt=p, max_new_tokens=5)
                           for i, p in enumerate(prompts)])
        assert len(done) == 4
        for r in done:
            assert r.generated == want[r.rid], r.rid


class TestAdmissionPolicy:
    def _fleet(self, model_and_params, n_cells=2, batch_slots=4,
               num_pages=8, prefix_affinity=True):
        model, params, cfg = model_and_params
        scfg = ServeConfig(batch_slots=batch_slots, max_seq_len=CACHE_LEN,
                           eos_id=-1, page_size=8, num_pages=num_pages)
        cells = [BatchedEngine(model, params, scfg)
                 for _ in range(n_cells)]
        return CellRouter(cells, prefix_affinity=prefix_affinity), cfg

    def test_least_loaded_by_free_pages_under_skew(self, model_and_params):
        """Skewed page reservations (alternating 3-page and 1-page
        requests): every admission must land on the cell that had the
        most free pages at that moment (ties to the lowest index)."""
        router, cfg = self._fleet(model_and_params)
        prompts = _prompts(cfg, 6)
        # skew the reservation via max_new: 3+20-1=22 tokens -> 3 pages,
        # 3+4-1=6 tokens -> 1 page (page_size 8)
        max_news = [20, 4, 20, 4, 20, 4]
        for i, (p, m) in enumerate(zip(prompts, max_news)):
            expect = min(range(router.num_cells), key=router._load_key)
            req = Request(rid=i, prompt=p[:3], max_new_tokens=m)
            assert router.admit([req]) == 1
            assert _cell_of(router, req) == expect, i

    def test_fleet_admits_strictly_more_than_one_cell(self,
                                                      model_and_params):
        """Acceptance: N cells splitting one cell's page budget admit
        strictly more concurrent requests — capacity scales with slots
        while the page budget stays fixed."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 6)
        reqs = lambda: [Request(rid=i, prompt=p[:3], max_new_tokens=4)
                        for i, p in enumerate(prompts)]
        single = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=8, num_pages=6))
        n_single = single.admit(reqs())
        router, _ = self._fleet(model_and_params, n_cells=3,
                                batch_slots=2, num_pages=2)
        n_fleet = router.admit(reqs())
        assert n_single == 2            # slot-bound
        assert n_fleet == 6             # same 6-page budget, 3x the slots
        assert n_fleet > n_single

    def test_fleet_wide_reject_of_never_admittable(self, model_and_params):
        """A reservation exceeding EVERY cell's total pool is rejected
        outright (consumed, done, no slot) — the single-engine
        never-admittable rule applied fleet-wide."""
        router, cfg = self._fleet(model_and_params, num_pages=2)
        giant = Request(rid=0, prompt=_prompts(cfg, 1)[0],
                        max_new_tokens=CACHE_LEN)     # 4 pages > 2
        after = Request(rid=1, prompt=_prompts(cfg, 2)[1][:3],
                        max_new_tokens=4)
        assert router.admit([giant, after]) == 2
        assert giant.rejected and giant.done and giant.slot is None
        assert not after.rejected and _cell_of(router, after) is not None

    def test_failover_walks_to_cell_with_free_slot(self, model_and_params):
        """The least-loaded cell is slot-saturated but still has the most
        free pages: admission must fail over to the next candidate
        instead of dropping the request."""
        model, params, cfg = model_and_params
        mk = lambda pages: BatchedEngine(model, params, ServeConfig(
            batch_slots=1, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=8, num_pages=pages))
        router = CellRouter([mk(4), mk(8)])
        prompts = _prompts(cfg, 3)
        r0 = Request(rid=0, prompt=prompts[0], max_new_tokens=8)
        assert router.admit([r0]) == 1
        assert _cell_of(router, r0) == 1          # bigger pool wins load
        r1 = Request(rid=1, prompt=prompts[1], max_new_tokens=8)
        assert router.admit([r1]) == 1
        assert _cell_of(router, r1) == 0          # cell 1 full: failover
        # both slots taken: FIFO stop, nothing consumed
        r2 = Request(rid=2, prompt=prompts[2], max_new_tokens=8)
        assert router.admit([r2]) == 0
        assert r2.slot is None and not r2.rejected

    def test_drain_removes_cell_from_admission(self, model_and_params):
        router, cfg = self._fleet(model_and_params)
        prompts = _prompts(cfg, 3)
        router.drain(0)
        r0 = Request(rid=0, prompt=prompts[0], max_new_tokens=4)
        r1 = Request(rid=1, prompt=prompts[1], max_new_tokens=4)
        assert router.admit([r0, r1]) == 2
        assert _cell_of(router, r0) == 1 and _cell_of(router, r1) == 1
        router.undrain(0)
        r2 = Request(rid=2, prompt=prompts[2], max_new_tokens=4)
        assert router.admit([r2]) == 1
        assert _cell_of(router, r2) == 0          # now the least loaded
        router.drain(0)
        router.drain(1)
        held = Request(rid=3, prompt=prompts[0], max_new_tokens=4)
        assert router.admit([held]) == 0          # all drained: hold queue
        assert not held.rejected and held.slot is None


class TestPrefixAffinity:
    PAGE = 4

    def _shared_reqs(self, cfg):
        shared = [7, 11, 13, 17, 19, 23, 29, 31]      # 2 full pages
        return (Request(rid=0, prompt=shared + [41], max_new_tokens=4),
                Request(rid=1, prompt=shared + [43], max_new_tokens=4))

    def _fleet(self, model_and_params, prefix_affinity=True):
        model, params, cfg = model_and_params
        scfg = ServeConfig(batch_slots=2, max_seq_len=CACHE_LEN,
                           eos_id=-1, page_size=self.PAGE)
        cells = [BatchedEngine(model, params, scfg) for _ in range(2)]
        return (CellRouter(cells, prefix_affinity=prefix_affinity),
                model, params, cfg)

    def test_shared_prefix_stays_on_owner_cell(self, model_and_params):
        """The second request sharing a 2-page prompt prefix must follow
        the pages to the first request's cell — refcount sharing only
        works within a cell's device-resident pool — and still decode
        its own tokens exactly."""
        router, model, params, cfg = self._fleet(model_and_params)
        ra, rb = self._shared_reqs(cfg)
        assert router.admit([ra]) == 1
        owner = _cell_of(router, ra)
        assert router.admit([rb]) == 1
        assert _cell_of(router, rb) == owner
        hits = [c.pool.shared_hits for c in router.cells]
        assert hits[owner] == 2                      # both prefix pages
        assert hits[1 - owner] == 0
        done = router.run([])
        assert router.active_requests() == []
        for r in (ra, rb):
            assert r.generated == sequential_decode(
                model, params, r.prompt, 4, eos=-1), r.rid

    def test_affinity_off_spreads_by_load(self, model_and_params):
        """Same two requests with affinity disabled: the second goes to
        the emptier cell and shares nothing."""
        router, model, params, cfg = self._fleet(model_and_params,
                                                 prefix_affinity=False)
        ra, rb = self._shared_reqs(cfg)
        assert router.admit([ra]) == 1
        assert router.admit([rb]) == 1
        assert _cell_of(router, rb) != _cell_of(router, ra)
        assert sum(c.pool.shared_hits for c in router.cells) == 0


class TestTransferFreeFleet:
    def test_tick_loop_transfer_free_one_stacked_harvest(
            self, model_and_params, monkeypatch):
        """Acceptance: N cells tick under ``transfer_guard('disallow')``
        (the router adds no per-tick host sync), and the whole fleet's
        pending history drains in exactly ONE ``jax.device_get``."""
        model, params, cfg = model_and_params
        router = make_cells(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=8), 2)
        prompts = _prompts(cfg, 4)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=12)
                for i, p in enumerate(prompts)]
        assert router.admit(reqs) == 4

        with jax.transfer_guard("disallow"):
            for _ in range(10):
                router.step()

        calls = []
        real = jax.device_get
        monkeypatch.setattr(jax, "device_get",
                            lambda x: calls.append(1) or real(x))
        router.sync()
        assert len(calls) == 1
        for c in router.cells:
            assert c._history == [] and c._stats_history == []
            assert len(c.tick_stats) == 10
            for r in c.slots:
                assert r is not None and len(r.generated) >= 11
        # idempotent: nothing pending -> no transfer at all
        router.sync()
        assert len(calls) == 1

    def test_cell_stats_snapshot(self, model_and_params):
        """cell_stats() (the profile script's rows) reports per-cell
        occupancy, utilization and shared-prefix hits."""
        model, params, cfg = model_and_params
        router = make_cells(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=8), 2)
        router.admit([Request(rid=0, prompt=_prompts(cfg, 1)[0],
                              max_new_tokens=8)])
        rows = router.cell_stats()
        assert [r["cell"] for r in rows] == [0, 1]
        loaded = rows[0]
        assert loaded["live_slots"] == 1 and loaded["occupied_pages"] > 0
        assert 0 < loaded["utilization"] <= 1
        assert rows[1]["occupied_pages"] == 0
        assert all(not r["drained"] for r in rows)


class TestBuildServeCells:
    def test_launch_builder_shares_params(self):
        """launch.cells.build_serve_cells: one param init, N cells whose
        ``params`` are the same device buffers (data parallelism over
        requests, not N copies of the model)."""
        from repro.launch.cells import build_serve_cells
        router = build_serve_cells(
            "granite-8b",
            ServeConfig(batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
                        page_size=8),
            n_cells=2)
        assert isinstance(router, CellRouter) and router.num_cells == 2
        p0, p1 = (jax.tree.leaves(c.params) for c in router.cells)
        assert all(a is b for a, b in zip(p0, p1))
