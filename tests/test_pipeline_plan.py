"""plan_row_pipeline edge cases (ISSUE 3 satellite).

Ragged row counts, pow2 blocks on sub-SUBLANES inputs, and the
min_occupancy invariant under tiny dialect scratchpad budgets —
property-style where the hypothesis shim allows, with example-based
anchors that always run.
"""
import dataclasses

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.core import TPU_V5E, plan_row_pipeline
from repro.core.pipeline import SUBLANES

settings.register_profile("pipeline", max_examples=60, deadline=None)
settings.load_profile("pipeline")


def _tiny_dialect(scratch_bytes: int):
    return dataclasses.replace(TPU_V5E, scratchpad_bytes=scratch_bytes,
                               regfile_bytes_per_core=scratch_bytes)


def _check_invariants(plan, total_rows, min_occupancy, dialect):
    """The properties every plan must satisfy, tuned or not."""
    assert plan.block_rows >= SUBLANES
    assert plan.block_rows % SUBLANES == 0 or plan.block_rows < SUBLANES
    assert plan.padded_rows >= total_rows
    assert plan.padded_rows % plan.block_rows == 0
    assert plan.grid == (plan.padded_rows // plan.block_rows,)
    # Eq. 1 invariant: min_occupancy stages resident, except at the floor
    # (one SUBLANES block) where the budget itself is too small — the
    # planner clamps rather than failing, and the occupancy it reports
    # must still be the dialect's honest number.
    assert plan.occupancy == dialect.buffer_occupancy(
        plan.block_rows * plan.row_bytes, plan.n_buffers)
    assert plan.occupancy >= min_occupancy or plan.block_rows == SUBLANES


# ---------------------------------------------------------------------------
# Example-based anchors (always run)
# ---------------------------------------------------------------------------


def test_ragged_total_rows():
    for total in (1, 7, 9, 63, 65, 1000, 1025):
        plan = plan_row_pipeline(total, 512, mode="native",
                                 max_block_rows=64)
        _check_invariants(plan, total, 2, TPU_V5E)
        # never pad a small input past one block of its own rounded size
        rounded = -(-total // SUBLANES) * SUBLANES
        assert plan.block_rows <= max(rounded, SUBLANES)


def test_pow2_blocks_sub_sublanes_input():
    for total in range(1, SUBLANES + 1):
        plan = plan_row_pipeline(total, 512, mode="abstract",
                                 pow2_blocks=True)
        assert plan.block_rows == SUBLANES          # the floor granule
        assert plan.block_rows & (plan.block_rows - 1) == 0
        _check_invariants(plan, total, 2, TPU_V5E)


def test_pow2_blocks_always_pow2():
    for total in (12, 100, 1000, 4096):
        plan = plan_row_pipeline(total, 512, mode="abstract",
                                 max_block_rows=48, pow2_blocks=True)
        assert plan.block_rows & (plan.block_rows - 1) == 0
        _check_invariants(plan, total, 2, TPU_V5E)


def test_min_occupancy_under_tiny_budgets():
    row_bytes = 4096
    # budget admits exactly min_occupancy double-buffered SUBLANES blocks
    enough = _tiny_dialect(2 * 2 * SUBLANES * row_bytes)
    plan = plan_row_pipeline(1024, row_bytes, mode="native",
                             dialect=enough)
    assert plan.occupancy >= 2
    # budget below the floor: the plan clamps to one SUBLANES block and
    # reports the honest (sub-minimum) occupancy instead of lying
    starved = _tiny_dialect(2 * 2 * SUBLANES * row_bytes - 1)
    plan = plan_row_pipeline(1024, row_bytes, mode="native",
                             dialect=starved)
    assert plan.block_rows == SUBLANES
    assert plan.occupancy < 2
    _check_invariants(plan, 1024, 2, starved)


def test_invalid_inputs_rejected():
    with pytest.raises(ValueError):
        plan_row_pipeline(0, 512, mode="native")
    with pytest.raises(ValueError):
        plan_row_pipeline(8, 0, mode="native")


def test_tuned_override_cannot_break_invariants():
    for tuned in ({"block_rows": 100000},        # occupancy-illegal
                  {"block_rows": 16, "n_buffers": 3},
                  {"n_buffers": 4},
                  {}):
        plan = plan_row_pipeline(777, 2048, mode="native",
                                 max_block_rows=64, tuned=tuned)
        _check_invariants(plan, 777, 2, TPU_V5E)


# ---------------------------------------------------------------------------
# Property sweeps (hypothesis; skip cleanly via the shim when absent)
# ---------------------------------------------------------------------------


@given(total=st.integers(1, 1 << 16),
       row_bytes=st.sampled_from([4, 512, 4096, 1 << 20]),
       cap=st.sampled_from([None, 8, 64, 512]),
       pow2=st.booleans())
def test_plan_invariants_property(total, row_bytes, cap, pow2):
    plan = plan_row_pipeline(total, row_bytes, mode="native",
                             max_block_rows=cap, pow2_blocks=pow2)
    _check_invariants(plan, total, 2, TPU_V5E)
    if pow2:
        assert plan.block_rows & (plan.block_rows - 1) == 0
    if cap is not None and not pow2:
        assert plan.block_rows <= max(cap, SUBLANES)


@given(scratch_kb=st.integers(1, 1 << 12),
       total=st.integers(1, 1 << 12),
       n_buffers=st.sampled_from([2, 3, 4]))
def test_tiny_budget_property(scratch_kb, total, n_buffers):
    """Across arbitrary scratchpad sizes the plan either honors
    min_occupancy or sits at the one-block floor — never in between."""
    dialect = _tiny_dialect(scratch_kb * 1024)
    plan = plan_row_pipeline(total, 2048, mode="native",
                             dialect=dialect, n_buffers=n_buffers)
    _check_invariants(plan, total, 2, dialect)


@given(total=st.integers(1, 1 << 14),
       tuned_block=st.integers(1, 1 << 15),
       tuned_buffers=st.sampled_from([2, 3, 4]))
def test_tuned_override_property(total, tuned_block, tuned_buffers):
    plan = plan_row_pipeline(total, 1024, mode="native", max_block_rows=64,
                             tuned={"block_rows": tuned_block,
                                    "n_buffers": tuned_buffers})
    _check_invariants(plan, total, 2, TPU_V5E)
    assert plan.n_buffers == tuned_buffers
