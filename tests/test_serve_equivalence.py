"""Serve-path equivalence: the batched continuous-batching engine must
produce token-for-token what sequential single-request decoding produces,
including across mid-stream admissions and slot reuse — plus regression
tests pinning the host-sync-free tick (one compiled program, zero host
transfers inside the tick loop)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig
from repro.core import REGISTRY
from repro.serve import BatchedEngine, Request, ServeConfig

KEY = jax.random.PRNGKey(0)
CACHE_LEN = 32


def tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, dtype="float32")
    return build_model(cfg, ParallelConfig(remat="none")), cfg


def sequential_decode(model, params, prompt, max_new, eos):
    """Hand-rolled prefill + one-at-a-time greedy decode: the ground truth
    the batched engine must reproduce (engine semantics: the prefill
    token counts toward max_new; stop on EOS or length)."""
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    logits, cache = model.prefill(params, {"tokens": toks})
    pad = CACHE_LEN - cache["k"].shape[3]
    cache = {
        "k": jnp.pad(cache["k"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "v": jnp.pad(cache["v"], ((0, 0),) * 3 + ((0, pad), (0, 0))),
        "pos": cache["pos"],
    }
    out = [int(jnp.argmax(logits[0]))]
    while out[-1] != eos and len(out) < max_new:
        lg, cache = model.decode_step(
            params, jnp.asarray([out[-1]], jnp.int32), cache)
        out.append(int(jnp.argmax(lg[0])))
    return out


@pytest.fixture(scope="module")
def model_and_params():
    model, cfg = tiny_model()
    return model, model.init_params(KEY), cfg


def _prompts(cfg, n, rng_key=KEY):
    keys = jax.random.split(rng_key, n)
    return [[int(t) for t in jax.random.randint(
        k, (3 + i % 3,), 2, cfg.vocab_size)] for i, k in enumerate(keys)]


class TestBatchedSequentialEquivalence:
    def test_oversubscribed_matches_sequential(self, model_and_params):
        """5 requests on 2 slots: admissions happen mid-stream as slots
        free; every request must still match its solo decode."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 5)
        max_news = [4, 7, 5, 6, 4]
        want = [sequential_decode(model, params, p, m, eos=-1)
                for p, m in zip(prompts, max_news)]

        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        reqs = [Request(rid=i, prompt=p, max_new_tokens=m)
                for i, (p, m) in enumerate(zip(prompts, max_news))]
        done = eng.run(reqs)
        assert len(done) == 5
        for r in done:
            assert r.generated == want[r.rid], r.rid

    def test_eos_termination_matches_sequential(self, model_and_params):
        """Pick a token the greedy path actually emits as EOS: batched
        early termination must match sequential early termination."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 3)
        probe = sequential_decode(model, params, prompts[0], 8, eos=-1)
        eos = probe[2]          # guaranteed to appear mid-stream
        want = [sequential_decode(model, params, p, 8, eos=eos)
                for p in prompts]
        assert len(want[0]) < 8  # the EOS path is actually exercised

        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=eos))
        done = eng.run([Request(rid=i, prompt=p, max_new_tokens=8)
                        for i, p in enumerate(prompts)])
        for r in done:
            assert r.generated == want[r.rid], r.rid

    def test_explicit_mid_stream_admission(self, model_and_params):
        """Admit a request onto a slot that another request just vacated,
        with ticks in between: the newcomer is unaffected by the slot's
        previous occupant."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 3)
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        r0 = Request(rid=0, prompt=prompts[0], max_new_tokens=3)
        r1 = Request(rid=1, prompt=prompts[1], max_new_tokens=8)
        assert eng.add_request(r0) and eng.add_request(r1)
        for _ in range(4):       # r0 finishes (3 tokens), r1 keeps going
            eng.step()
        r2 = Request(rid=2, prompt=prompts[2], max_new_tokens=5)
        assert eng.add_request(r2)      # reuses r0's slot
        assert r2.slot == r0.slot and r0.done
        for _ in range(8):
            eng.step()
        eng.sync()
        for req, m in ((r0, 3), (r1, 8), (r2, 5)):
            assert req.generated == sequential_decode(
                model, params, req.prompt, m, eos=-1), req.rid

    def test_slot_reaping_admits_into_reaped_slot(self, model_and_params):
        """Regression for the double-_free_slot bug: admission must claim
        exactly the slot it reaps, once per admission."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        prompts = _prompts(cfg, 4)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=3 + i)
                for i in range(4)]
        done = eng.run(reqs)
        assert len(done) == 4
        assert all(r.done and len(r.generated) == 3 + r.rid for r in done)
        # the two late requests took over the two early slots
        assert {reqs[2].slot, reqs[3].slot} == {reqs[0].slot, reqs[1].slot}


class TestFusionEquivalence:
    """ISSUE 4 satellite: PR 3's decode path was only ever tested with
    fusion off.  The fused decode tick (add_rmsnorm residual→ln2 in
    block_decode, rmsnorm_matmul final-norm→lm_head in _head) must emit
    token-for-token what the unfused engine emits."""

    def test_fused_decode_matches_unfused(self, model_and_params):
        model, params, cfg = model_and_params
        fused_model = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True))
        assert fused_model.policy.fuses() and not model.policy.fuses()
        prompts = _prompts(cfg, 4)
        max_news = [4, 7, 5, 6]

        def run(m):
            eng = BatchedEngine(m, params, ServeConfig(
                batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
            return eng.run([Request(rid=i, prompt=p, max_new_tokens=mx)
                            for i, (p, mx) in enumerate(zip(prompts,
                                                            max_news))])

        want = {r.rid: r.generated for r in run(model)}
        got = run(fused_model)
        assert len(got) == 4
        for r in got:
            assert r.generated == want[r.rid], r.rid

    def test_all_three_fusions_on_concat_layout(self, model_and_params):
        """ISSUE 5: with the persisted [wq|wk|wv]/[wi|wg] layout and the
        Pallas decode attention epilogue, ALL THREE seq-path fusions
        (q/k/v prologue, flash->wo, ln2->swiglu) are live inside the
        decode tick — and the engine still emits token-for-token what the
        unfused legacy engine emits."""
        model, params, cfg = model_and_params
        full = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True, use_pallas_attn=True))
        assert full.param_layout.attn_qkv and full.param_layout.mlp_swiglu
        # same seed, concatenated layout: identical weights, fused form
        concat_params = full.init_params(KEY)
        prompts = _prompts(cfg, 4)
        max_news = [4, 7, 5, 6]

        def run(m, p):
            eng = BatchedEngine(m, p, ServeConfig(
                batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
            done = eng.run([Request(rid=i, prompt=pr, max_new_tokens=mx)
                            for i, (pr, mx) in enumerate(zip(prompts,
                                                             max_news))])
            return done, eng

        want = {r.rid: r.generated for r in run(model, params)[0]}
        got, eng = run(full, concat_params)
        assert eng.param_layout.attn_qkv            # engine surfaces it
        assert eng.trace_count == 1                 # still ONE tick program
        assert len(got) == 4
        for r in got:
            assert r.generated == want[r.rid], r.rid

    def test_fused_tick_stays_one_compiled_program(self, model_and_params):
        """Fusion must not break the host-sync-free tick: still exactly
        one trace across admissions and slot reuse."""
        model, params, cfg = model_and_params
        fused_model = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True))
        eng = BatchedEngine(fused_model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        prompts = _prompts(cfg, 5)
        eng.run([Request(rid=i, prompt=p, max_new_tokens=4 + i % 3)
                 for i, p in enumerate(prompts)])
        assert eng.tick_count > 4
        assert eng.trace_count == 1


class TestPagedEngine:
    """ISSUE 6 tentpole: the paged KV cache (page pool + per-slot block
    tables) must be invisible to correctness — identical tokens to the
    dense engine for identical request streams — while keeping the tick
    ONE compiled program with zero per-tick host transfers, admitting by
    page budget instead of slot-dense capacity, and freeing pages on
    reap."""

    PAGE = 8                      # CACHE_LEN=32 -> 4 pages per slot

    def _run(self, model, params, reqs, **cfg_kw):
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1, **cfg_kw))
        done = eng.run(reqs)
        return {r.rid: r.generated for r in done}, eng

    def _reqs(self, cfg, n=5, max_news=(4, 7, 5, 6, 4)):
        return [Request(rid=i, prompt=p, max_new_tokens=m)
                for i, (p, m) in enumerate(zip(_prompts(cfg, n),
                                               max_news))]

    def test_paged_matches_dense_tokens(self, model_and_params):
        """Oversubscribed (5 requests, 2 slots, mid-stream reaping):
        paged and dense engines emit identical token streams."""
        model, params, cfg = model_and_params
        want, _ = self._run(model, params, self._reqs(cfg))
        got, eng = self._run(model, params, self._reqs(cfg),
                             page_size=self.PAGE)
        assert len(got) == 5
        assert got == want
        assert eng.trace_count == 1          # still ONE tick program

    def test_paged_fused_pallas_matches_dense(self, model_and_params):
        """The paged decode shape of flash_attention_matmul (block-table
        gather + dead-block skip) inside the fully-fused Pallas tick:
        token-for-token against the unfused dense engine."""
        model, params, cfg = model_and_params
        full = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True, use_pallas_attn=True))
        want, _ = self._run(model, params, self._reqs(cfg, 4,
                                                      (4, 7, 5, 6)))
        got, eng = self._run(full, full.init_params(KEY),
                             self._reqs(cfg, 4, (4, 7, 5, 6)),
                             page_size=self.PAGE)
        assert got == want
        assert eng.trace_count == 1

    def test_page_budget_admission_beats_dense_capacity(
            self, model_and_params):
        """ISSUE 6 satellite: short prompts must not pay the max_seq_len
        capacity tax.  A pool holding FEWER tokens than
        ``batch_slots × max_seq_len`` (dense-impossible) still admits
        every slot, because reservations follow actual request length."""
        model, params, cfg = model_and_params
        num_pages = 8                        # 64 tokens of pool capacity
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=4, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, num_pages=num_pages))
        assert num_pages * self.PAGE < 4 * CACHE_LEN   # < dense bytes
        prompts = _prompts(cfg, 4)
        reqs = [Request(rid=i, prompt=p[:3], max_new_tokens=4)
                for i, p in enumerate(prompts)]
        assert eng.admit(reqs) == 4          # all slots, tiny pool
        # capacity regression: the pool covers > batch_slots × avg_len
        # actual tokens, while a dense layout of the same byte budget
        # would hold only num_pages·page/max_len = 2 slots
        avg_len = sum(len(r.prompt) + r.max_new_tokens for r in reqs) / 4
        assert num_pages * self.PAGE > 4 * avg_len
        assert num_pages * self.PAGE // CACHE_LEN < 4
        done = eng.run([])
        for r in reqs:
            assert r.generated == sequential_decode(
                model, params, r.prompt, 4, eos=-1), r.rid

    def test_admission_stops_when_pool_exhausted(self, model_and_params):
        """Page budget is a real budget: with pages for only one
        reservation, the second request waits even though a slot is
        free — then admits once the first reaps and frees its pages."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 2)
        # each request reserves ceil((3..5 + 4 - 1)/8) = 1 page
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, num_pages=1))
        reqs = [Request(rid=i, prompt=p[:3], max_new_tokens=4)
                for i, p in enumerate(prompts)]
        assert eng.admit(reqs) == 1          # pool, not slots, is the gate
        assert eng.pool.free_pages == 0
        done = eng.run(reqs[1:])             # finishes r0, then admits r1
        assert reqs[0].done and reqs[1].done
        for r in reqs:
            assert r.generated == sequential_decode(
                model, params, r.prompt, 4, eos=-1), r.rid

    def test_prefix_sharing_refcounts_and_tokens(self, model_and_params):
        """Two requests with one common full prompt page share it by
        refcount; the tail/frontier page is never shared (copy-on-write
        never aliases), and output tokens match the non-sharing engine."""
        model, params, cfg = model_and_params
        prompt = _prompts(cfg, 1)[0] * 4     # >= 10 tokens: 1 full page
        assert len(prompt) > self.PAGE
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE))
        r0 = Request(rid=0, prompt=list(prompt), max_new_tokens=5)
        r1 = Request(rid=1, prompt=list(prompt), max_new_tokens=5)
        assert eng.admit([r0, r1]) == 2
        head0, head1 = eng._slot_pages[0][0], eng._slot_pages[1][0]
        assert head0 == head1                        # shared prefix page
        assert eng.pool.refcount[head0] == 2
        assert (set(eng._slot_pages[0][1:])
                & set(eng._slot_pages[1][1:]) == set())   # tails disjoint
        assert eng.pool.shared_hits == 1
        eng.run([])
        assert r0.generated == r1.generated
        plain = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, prefix_sharing=False))
        solo = plain.run([Request(rid=0, prompt=list(prompt),
                                  max_new_tokens=5)])
        assert plain.pool.shared_hits == 0
        assert solo[0].generated == r0.generated

    def test_reap_frees_pages_and_slot_reuse_is_clean(
            self, model_and_params):
        """Pages release exactly at reap; a newcomer over a reaped slot
        reuses its pages without contamination from the previous
        occupant (sentinel table hygiene)."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 4)
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE))
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=3 + i)
                for i in range(4)]
        done = eng.run(reqs)
        assert len(done) == 4
        assert eng.pool.occupied_pages == sum(
            len(p) for p in eng._slot_pages)
        for r in done:
            assert r.generated == sequential_decode(
                model, params, r.prompt, 3 + r.rid, eos=-1), r.rid

    def test_paged_tick_loop_is_transfer_free(self, model_and_params):
        """Zero host transfers inside the paged tick loop — the block
        tables, page pools, and per-tick stats all stay on device."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE))
        eng.add_request(Request(rid=0, prompt=[3, 5, 7],
                                max_new_tokens=50))
        eng.step()                       # compile outside the guard
        with jax.transfer_guard("disallow"):
            for _ in range(10):
                eng.step()
        eng.sync()
        assert len(eng.slots[0].generated) >= 11
        assert eng.trace_count == 1

    def test_tick_stats_harvested_in_sync(self, model_and_params):
        """ISSUE 6 satellite: per-tick stats ride the device history and
        drain in sync() — live slots, frontier pages, pool utilization,
        shared-prefix hits."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE))
        eng.run(self._reqs(cfg))
        assert len(eng.tick_stats) == eng.tick_count
        first = eng.tick_stats[0]
        assert set(first) == {"tick", "live_slots", "frontier_pages",
                              "pool_occupied_pages", "pool_utilization",
                              "shared_prefix_hits"}
        assert first["live_slots"] == 2
        assert 0 < first["frontier_pages"] <= eng.num_pages
        assert 0.0 < first["pool_utilization"] <= 1.0

    def test_paged_hbm_cost_scales_with_occupied_pages(
            self, model_and_params):
        """Acceptance pin: the paged decode structural_cost.hbm_bytes
        follows occupied pages, NOT max_len.  Doubling capacity at fixed
        occupancy leaves traffic unchanged; doubling occupancy raises
        it; a quarter-occupied paged cache beats the dense decode shape
        that streams the whole strip."""
        del model_and_params
        base = dict(b=8, h=4, sq=1, d=64, n=256, causal=False)
        for mode in REGISTRY.modes("flash_attention_matmul"):
            paged = REGISTRY.structural_cost(
                "flash_attention_matmul", mode, skv=1024, page_size=128,
                pages_occupied=16, **base)
            grown = REGISTRY.structural_cost(
                "flash_attention_matmul", mode, skv=4096, page_size=128,
                pages_occupied=16, **base)
            double = REGISTRY.structural_cost(
                "flash_attention_matmul", mode, skv=1024, page_size=128,
                pages_occupied=32, **base)
            dense = REGISTRY.structural_cost(
                "flash_attention_matmul", mode, skv=1024, **base)
            assert paged["hbm_bytes"] == grown["hbm_bytes"], mode
            assert double["hbm_bytes"] > paged["hbm_bytes"], mode
            assert paged["hbm_bytes"] < dense["hbm_bytes"], mode
            assert paged["blocks_visited"] == 4 * 16, mode


class TestQuantizedEngine:
    """ISSUE 7 tentpole: the fully-quantized serve tick — int8 weights
    dequantized in VMEM through the _q8 registry twins, int8 KV pages +
    scale strips through the block tables — must emit the same tokens as
    the f32 engine on the tiny model, stay ONE compiled program with
    zero per-tick host transfers, and hold MORE pages than f32 inside
    the same byte budget (the capacity win quantization exists for)."""

    PAGE = 8

    def _quant_engine(self, cfg, **serve_kw):
        from repro.models import common
        model = build_model(cfg, ParallelConfig(
            remat="none", fuse_epilogues=True, use_pallas_attn=True,
            weight_precision="int8", kv_cache_int8=True))
        qparams = common.quantize_params(model.init_params(KEY))
        return model, qparams, BatchedEngine(model, qparams, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1, **serve_kw))

    def test_quantized_paged_matches_f32_tokens(self, model_and_params):
        """int8 weights + int8 KV pages against the unfused dense f32
        engine: identical greedy tokens for identical request streams
        (the tiny model's logit gaps dominate the declared int8
        tolerance), through one compiled tick program."""
        model, params, cfg = model_and_params
        prompts = _prompts(cfg, 4)
        max_news = [4, 7, 5, 6]
        reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=m)
                        for i, (p, m) in enumerate(zip(prompts, max_news))]
        want = {r.rid: r.generated
                for r in BatchedEngine(model, params, ServeConfig(
                    batch_slots=2, max_seq_len=CACHE_LEN,
                    eos_id=-1)).run(reqs())}
        _, _, eng = self._quant_engine(cfg, page_size=self.PAGE)
        got = {r.rid: r.generated for r in eng.run(reqs())}
        assert len(got) == 4
        assert got == want
        assert eng.trace_count == 1

    def test_quantized_tick_loop_is_transfer_free(self, model_and_params):
        """Quantization must not smuggle host work into the tick: scale
        pools, int8 pages, and block tables all live on device; steps
        run under a disallow-all transfer guard."""
        _, _, cfg = model_and_params
        _, _, eng = self._quant_engine(cfg, page_size=self.PAGE)
        eng.add_request(Request(rid=0, prompt=[3, 5, 7],
                                max_new_tokens=50))
        eng.step()                       # compile outside the guard
        with jax.transfer_guard("disallow"):
            for _ in range(10):
                eng.step()
        eng.sync()
        assert len(eng.slots[0].generated) >= 11
        assert eng.trace_count == 1

    def test_int8_pool_holds_more_pages_per_byte(self, model_and_params):
        """Capacity accounting follows the real footprint: at the same
        ``kv_pool_bytes`` budget the int8 engine sizes its pool
        4·hd/(hd+4)x larger than f32 (hd=16 -> 3.2x), because an int8
        page costs (hd+4) bytes per (token, head, direction) against
        f32's 4·hd."""
        model, params, cfg = model_and_params
        budget = 64 * 1024
        eng_f = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, kv_pool_bytes=budget))
        _, _, eng_q = self._quant_engine(cfg, page_size=self.PAGE,
                                         kv_pool_bytes=budget)
        hd = cfg.resolved_head_dim
        assert eng_q.page_footprint_bytes() * 4 * hd == \
            eng_f.page_footprint_bytes() * (hd + 4)
        assert eng_q.num_pages == budget // eng_q.page_footprint_bytes()
        assert eng_f.num_pages == budget // eng_f.page_footprint_bytes()
        assert eng_q.num_pages > eng_f.num_pages


class TestHostSyncFreeTick:
    def test_tick_compiles_exactly_once(self, model_and_params):
        """The fused tick must stay ONE compiled program across admissions,
        slot reuse, EOS exits, and hundreds of ticks."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        prompts = _prompts(cfg, 6)
        eng.run([Request(rid=i, prompt=p, max_new_tokens=4 + i % 3)
                 for i, p in enumerate(prompts)])
        assert eng.tick_count > 5
        assert eng.trace_count == 1

    def test_tick_loop_is_transfer_free(self, model_and_params):
        """Zero host transfers inside the tick loop: steps run under a
        disallow-all transfer guard (warmup outside the guard pays the
        one-time compile)."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        eng.add_request(Request(rid=0, prompt=[3, 5, 7],
                                max_new_tokens=50))
        eng.step()                       # compile outside the guard
        with jax.transfer_guard("disallow"):
            for _ in range(10):
                eng.step()
        eng.sync()
        assert len(eng.slots[0].generated) >= 11


class TestSSDDecodeServe:
    """ISSUE 9 tentpole: the mamba decode tick routed through the fused
    ``ssd_decode`` kernel must emit token-for-token what the jnp einsum
    trio emits, while the engine's tick stays ONE compiled program with
    zero per-tick host transfers."""

    def _cfg(self):
        from repro.models.config import SSMConfig
        return ModelConfig(name="t", family="ssm", num_layers=2,
                           d_model=64, num_heads=0, num_kv_heads=0, d_ff=0,
                           vocab_size=128, dtype="float32",
                           ssm=SSMConfig(state_dim=16, head_dim=16,
                                         chunk_size=8), subquadratic=True)

    def _run(self, model, params, prompts, max_news):
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        done = eng.run([Request(rid=i, prompt=p, max_new_tokens=m)
                        for i, (p, m) in enumerate(zip(prompts,
                                                       max_news))])
        return {r.rid: r.generated for r in done}, eng

    def test_fused_decode_tick_matches_jnp_recurrence(self):
        """Oversubscribed (4 requests, 2 slots, mid-stream admission):
        the fused-ssd-decode engine and the library engine emit identical
        token streams, and the fused tick compiles exactly once."""
        cfg = self._cfg()
        lib = build_model(cfg, ParallelConfig(remat="none"))
        fused = build_model(cfg, ParallelConfig(remat="none",
                                                fuse_epilogues=True))
        assert fused.policy.fuses() and not lib.policy.fuses()
        params = lib.init_params(KEY)
        prompts = _prompts(cfg, 4)
        max_news = [4, 7, 5, 6]
        want, _ = self._run(lib, params, prompts, max_news)
        got, eng = self._run(fused, params, prompts, max_news)
        assert len(got) == 4
        assert got == want
        assert eng.trace_count == 1          # still ONE tick program

    def test_fused_mamba_tick_is_transfer_free(self):
        """The [B,G,Hg,N,P] state never leaves the device between ticks:
        steps run under a disallow-all transfer guard with the fused
        recurrence inside the one compiled program."""
        cfg = self._cfg()
        fused = build_model(cfg, ParallelConfig(remat="none",
                                                fuse_epilogues=True))
        params = fused.init_params(KEY)
        eng = BatchedEngine(fused, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1))
        eng.add_request(Request(rid=0, prompt=[3, 5, 7],
                                max_new_tokens=30))
        eng.step()                       # compile outside the guard
        with jax.transfer_guard("disallow"):
            for _ in range(10):
                eng.step()
        eng.sync()
        assert len(eng.slots[0].generated) >= 11
        assert eng.trace_count == 1


class TestAdmissionBugfixes:
    """ISSUE 9 satellites: a never-admittable request is rejected instead
    of livelocking run(), and frontier_pages uses ceil semantics at page
    boundaries."""

    PAGE = 8

    def test_oversized_request_rejected_not_livelocked(
            self, model_and_params):
        """A request whose page reservation exceeds the pool's TOTAL is
        marked done/rejected at admission; the rest of the stream is
        served normally and run() returns promptly instead of burning
        masked ticks to max_ticks."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, num_pages=2))
        # reserve = ceil((3 + 20 - 1) / 8) = 3 > 2 total pages
        big = Request(rid=0, prompt=[3, 5, 7], max_new_tokens=20)
        ok = Request(rid=1, prompt=[2, 4, 6], max_new_tokens=4)
        done = eng.run([big, ok])
        assert big.rejected and big.done and big.generated == []
        assert big.slot is None              # never occupied a slot
        assert not ok.rejected and ok.done
        assert ok.generated == sequential_decode(model, params, ok.prompt,
                                                 4, eos=-1)
        assert {r.rid for r in done} == {0, 1}
        assert eng.tick_count < 100          # bounded by real work

    def test_all_unadmittable_returns_without_ticking(
            self, model_and_params):
        """The pure livelock case: nothing active, nothing admissible —
        run() must return immediately, not spin to max_ticks."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=2, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE, num_pages=1))
        big = Request(rid=0, prompt=[3, 5, 7], max_new_tokens=20)
        done = eng.run([big])
        assert big.rejected and big.done
        assert eng.tick_count == 0
        assert [r.rid for r in done] == [0]

    def test_frontier_pages_exact_on_page_boundary(self, model_and_params):
        """A frontier landing exactly on a page boundary (pos == k·ps)
        has written k pages — the stats row must say k, not k+1 (the
        pre-fix floor+1 overcount)."""
        model, params, cfg = model_and_params
        eng = BatchedEngine(model, params, ServeConfig(
            batch_slots=1, max_seq_len=CACHE_LEN, eos_id=-1,
            page_size=self.PAGE))
        prompt = _prompts(cfg, 1)[0] * 3     # 9 tokens
        eng.add_request(Request(rid=0, prompt=prompt[:7],
                                max_new_tokens=12))
        eng.step()                           # pos: 7 -> 8 == page_size
        eng.sync()
        assert eng.tick_stats[0]["frontier_pages"] == 1
