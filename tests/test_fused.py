"""Fused-epilogue lowerings (ISSUE 3 tentpole).

Pins: numerical equivalence of the fused ops to their unfused pairs
(same tolerance discipline as test_registry.py), the exact one-activation
-round-trip HBM saving in the registered structural costs, auto selection
across dialects, the declared (warned + recorded) fallbacks, policy-gated
model routing, and the fused rows in the committed bench artifact.
"""
import json
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ExecutionPolicy, IsaMode, LoweringFallbackWarning,
                        REGISTRY, TARGET, UISA_UNIVERSAL10)
from repro.kernels import ops, ref
from repro.kernels.fused import FUSED_OPS

KEY = jax.random.PRNGKey(11)
ALL_MODES = ("abstract", "abstract+shuffle", "native", "library")


def _inputs(rows=33, d=200, n=96):
    ka, kb, kc, kd = jax.random.split(KEY, 4)
    x = jax.random.normal(ka, (rows, d), jnp.float32)
    w = jax.random.normal(kb, (d,), jnp.float32) + 1.0
    p = jax.random.normal(kc, (d, n), jnp.float32)
    r = jax.random.normal(kd, (rows, d), jnp.float32)
    return x, w, p, r


# ---------------------------------------------------------------------------
# Numerical equivalence to the unfused pair
# ---------------------------------------------------------------------------


class TestNumerics:
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_rmsnorm_matmul_matches_unfused_pair(self, mode):
        x, w, p, _ = _inputs()
        want = jnp.einsum("rd,dn->rn", ref.rmsnorm(x, w), p)
        got = ops.fused_rmsnorm_matmul(x, w, p, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_add_rmsnorm_matches_unfused_pair(self, mode):
        x, w, _, r = _inputs()
        want_s = x + r
        want_h = ref.rmsnorm(want_s, w)
        h, s = ops.fused_add_rmsnorm(x, r, w, mode=mode)
        np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h), np.asarray(want_h),
                                   rtol=1e-4, atol=1e-4)

    def test_leading_batch_dims(self):
        x, w, p, _ = _inputs(rows=6, d=128, n=64)
        x3 = x.reshape(2, 3, 128)
        got = ops.fused_rmsnorm_matmul(x3, w, p, mode="native")
        assert got.shape == (2, 3, 64)
        want = jnp.einsum("bsd,dn->bsn", ref.rmsnorm(x3, w), p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("causal", (True, False))
    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_flash_attention_matmul_matches_unfused_pair(self, mode,
                                                         causal):
        """True GQA (un-repeated [B,Hkv,S,D] cache, folded by the kernel's
        index maps) + ragged seq (padded kv must stay masked when the
        causal mask is off) + ragged wo width: the fused flash→wo output
        equals flash attention followed by the wo einsum."""
        ks = jax.random.split(KEY, 4)
        b, h, hkv, s, d, n = 2, 4, 2, 96, 32, 80
        q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
        wo = jax.random.normal(ks[3], (h * d, n), jnp.float32)
        o = ref.attention(q, jnp.repeat(k, h // hkv, axis=1),
                          jnp.repeat(v, h // hkv, axis=1), causal=causal)
        want = jnp.einsum("bsh,hn->bsn",
                          o.transpose(0, 2, 1, 3).reshape(b, s, h * d), wo)
        got = ops.fused_flash_attention_matmul(q, k, v, wo, causal=causal,
                                               mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("mode",
                             ("abstract", "abstract+shuffle", "native"))
    def test_flash_attention_noncausal_padded_kv_masked(self, mode):
        """Regression (found by review): with causal=False and skv not a
        block multiple, the zero-padded keys must not receive softmax
        weight — the causal mask that normally hides the pad is off."""
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (1, 2, 96, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 96, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 96, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=False, mode=mode)
        want = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)

    def test_flash_attention_matmul_bf16_cross_head_accumulation(self):
        """The cross-head sum runs in an f32 VMEM scratch with ONE final
        output-dtype cast — bf16 outputs must match the unfused bf16
        pair without per-head rounding drift even with many heads."""
        ks = jax.random.split(KEY, 4)
        b, h, s, d, n = 1, 8, 64, 32, 64
        q = jax.random.normal(ks[0], (b, h, s, d)).astype(jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, h, s, d)).astype(jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, h, s, d)).astype(jnp.bfloat16)
        wo = jax.random.normal(ks[3], (h * d, n)).astype(jnp.bfloat16)
        want = ops.fused_flash_attention_matmul(q, k, v, wo, causal=True,
                                                mode="library")
        got = ops.fused_flash_attention_matmul(q, k, v, wo, causal=True,
                                               mode="native")
        assert got.dtype == jnp.bfloat16
        # bf16 carries ~8 mantissa bits: both routes round their inputs
        # and outputs to bf16, so the bound is bf16-relative
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=3e-2, atol=3e-1)

    @pytest.mark.parametrize("mode", ALL_MODES)
    def test_rmsnorm_swiglu_matches_unfused_pair(self, mode):
        x, w, _, _ = _inputs()
        f = 96
        w_cat = jax.random.normal(jax.random.fold_in(KEY, 2),
                                  (x.shape[-1], 2 * f), jnp.float32)
        y = ref.rmsnorm(x, w)
        want = jax.nn.silu(y @ w_cat[:, f:]) * (y @ w_cat[:, :f])
        got = ops.fused_rmsnorm_swiglu(x, w, w_cat, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# The acceptance criterion: exactly one activation round trip saved
# ---------------------------------------------------------------------------


class TestStructuralCost:
    @pytest.mark.parametrize("mode",
                             ("abstract", "abstract+shuffle", "native"))
    def test_rmsnorm_matmul_saves_exactly_one_round_trip(self, mode):
        rows, d, n = 1024, 1024, 512
        itemsize = 4
        fused = REGISTRY.structural_cost("rmsnorm_matmul", mode,
                                         rows=rows, d=d, n=n)
        norm = REGISTRY.structural_cost("rmsnorm", mode, rows=rows, d=d)
        proj = REGISTRY.structural_cost(
            "gemm", mode if mode != "abstract+shuffle" else "abstract",
            m=rows, n=n, k=d)
        unfused_sum = norm["hbm_bytes"] + proj["hbm_bytes"]
        round_trip = 2 * rows * d * itemsize     # write + read-back
        assert fused["hbm_bytes"] == unfused_sum - round_trip
        assert fused["hbm_bytes_saved"] == round_trip

    def test_library_row_is_the_unfused_pair(self):
        cost = REGISTRY.structural_cost("rmsnorm_matmul", "library",
                                        rows=256, d=256, n=256)
        assert cost["hbm_bytes_saved"] == 0
        assert cost["hbm_bytes"] == cost["hbm_bytes_unfused_pair"]

    @pytest.mark.parametrize("mode",
                             ("abstract", "abstract+shuffle", "native"))
    def test_add_rmsnorm_saves_the_readback_leg(self, mode):
        rows, d = 512, 1024
        cost = REGISTRY.structural_cost("add_rmsnorm", mode,
                                        rows=rows, d=d)
        # honest asymmetry: the write leg survives as the residual
        # stream's own output, only the norm's read-back disappears
        assert cost["hbm_bytes_saved"] == rows * d * 4
        assert cost["hbm_bytes"] == \
            cost["hbm_bytes_unfused_pair"] - rows * d * 4

    @pytest.mark.parametrize("mode",
                             ("abstract", "abstract+shuffle", "native"))
    def test_flash_attention_matmul_saves_one_activation_round_trip(
            self, mode):
        """The acceptance pin: hbm delta == exactly one [B,S,H,D] trip."""
        shape = dict(b=2, h=8, sq=1024, skv=1024, d=64, n=512, causal=True)
        cost = REGISTRY.structural_cost("flash_attention_matmul", mode,
                                        **shape)
        round_trip = 2 * 2 * 1024 * 8 * 64 * 4     # write + read-back
        assert cost["hbm_bytes_saved"] == round_trip
        assert cost["hbm_bytes"] == \
            cost["hbm_bytes_unfused_pair"] - round_trip
        att = REGISTRY.structural_cost(
            "flash_attention", mode, b=2, h=8, sq=1024, skv=1024, d=64,
            causal=True)
        proj = REGISTRY.structural_cost(
            "gemm", mode if mode != "abstract+shuffle" else "abstract",
            m=2 * 1024, n=512, k=8 * 64)
        assert cost["hbm_bytes_unfused_pair"] == \
            att["hbm_bytes"] + proj["hbm_bytes"]

    @pytest.mark.parametrize("mode",
                             ("abstract", "abstract+shuffle", "native"))
    def test_rmsnorm_swiglu_saves_exactly_one_round_trip(self, mode):
        rows, d, f = 1024, 1024, 512
        cost = REGISTRY.structural_cost("rmsnorm_swiglu", mode,
                                        rows=rows, d=d, f=f)
        norm = REGISTRY.structural_cost("rmsnorm", mode, rows=rows, d=d)
        proj = REGISTRY.structural_cost(
            "gemm", mode if mode != "abstract+shuffle" else "abstract",
            m=rows, n=2 * f, k=d)
        round_trip = 2 * rows * d * 4              # write + read-back
        assert cost["hbm_bytes_saved"] == round_trip
        assert cost["hbm_bytes"] == \
            norm["hbm_bytes"] + proj["hbm_bytes"] - round_trip

    def test_new_fused_library_rows_are_the_unfused_pairs(self):
        for op, shape in (
                ("flash_attention_matmul",
                 dict(b=1, h=2, sq=256, skv=256, d=64, n=128, causal=True)),
                ("rmsnorm_swiglu", dict(rows=256, d=256, f=256))):
            cost = REGISTRY.structural_cost(op, "library", **shape)
            assert cost["hbm_bytes_saved"] == 0
            assert cost["hbm_bytes"] == cost["hbm_bytes_unfused_pair"]

    def test_shuffle_variant_structurally_cheapest(self):
        """The §VII.C ordering holds for the fused ops too: zero scratch
        for the shuffle moment tree, round-trips for the abstract one."""
        shape = dict(rows=1024, d=1024, n=512)
        ab = REGISTRY.structural_cost("rmsnorm_matmul", "abstract", **shape)
        sh = REGISTRY.structural_cost("rmsnorm_matmul", "abstract+shuffle",
                                      **shape)
        assert ab["scratch_bytes_total"] > 0
        assert sh["scratch_bytes_total"] == 0
        assert sh["lane_shuffles_per_block"] > 0


# ---------------------------------------------------------------------------
# Auto selection + declared fallbacks
# ---------------------------------------------------------------------------


class TestDispatch:
    def test_auto_picks_shuffle_on_target(self):
        pol = ExecutionPolicy(mode="auto", dialect=TARGET.name)
        for op in FUSED_OPS:
            low = REGISTRY.select(op, pol, shape=ops.PROBE_SHAPES[op])
            assert low.mode is IsaMode.ABSTRACT_SHUFFLE, (op, low.mode)

    def test_auto_degrades_to_scratch_tree_without_shuffle(self):
        pol = ExecutionPolicy(mode="auto", dialect=UISA_UNIVERSAL10.name)
        for op in FUSED_OPS:
            low = REGISTRY.select(op, pol, shape=ops.PROBE_SHAPES[op])
            assert low.mode is IsaMode.ABSTRACT, (op, low.mode)

    def test_new_ops_declare_both_fallbacks(self):
        for op in ("flash_attention_matmul", "rmsnorm_swiglu"):
            fb = REGISTRY.fallback_for(op, IsaMode.ABSTRACT_SHUFFLE)
            assert fb is not None and fb.to is IsaMode.ABSTRACT
            fb = REGISTRY.fallback_for(op, IsaMode.NATIVE)
            assert fb is not None and fb.to is IsaMode.LIBRARY

    def test_shuffle_request_falls_back_declared(self):
        """abstract+shuffle on a no-shuffle dialect: warned + recorded,
        lands on the fused scratch-tree variant (never silent)."""
        x, w, p, _ = _inputs()
        n0 = len(REGISTRY.fallback_events)
        pol = ExecutionPolicy(mode="abstract+shuffle",
                              dialect=UISA_UNIVERSAL10.name)
        with pytest.warns(LoweringFallbackWarning):
            got = ops.fused_rmsnorm_matmul(x, w, p, policy=pol)
        ev = REGISTRY.fallback_events[n0]
        assert (ev.op, ev.requested, ev.used) == \
            ("rmsnorm_matmul", "abstract+shuffle", "abstract")
        want = jnp.einsum("rd,dn->rn", ref.rmsnorm(x, w), p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_native_request_falls_back_to_unfused_pair(self):
        """native on a foreign dialect: the declared escape is the
        library row, which IS the unfused jnp pair."""
        x, _, _, r = _inputs()
        w = jnp.ones((x.shape[-1],), jnp.float32)
        n0 = len(REGISTRY.fallback_events)
        pol = ExecutionPolicy(mode="native", dialect="nvidia-ada-sm89")
        with pytest.warns(LoweringFallbackWarning):
            h, s = ops.fused_add_rmsnorm(x, r, w, policy=pol)
        ev = REGISTRY.fallback_events[n0]
        assert (ev.op, ev.requested, ev.used) == \
            ("add_rmsnorm", "native", "library")
        np.testing.assert_allclose(np.asarray(s), np.asarray(x + r),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Policy-gated model routing
# ---------------------------------------------------------------------------


def _tiny_model(**par_kw):
    from repro.models.config import ModelConfig, ParallelConfig
    from repro.models.transformer import TransformerLM
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=128,
                      qk_norm=True, dtype="float32")
    return TransformerLM(cfg, ParallelConfig(remat="none", **par_kw))


class TestModelRouting:
    def test_forced_fusion_selects_a_fused_lowering(self):
        """fuse_epilogues=True under the default (library-norm) policy
        must dispatch a fused Pallas variant through the kernel view —
        not silently land on the library row (the unfused pair)."""
        pol = _tiny_model(fuse_epilogues=True).policy
        assert pol.fuses()
        low = REGISTRY.select("rmsnorm_matmul", pol.kernel(),
                              shape=ops.PROBE_SHAPES["rmsnorm_matmul"])
        assert low.mode is not IsaMode.LIBRARY, low.mode

    def test_fuse_gate_default_follows_auto(self):
        assert _tiny_model().policy.fuses() is False
        assert _tiny_model(isa_mode="auto").policy.fuses() is True
        assert _tiny_model(isa_mode="auto",
                           fuse_epilogues=False).policy.fuses() is False
        assert _tiny_model(fuse_epilogues=True).policy.fuses() is True

    def test_fused_model_matches_reference(self):
        batch = {"tokens": jnp.arange(32).reshape(2, 16) % 128,
                 "labels": jnp.arange(32).reshape(2, 16) % 128}
        ref_model = _tiny_model()
        params = ref_model.init_params(jax.random.PRNGKey(0))
        want, _ = ref_model.loss_fn(params, batch)
        for kw in (dict(isa_mode="auto"), dict(fuse_epilogues=True),
                   # the flash→wo fused epilogue path (attn_seq)
                   dict(fuse_epilogues=True, use_pallas_attn=True),
                   dict(isa_mode="abstract", fuse_epilogues=True)):
            model = _tiny_model(**kw)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", LoweringFallbackWarning)
                got, _ = model.loss_fn(params, batch)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-4, atol=1e-4)

    def test_fused_decode_matches_reference(self):
        batch = {"tokens": jnp.arange(16).reshape(2, 8) % 128}
        ref_model = _tiny_model()
        params = ref_model.init_params(jax.random.PRNGKey(1))
        logits_ref, cache_ref = ref_model.prefill(params, batch)
        step_ref, _ = ref_model.decode_step(
            params, jnp.argmax(logits_ref, -1).astype(jnp.int32), cache_ref)
        fused = _tiny_model(fuse_epilogues=True)
        logits_f, cache_f = fused.prefill(params, batch)
        np.testing.assert_allclose(np.asarray(logits_f),
                                   np.asarray(logits_ref),
                                   rtol=1e-3, atol=1e-3)
        step_f, _ = fused.decode_step(
            params, jnp.argmax(logits_f, -1).astype(jnp.int32), cache_f)
        np.testing.assert_allclose(np.asarray(step_f),
                                   np.asarray(step_ref),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# The committed bench artifact carries the fused rows + the gate is green
# ---------------------------------------------------------------------------


class TestBenchArtifact:
    def test_fused_rows_present_and_gate_green(self):
        root = pathlib.Path(__file__).resolve().parent.parent
        data = json.loads((root / "BENCH_kernels.json").read_text())
        by_kernel = {}
        for row in data["rows"]:
            by_kernel.setdefault(row["kernel"], set()).add(row["mode"])
        assert {"abstract", "abstract+shuffle", "native", "library"} <= \
            by_kernel.get("rmsnorm_matmul", set())
        assert {"abstract", "abstract+shuffle", "native", "library"} <= \
            by_kernel.get("add_rmsnorm", set())
        # the --compare gate against itself (coverage + structural
        # recompute at the committed shapes) must be green
        import sys
        sys.path.insert(0, str(root))
        try:
            from benchmarks.bench_kernels import compare
        finally:
            sys.path.pop(0)
        assert compare(data, data) == []
