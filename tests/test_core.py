"""UISA core: dialects, primitives, contracts, execution model, mapping.

Covers paper Tables I-IV and Eq. 1, plus hypothesis property tests on the
invariants the core layer enforces.
"""
import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.core import (Classification, ContractViolation, DIALECTS,
                        Dialect, IsaMode, KernelContract, LaunchError,
                        LaunchGeometry, Primitive, SPECS, TARGET,
                        UNIVERSAL_PLUS_SHUFFLE, UNIVERSAL_SET,
                        choose_block_bytes, get_dialect, gpu_dialects,
                        occupancy, validate_contract, validate_launch)
from repro.core import mapping
from repro.core.dialect import REGISTER_WIDTH_BYTES
from repro.core.memory_model import (MANDATORY_HIERARCHY, Ordering, Scope,
                                     fence, requires_fence)


# ---------------------------------------------------------------------------
# Table II / III audit
# ---------------------------------------------------------------------------


class TestDialects:
    def test_four_gpu_vendors_plus_tpu(self):
        assert {d.vendor for d in gpu_dialects()} == {
            "NVIDIA", "AMD", "Intel", "Apple"}
        assert "tpu-v5e" in DIALECTS

    def test_paper_table_iii_parameters(self):
        nv = get_dialect("nvidia-ada-sm89")
        assert nv.W == 32 and nv.R == 255 and nv.named_barriers == 16
        amd = get_dialect("amd-rdna3")
        assert amd.wave_width == (32, 64)
        intel = get_dialect("intel-xe-hpg")
        assert intel.wave_width == (8, 16) and intel.S == 512 * 1024
        apple = get_dialect("apple-g13")
        assert not apple.native_fp64 and apple.matrix_unit is None

    def test_every_vendor_implements_shuffle(self):
        # §VII.C: "all four vendors already implement shuffle in hardware"
        for d in gpu_dialects():
            assert d.has_lane_shuffle

    def test_query_api(self):
        assert TARGET.query("W") == 128
        assert TARGET.query("matrix_tile") == (128, 128, 128)
        with pytest.raises(KeyError):
            TARGET.query("nonexistent")

    def test_max_workgroup_uniform_1024(self):
        for d in gpu_dialects():
            assert d.max_workgroup == 1024


class TestOccupancyEq1:
    def test_eq1_nvidia_example(self):
        nv = get_dialect("nvidia-ada-sm89")
        # 256KB regfile, 32 regs x 32 lanes x 4B = 4KB per wave -> 64 waves
        assert nv.occupancy(32) == 64

    def test_zero_when_over_register_budget(self):
        nv = get_dialect("nvidia-ada-sm89")
        assert nv.occupancy(256) == 0        # R=255

    @given(regs=st.integers(1, 255), width=st.sampled_from([8, 16, 32, 64]))
    @settings(max_examples=100, deadline=None)
    def test_eq1_property(self, regs, width):
        """O = floor(F/(R·W·w)) exactly, for every dialect (Eq. 1)."""
        for d in gpu_dialects():
            o = d.occupancy(regs, wave_width=width)
            if regs > d.R:
                assert o == 0
            else:
                assert o == d.F // (regs * width * REGISTER_WIDTH_BYTES)

    @given(regs=st.integers(1, 128))
    @settings(max_examples=50, deadline=None)
    def test_occupancy_monotone_in_registers(self, regs):
        """More registers per thread never increases occupancy."""
        for d in gpu_dialects():
            if regs + 1 <= d.R:
                assert d.occupancy(regs) >= d.occupancy(regs + 1)

    @given(block=st.integers(1, 1 << 24), bufs=st.integers(1, 4))
    @settings(max_examples=100, deadline=None)
    def test_buffer_occupancy_tpu_rederivation(self, block, bufs):
        o = TARGET.buffer_occupancy(block, bufs)
        assert o == TARGET.S // (bufs * block)
        # same fixed-area algebra: occupancy x demand <= budget
        assert o * bufs * block <= TARGET.S


class TestLaunchValidation:
    def test_valid_launch(self):
        validate_launch(LaunchGeometry(grid=(8, 8), workgroup=256),
                        get_dialect("nvidia-ada-sm89"))

    def test_rejects_oversized_workgroup(self):
        with pytest.raises(LaunchError):
            validate_launch(LaunchGeometry(grid=(1,), workgroup=2048),
                            get_dialect("nvidia-ada-sm89"))

    def test_rejects_scratchpad_overflow(self):
        d = get_dialect("apple-g13")
        with pytest.raises(LaunchError):
            validate_launch(
                LaunchGeometry(grid=(1,), workgroup=64,
                               scratchpad_bytes=d.S + 1), d)

    @given(wg=st.integers(1, 1024), regs=st.integers(1, 64),
           scratch=st.integers(0, 32 * 1024))
    @settings(max_examples=50, deadline=None)
    def test_valid_geometries_have_nonneg_occupancy(self, wg, regs, scratch):
        d = get_dialect("amd-rdna3")
        geom = LaunchGeometry(grid=(4,), workgroup=wg,
                              regs_per_thread=regs,
                              scratchpad_bytes=scratch)
        validate_launch(geom, d)
        assert occupancy(geom, d) >= 0


# ---------------------------------------------------------------------------
# Primitives + contracts (the Table V methodology enforcement)
# ---------------------------------------------------------------------------


class TestPrimitives:
    def test_ten_plus_shuffle(self):
        assert len(UNIVERSAL_SET) == 10
        assert len(UNIVERSAL_PLUS_SHUFFLE) == 11
        assert Primitive.LANE_SHUFFLE not in UNIVERSAL_SET

    def test_every_primitive_has_four_vendor_realizations(self):
        for prim, spec in SPECS.items():
            assert set(spec.vendor_realization) == {
                "NVIDIA", "AMD", "Intel", "Apple"}, prim

    def test_tpu_divergences_flagged(self):
        # zero-cost switch and HW atomics do not transfer (DESIGN.md §2)
        assert not SPECS[Primitive.ZERO_COST_SWITCH].tpu_direct
        assert not SPECS[Primitive.ATOMIC_RMW].tpu_direct

    def test_abstract_mode_budget(self):
        assert IsaMode.ABSTRACT.allowed == UNIVERSAL_SET
        assert IsaMode.ABSTRACT_SHUFFLE.allowed == UNIVERSAL_PLUS_SHUFFLE


class TestContracts:
    def test_abstract_cannot_use_shuffle(self):
        with pytest.raises(ContractViolation):
            validate_contract(KernelContract(
                kernel="x", mode=IsaMode.ABSTRACT,
                primitives=frozenset({Primitive.LANE_SHUFFLE})))

    def test_abstract_cannot_use_native_features(self):
        with pytest.raises(ContractViolation):
            validate_contract(KernelContract(
                kernel="x", mode=IsaMode.ABSTRACT,
                primitives=frozenset({Primitive.LOCKSTEP_GROUP}),
                native_features=frozenset({"mxu_aligned_tiles"})))

    def test_unknown_native_feature_rejected(self):
        with pytest.raises(ValueError):
            KernelContract(kernel="x", mode=IsaMode.NATIVE,
                           primitives=frozenset(),
                           native_features=frozenset({"warp_shuffle"}))

    def test_atomics_on_tpu_require_privatize_reduce(self):
        # claiming ATOMIC_RMW without scratchpad+barrier must fail on TPU
        with pytest.raises(ContractViolation):
            validate_contract(KernelContract(
                kernel="x", mode=IsaMode.NATIVE,
                primitives=frozenset({Primitive.ATOMIC_RMW})))

    def test_all_shipped_contracts_validate(self):
        from repro.kernels.ops import CONTRACTS
        for kernel, contracts in CONTRACTS.items():
            for c in contracts:
                validate_contract(c)    # must not raise

    @given(prims=st.sets(st.sampled_from(list(Primitive))))
    @settings(max_examples=100, deadline=None)
    def test_contract_validation_is_exact(self, prims):
        """A contract passes iff its primitives fit the mode budget (and
        TPU-divergent primitives carry their required companions)."""
        prims = frozenset(prims)
        c = KernelContract(kernel="p", mode=IsaMode.ABSTRACT,
                           primitives=prims)
        legal = prims <= IsaMode.ABSTRACT.allowed
        if Primitive.ATOMIC_RMW in prims:
            legal = legal and {Primitive.MANAGED_SCRATCHPAD,
                               Primitive.WORKGROUP_BARRIER} <= prims
        try:
            validate_contract(c)
            assert legal
        except ContractViolation:
            assert not legal


# ---------------------------------------------------------------------------
# Memory model
# ---------------------------------------------------------------------------


class TestMemoryModel:
    def test_three_mandatory_levels(self):
        assert len(MANDATORY_HIERARCHY) == 3       # Table IV resolution

    def test_scope_ordering(self):
        assert Scope.WAVE.rank < Scope.WORKGROUP.rank \
            < Scope.DEVICE.rank < Scope.SYSTEM.rank

    def test_fence_accepts_all_scopes(self):
        for s in Scope:
            for o in Ordering:
                fence(s, o)                        # auditable no-op

    def test_wave_local_needs_no_fence(self):
        assert not requires_fence(Scope.WAVE, Scope.WAVE)
        assert requires_fence(Scope.WORKGROUP, Scope.WAVE)
        assert requires_fence(Scope.WAVE, Scope.SYSTEM)


# ---------------------------------------------------------------------------
# Mapping report (Fig. 3)
# ---------------------------------------------------------------------------


class TestMapping:
    def test_full_report_renders(self):
        report = mapping.full_report()
        for needle in ("NVIDIA", "AMD", "Intel", "Apple",
                       "LANE_SHUFFLE", "ADAPTED"):
            assert needle in report

    def test_dialect_table_has_tpu_column(self):
        assert "Google" in mapping.dialect_table()
