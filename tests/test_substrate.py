"""Substrate tests: optimizer, data pipeline, checkpointing, train loop
fault tolerance, serving engine."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dev dep (requirements-dev.txt)
    from _hypothesis_stub import given, settings, st

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig
from repro.parallel import compress
from repro.serve import BatchedEngine, Request, ServeConfig
from repro.train import (OptConfig, adamw_update, build_train_step,
                         init_opt_state, lr_at_step)
from repro.train.loop import (LoopConfig, StragglerMonitor, resume_or_init,
                              train_loop)

KEY = jax.random.PRNGKey(0)


def tiny_model(dtype="float32", **kw):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=256, dtype=dtype)
    return build_model(cfg, ParallelConfig(remat="none", **kw)), cfg


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                        min_lr_ratio=0.1)
        assert float(lr_at_step(cfg, 0)) == 0.0
        np.testing.assert_allclose(float(lr_at_step(cfg, 10)), 1e-3,
                                   rtol=1e-5)
        assert float(lr_at_step(cfg, 100)) == pytest.approx(1e-4, rel=1e-4)
        # monotone decay after warmup
        lrs = [float(lr_at_step(cfg, s)) for s in range(10, 101, 10)]
        assert all(a >= b for a, b in zip(lrs, lrs[1:]))

    def test_adamw_descends_quadratic(self):
        cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.array([5.0, -3.0])}
        state = init_opt_state(params, cfg)
        for _ in range(60):
            grads = {"w": params["w"]}        # d/dw (w²/2)
            params, state, stats = adamw_update(grads, state, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_grad_clipping(self):
        cfg = OptConfig(grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros(4)}
        state = init_opt_state(params, cfg)
        grads = {"w": jnp.full((4,), 100.0)}
        _, _, stats = adamw_update(grads, state, params, cfg)
        assert float(stats["grad_norm"]) == pytest.approx(200.0)
        assert float(stats["clip_factor"]) == pytest.approx(1 / 200.0)

    def test_int8_ef_residual_carries(self):
        """Error feedback: quantization residual rides in state['ef'] and
        the accumulated update converges to the true gradient signal."""
        cfg = OptConfig(lr=0.01, warmup_steps=0, compression="int8_ef",
                        weight_decay=0.0, grad_clip=1e9)
        params = {"w": jnp.array([1.0])}
        state = init_opt_state(params, cfg)
        assert "ef" in state
        # constant tiny gradient that always quantizes to 0 alone
        for _ in range(5):
            grads = {"w": jnp.array([1e-10])}
            params, state, _ = adamw_update(grads, state, params, cfg)
        # residual must accumulate rather than be dropped
        assert float(jnp.abs(state["ef"]["w"])[0]) >= 0.0

    def test_master_weights_are_fp32_copies(self):
        model, _ = tiny_model(dtype="bfloat16")
        params = model.init_params(KEY)
        state = init_opt_state(params, OptConfig())
        for m, p in zip(jax.tree.leaves(state["master"]),
                        jax.tree.leaves(params)):
            assert m.dtype == jnp.float32
            assert m.shape == p.shape

    @given(step=st.integers(0, 10000))
    @settings(max_examples=50, deadline=None)
    def test_lr_always_in_range(self, step):
        cfg = OptConfig(lr=3e-4, warmup_steps=200, total_steps=10000)
        lr = float(lr_at_step(cfg, step))
        assert 0.0 <= lr <= cfg.lr * (1 + 1e-6)


class TestCompression:
    @given(scale=st.floats(1e-6, 1e3))
    @settings(max_examples=30, deadline=None)
    def test_int8_roundtrip_error_bound(self, scale):
        g = jax.random.normal(KEY, (256,)) * scale
        q, s = compress.quantize_int8(g)
        deq = compress.dequantize_int8(q, s)
        max_err = float(jnp.max(jnp.abs(deq - g)))
        assert max_err <= float(s) * 0.5 + 1e-9   # half-step rounding

    def test_int8_wire_dtype(self):
        q, _ = compress.quantize_int8(jax.random.normal(KEY, (64,)))
        assert q.dtype == jnp.int8


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def _cfg(self, **kw):
        d = dict(global_batch=4, seq_len=16, vocab_size=1000, seed=7)
        d.update(kw)
        return DataConfig(**d)

    def test_deterministic_by_step(self):
        ds1 = SyntheticLMDataset(self._cfg())
        ds2 = SyntheticLMDataset(self._cfg())
        b1, b2 = ds1.batch_at(5), ds2.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(ds1.batch_at(5)["tokens"],
                                  ds1.batch_at(6)["tokens"])

    def test_labels_are_next_tokens(self):
        ds = SyntheticLMDataset(self._cfg())
        b = ds.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (4, 16)

    def test_host_sharding_partitions_batch(self):
        full = SyntheticLMDataset(self._cfg(host_count=1)).batch_at(3)
        h0 = SyntheticLMDataset(self._cfg(host_count=2,
                                          host_index=0)).batch_at(3)
        h1 = SyntheticLMDataset(self._cfg(host_count=2,
                                          host_index=1)).batch_at(3)
        np.testing.assert_array_equal(
            np.concatenate([h0["tokens"], h1["tokens"]]), full["tokens"])

    def test_resume_replays_nothing(self):
        ds = SyntheticLMDataset(self._cfg())
        seen = [next(ds)["tokens"] for _ in range(4)]
        state = ds.state()
        ds2 = SyntheticLMDataset(self._cfg())
        ds2.restore(state)
        nxt = next(ds2)["tokens"]
        assert not any(np.array_equal(nxt, s) for s in seen)
        np.testing.assert_array_equal(nxt, ds.batch_at(4)["tokens"])

    def test_prefetch_thread_matches_sync(self):
        ds = SyntheticLMDataset(self._cfg()).start()
        try:
            got = [next(ds)["tokens"] for _ in range(3)]
        finally:
            ds.stop()
        want = [SyntheticLMDataset(self._cfg()).batch_at(i)
                for i in range(3)]
        for g, w in zip(got, want):
            np.testing.assert_array_equal(g, w["tokens"])

    def test_token_distribution_is_skewed(self):
        """Zipf-ish skew: low ids more frequent than high ids."""
        ds = SyntheticLMDataset(self._cfg(global_batch=64, seq_len=128,
                                          vocab_size=1000))
        toks = ds.batch_at(0)["tokens"]
        low = (toks < 100).mean()
        high = (toks >= 900).mean()
        assert low > high * 2


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def _tree(self, x=1.0):
        return {"params": {"w": jnp.full((4, 4), x),
                           "b": jnp.zeros((4,))},
                "opt_state": {"step": jnp.array(3)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(10, self._tree(2.5))
        got = mgr.restore(10, self._tree(0.0))
        np.testing.assert_allclose(got["params"]["w"],
                                   np.full((4, 4), 2.5))
        assert mgr.latest_step() == 10

    def test_atomic_no_tmp_visible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, self._tree())
        assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, keep_period=10)
        for s in (5, 10, 15, 20, 25):
            mgr.save(s, self._tree())
        steps = mgr.all_steps()
        assert 10 in steps and 20 in steps       # keep_period multiples
        assert 25 in steps and 20 in steps       # newest two
        assert 5 not in steps and 15 not in steps

    def test_async_save_lands_after_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(7, self._tree(1.5), blocking=False)
        mgr.wait()
        assert mgr.latest_step() == 7

    def test_restore_missing_leaf_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"a": jnp.zeros(2)})
        with pytest.raises((KeyError, FileNotFoundError)):
            mgr.restore(1, {"a": jnp.zeros(2), "b": jnp.zeros(2)})

    def test_manifest_describes_leaves(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, self._tree(), extra={"note": "hi"})
        man = mgr.manifest(2)
        assert man["extra"]["note"] == "hi"
        assert man["leaves"]["params/w"]["shape"] == [4, 4]


# ---------------------------------------------------------------------------
# Train loop fault tolerance
# ---------------------------------------------------------------------------


class TestTrainLoop:
    def _setup(self, tmp, total=6):
        model, cfg = tiny_model()
        opt_cfg = OptConfig(total_steps=total, warmup_steps=1)
        step_fn, _ = build_train_step(model, opt_cfg)
        step_fn = jax.jit(step_fn)
        params = model.init_params(KEY)
        opt = init_opt_state(params, opt_cfg)
        ds = SyntheticLMDataset(DataConfig(
            global_batch=4, seq_len=16, vocab_size=cfg.vocab_size))
        ckpt = CheckpointManager(tmp)
        put = lambda b: {k: jnp.asarray(v) for k, v in b.items()}
        return model, opt_cfg, step_fn, params, opt, ds, ckpt, put

    def test_checkpoint_restart_continuity(self, tmp_path):
        (model, opt_cfg, step_fn, params, opt, ds, ckpt,
         put) = self._setup(str(tmp_path))
        p1, o1, rep = train_loop(step_fn, params, opt, ds,
                                 LoopConfig(total_steps=4,
                                            checkpoint_every=2,
                                            async_checkpoint=False),
                                 ckpt, batch_put=put)
        assert rep["final_step"] == 4
        # restart: resume_or_init must pick up step 4
        def init_fn():
            p = model.init_params(KEY)
            return p, init_opt_state(p, opt_cfg)
        p2, o2, start = resume_or_init(ckpt, init_fn)
        assert start == 4
        assert int(o2["step"]) == int(o1["step"])
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(p2)[0]),
            np.asarray(jax.tree.leaves(p1)[0]), rtol=1e-6)

    def test_straggler_detection(self):
        mon = StragglerMonitor(factor=2.0, alpha=0.5)
        for _ in range(5):
            mon.observe(0, 0.1)
        assert mon.observe(10, 0.5)             # 5x the EWMA
        assert len(mon.events) == 1
        assert mon.events[0]["slowdown"] > 2.0

    def test_loss_decreases(self, tmp_path):
        (model, opt_cfg, step_fn, params, opt, ds, ckpt,
         put) = self._setup(str(tmp_path), total=30)
        _, _, rep = train_loop(step_fn, params, opt, ds,
                               LoopConfig(total_steps=30,
                                          checkpoint_every=1000,
                                          log_every=1),
                               None, batch_put=put)
        losses = [h["loss"] for h in rep["history"]]
        assert losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------


class TestEngine:
    def _engine(self, slots=2):
        model, cfg = tiny_model()
        params = model.init_params(KEY)
        return BatchedEngine(model, params,
                             ServeConfig(batch_slots=slots, max_seq_len=32,
                                         max_new_tokens=6, eos_id=-1)), cfg

    def test_continuous_batching_serves_more_requests_than_slots(self):
        eng, cfg = self._engine(slots=2)
        reqs = [Request(rid=i, prompt=[3, 5, 7], max_new_tokens=4)
                for i in range(5)]
        done = eng.run(reqs)
        assert len(done) == 5
        assert all(len(r.generated) == 4 for r in done)

    def test_greedy_decode_is_deterministic(self):
        eng1, _ = self._engine()
        eng2, _ = self._engine()
        r1 = Request(rid=0, prompt=[2, 4, 6], max_new_tokens=5)
        r2 = Request(rid=0, prompt=[2, 4, 6], max_new_tokens=5)
        eng1.run([r1])
        eng2.run([r2])
        assert r1.generated == r2.generated

    def test_engine_matches_manual_decode(self):
        """Engine slot-0 output == hand-rolled prefill+decode chain."""
        model, cfg = tiny_model()
        params = model.init_params(KEY)
        eng = BatchedEngine(model, params,
                            ServeConfig(batch_slots=1, max_seq_len=32,
                                        max_new_tokens=4, eos_id=-1))
        req = Request(rid=0, prompt=[3, 5, 7], max_new_tokens=4)
        eng.run([req])

        toks = jnp.array([[3, 5, 7]], jnp.int32)
        logits, cache = model.prefill(params, {"tokens": toks})
        full = model.init_cache(1, 32)
        # place prefill kv into capacity cache
        k = jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 0), (0, 29), (0, 0)))
        v = jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 0), (0, 29), (0, 0)))
        cache = {"k": k, "v": v, "pos": cache["pos"]}
        want = [int(jnp.argmax(logits[0]))]
        tok = jnp.array([want[0]], jnp.int32)
        for _ in range(3):
            lg, cache = model.decode_step(params, tok, cache)
            nxt = int(jnp.argmax(lg[0]))
            want.append(nxt)
            tok = jnp.array([nxt], jnp.int32)
        assert req.generated == want
