"""Serving example: continuous batching over more requests than slots.

Loads a reduced assigned architecture (default zamba2 hybrid — the
SSM+attention cache is the interesting one) and pushes a request stream
through the BatchedEngine: admissions, per-tick decode, slot reuse.

  PYTHONPATH=src python examples/serve_batch.py [--arch mamba2-2.7b]
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.models.config import ParallelConfig
from repro.serve import BatchedEngine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"[serve_batch] {cfg.name} ({cfg.family}), "
          f"{args.slots} slots, {args.requests} requests")

    engine = BatchedEngine(model, params, ServeConfig(
        batch_slots=args.slots, max_seq_len=64,
        max_new_tokens=args.max_new, eos_id=-1))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size, 10).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.generated) for r in done)
    print(f"[serve_batch] {len(done)} requests -> {tokens} tokens "
          f"in {dt:.2f}s ({tokens / dt:.1f} tok/s, CPU)")
    for r in done:
        print(f"  rid={r.rid}: {r.generated}")
    assert len(done) == args.requests
    assert all(len(r.generated) == args.max_new for r in done)
    print("[serve_batch] OK — continuous batching over-subscribed "
          f"{args.requests} reqs onto {args.slots} slots")


if __name__ == "__main__":
    main()
