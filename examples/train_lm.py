"""End-to-end driver: train a ~100M-param dense LM for a few hundred
steps on CPU with the full production stack — sharded AdamW, grad
accumulation, checkpointing (async), straggler monitor, resumable data.

~100M params: 12L, d=512, 8H, d_ff=2048, vocab=32000 -> ~115M.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

Interrupt with Ctrl-C and re-run: it resumes from the last checkpoint
(the fault-tolerance path, exercised for real).
"""
import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import build_model
from repro.models.config import ModelConfig, ParallelConfig
from repro.train import OptConfig, build_train_step, init_opt_state
from repro.train.loop import (LoopConfig, PreemptionGuard, resume_or_init,
                              train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/uisa_train_lm")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm-100m", family="dense", num_layers=12, d_model=512,
        num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32000,
        dtype="float32")
    n = cfg.param_count()
    print(f"[train_lm] {cfg.name}: {n / 1e6:.0f}M params")

    par = ParallelConfig(remat="none", grad_accum=2)
    model = build_model(cfg, par)
    opt_cfg = OptConfig(lr=6e-4, total_steps=args.steps,
                        warmup_steps=args.steps // 10)
    step_fn, _ = build_train_step(model, opt_cfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    dataset = SyntheticLMDataset(DataConfig(
        global_batch=args.batch, seq_len=args.seq,
        vocab_size=cfg.vocab_size)).start()
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    def init_fn():
        params = model.init_params(jax.random.PRNGKey(0))
        return params, init_opt_state(params, opt_cfg)

    params, opt_state, start = resume_or_init(ckpt, init_fn)
    if start:
        print(f"[train_lm] resumed at step {start}")

    def sink(step, rec):
        print(f"  step {step:4d}  loss={rec['loss']:.4f}  "
              f"lr={rec['lr']:.2e}  {rec['step_time_s'] * 1e3:.0f} ms"
              + ("  STRAGGLER" if rec.get("straggler") else ""))

    guard = PreemptionGuard()
    params, opt_state, report = train_loop(
        step_fn, params, opt_state, dataset,
        LoopConfig(total_steps=args.steps, checkpoint_every=100,
                   log_every=20),
        ckpt, start_step=start, metrics_sink=sink, preemption=guard,
        batch_put=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    dataset.stop()

    losses = [h["loss"] for h in report["history"]]
    print(f"[train_lm] finished at step {report['final_step']}; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}"
          f"{' (preempted, resumable)' if report['preempted'] else ''}")


if __name__ == "__main__":
    main()
