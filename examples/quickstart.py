"""Quickstart: the UISA layer in five minutes.

1. Query a dialect (never assume W/S/R — paper Table III).
2. Run the paper's three kernels in abstract vs native mode.
3. Check the contract validator rejects an illegal abstract kernel.
4. Build one assigned architecture (reduced) and take a train step.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ContractViolation, IsaMode, KernelContract,
                        Primitive, TARGET, get_dialect, validate_contract)
from repro.kernels import ops
from repro import configs
from repro.models import build_model
from repro.models.config import ParallelConfig
from repro.train import OptConfig, build_train_step, init_opt_state

# ---- 1. queryable dialects ------------------------------------------------
print("== dialects (query, don't assume) ==")
for name in ("nvidia-ada-sm89", "apple-g13", "tpu-v5e"):
    d = get_dialect(name)
    print(f"  {name:18s} W={d.W:<4} S={d.S // 1024:>6} KiB "
          f"matrix_tile={d.query('matrix_tile')}")
print(f"  occupancy(Eq.1) on NVIDIA @32 regs: "
      f"{get_dialect('nvidia-ada-sm89').occupancy(32)} waves/core")
print(f"  TPU buffer-occupancy @4MiB blocks: "
      f"{TARGET.buffer_occupancy(4 << 20)} pipeline stages")

# ---- 2. the Table V kernels -----------------------------------------------
print("\n== Table V kernels: abstract vs native (interpret=True on CPU) ==")
key = jax.random.PRNGKey(0)
a = jax.random.normal(key, (256, 256))
b = jax.random.normal(key, (256, 256))
x = jax.random.normal(key, (100_000,))
v = jax.random.randint(key, (50_000,), 0, 256)

for mode in ("abstract", "native"):
    c = ops.matmul(a, b, mode=mode)
    r = ops.reduce_sum(x, mode=mode)
    h = ops.histogram(v, 256, mode=mode)
    print(f"  [{mode:8s}] gemm={np.asarray(c)[0, 0]:+.3f}  "
          f"sum={float(r):+.1f}  hist[0]={int(h[0])}")
s = ops.reduce_sum(x, mode="abstract+shuffle")
print(f"  [abstract+shuffle] sum={float(s):+.1f}   "
      f"(the paper's 11th-primitive refinement)")

# ---- 3. contracts enforce the methodology ---------------------------------
print("\n== contract validator ==")
try:
    validate_contract(KernelContract(
        kernel="cheater", mode=IsaMode.ABSTRACT,
        primitives=frozenset({Primitive.LANE_SHUFFLE})))
except ContractViolation as e:
    print(f"  rejected as expected: {e}")

# ---- 4. one assigned architecture, one train step --------------------------
print("\n== assigned arch (reduced qwen3-32b), one train step ==")
cfg = configs.get_reduced("qwen3-32b")
model = build_model(cfg, ParallelConfig(remat="none"))
opt_cfg = OptConfig(total_steps=10, warmup_steps=1)
step_fn, _ = build_train_step(model, opt_cfg)
params = model.init_params(jax.random.PRNGKey(0))
opt_state = init_opt_state(params, opt_cfg)
toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
_, _, metrics = jax.jit(step_fn)(params, opt_state,
                                 {"tokens": toks, "labels": toks})
print(f"  loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")
print("\nquickstart OK")
