"""Render the paper's mapping analysis (Fig. 3 + Tables II/III) and the
TPU adaptation table from the live registry.

  PYTHONPATH=src python examples/isa_report.py
"""
from repro.core import mapping

if __name__ == "__main__":
    print(mapping.full_report())
