"""Checkpointing: atomic, async, retention-managed, reshard-on-restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
