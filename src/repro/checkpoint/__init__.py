"""Checkpointing: atomic, async, retention-managed, reshard-on-restore,
parameter-layout migrating (legacy per-matrix <-> fusion-legal concat)."""
from repro.checkpoint.manager import (CheckpointManager, LAYOUT_GROUPS,
                                      layout_of, migrate_layout)

__all__ = ["CheckpointManager", "LAYOUT_GROUPS", "layout_of",
           "migrate_layout"]
