"""Fault-tolerant checkpoint manager.

Production constraints honored (scaled to this container):

- **Atomic commit**: writes land in ``step_<n>.tmp/`` and are renamed to
  ``step_<n>/`` only after every shard file + manifest is fsync'd — a
  preempted save can never be mistaken for a valid checkpoint.
- **Async save**: ``save(..., blocking=False)`` snapshots to host memory
  (device_get) synchronously — the train loop may mutate buffers right
  after — then writes in a background thread (the Orbax pattern).
- **Retention**: keep the newest ``keep`` checkpoints plus every multiple
  of ``keep_period`` (for post-hoc evals).
- **Elastic restore**: ``restore(..., shardings=...)`` device_puts each
  leaf against the *target* sharding tree, which may describe a different
  mesh than the one that saved — restart on 256 chips from a 512-chip
  checkpoint (or vice versa) is a first-class path, not a special case.
- **Self-describing**: a JSON manifest stores the tree structure, leaf
  dtypes/shapes, and the save-time mesh for audit.

Storage is one ``.npy`` per leaf under the step directory (the analogue
of a tensorstore shard per parameter); leaf names are slash-joined tree
paths.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(treedef_template, flat: Dict[str, np.ndarray]):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(
        treedef_template)[0]
    leaves = []
    for path, _ in paths_and_leaves:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    treedef = jax.tree_util.tree_structure(treedef_template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 keep_period: Optional[int] = None):
        self.directory = directory
        self.keep = keep
        self.keep_period = keep_period
        os.makedirs(directory, exist_ok=True)
        self._save_thread: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None

    # ---- paths ----

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- save ----

    def save(self, step: int, tree, *, extra: Optional[dict] = None,
             blocking: bool = True):
        """Snapshot ``tree`` (sync) and write it (async unless blocking)."""
        self.wait()  # one in-flight save at a time
        host_flat = {k: np.asarray(jax.device_get(v))
                     for k, v in _flatten(tree).items()}
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host_flat.items()},
        }
        if blocking:
            self._write(step, host_flat, manifest)
        else:
            self._save_thread = threading.Thread(
                target=self._write_guarded, args=(step, host_flat, manifest),
                daemon=True)
            self._save_thread.start()

    def _write_guarded(self, step, host_flat, manifest):
        try:
            self._write(step, host_flat, manifest)
        except BaseException as e:  # surfaced by wait()
            self._save_error = e

    def _write(self, step: int, host_flat, manifest):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in host_flat.items():
            fname = key.replace("/", "__") + ".npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)              # the atomic commit point
        self._gc()

    def wait(self):
        """Block until any in-flight async save lands; re-raise its error."""
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        protect = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_period:
            protect |= {s for s in steps if s % self.keep_period == 0}
        for s in steps:
            if s not in protect:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore ----

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, template, *, shardings=None):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of (Named)Shardings matching
        ``template`` — each leaf is device_put against it, which reshards
        onto whatever mesh the caller is running now (elastic restart).
        """
        d = self._step_dir(step)
        flat_np = {}
        for key in _flatten(template):
            fname = key.replace("/", "__") + ".npy"
            flat_np[key] = np.load(os.path.join(d, fname))
        tree = _unflatten(template, flat_np)

        def put(leaf, tmpl, sh):
            arr = np.asarray(leaf).astype(tmpl.dtype)
            if sh is not None:
                return jax.device_put(arr, sh)
            return jax.device_put(arr)

        if shardings is not None:
            return jax.tree.map(put, tree, template, shardings)
        return jax.tree.map(lambda l, t: put(l, t, None), tree, template)
