"""Fault-tolerant checkpoint manager.

Production constraints honored (scaled to this container):

- **Atomic commit**: writes land in ``step_<n>.tmp/`` and are renamed to
  ``step_<n>/`` only after every shard file + manifest is fsync'd — a
  preempted save can never be mistaken for a valid checkpoint.
- **Async save**: ``save(..., blocking=False)`` snapshots to host memory
  (device_get) synchronously — the train loop may mutate buffers right
  after — then writes in a background thread (the Orbax pattern).
- **Retention**: keep the newest ``keep`` checkpoints plus every multiple
  of ``keep_period`` (for post-hoc evals).
- **Elastic restore**: ``restore(..., shardings=...)`` device_puts each
  leaf against the *target* sharding tree, which may describe a different
  mesh than the one that saved — restart on 256 chips from a 512-chip
  checkpoint (or vice versa) is a first-class path, not a special case.
- **Self-describing**: a JSON manifest stores the tree structure, leaf
  dtypes/shapes, the save-time mesh, and the parameter layout for audit.
- **Layout migration** (ISSUE 5): the fusion-legal parameter layout
  stores ``[wq|wk|wv]`` / ``[wi|wg]`` as single concatenated leaves
  (models/config.py::ParamLayout) while legacy checkpoints carry the
  per-matrix leaves.  :func:`migrate_layout` reconciles a flat leaf dict
  to a template's layout in *both* directions — join by last-axis
  concatenation, split at the template parts' widths — so a legacy
  checkpoint restores into a concat-layout model and a concat-layout
  serving process saves back out in legacy form (``save(...,
  migrate_to=)``); the round trip is bitwise on weights (numpy
  concatenate/slice moves bytes, never values).

Storage is one ``.npy`` per leaf under the step directory (the analogue
of a tensorstore shard per parameter); leaf names are slash-joined tree
paths.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Mapping, Optional

import jax
import numpy as np

#: concatenated-layout leaf basename -> its legacy per-matrix parts, in
#: concatenation order (matches models/transformer.init_attn and
#: models/mlp.init_mlp)
LAYOUT_GROUPS = {"wqkv": ("wq", "wk", "wv"), "wig": ("wi", "wg")}
_PART_TO_CAT = {part: (cat, parts)
                for cat, parts in LAYOUT_GROUPS.items() for part in parts}


def layout_of(flat_keys) -> str:
    """'concat' when any leaf is a persisted fused-layout tensor."""
    for key in flat_keys:
        if key.rpartition("/")[2] in LAYOUT_GROUPS:
            return "concat"
    return "legacy"


def precision_of(flat: Mapping[str, Any]) -> str:
    """'int8' when any weight leaf is quantized (has a scale sibling)."""
    for key, leaf in flat.items():
        if np.dtype(leaf.dtype) == np.int8 and key + "_scale" in flat:
            return "int8"
    return "f32"


def quantize_leaf(arr: np.ndarray):
    """Per-output-channel symmetric int8 with **power-of-two** scales.

    The runtime scheme (kernels/fused.py) uses exact max/127 scales; at
    rest we snap the scale to 2^(floor(log2 max) - 6) instead so the
    round trip is a fixed point: dequantize→requantize recovers the same
    exponent (127·2^e < 2^(e+7) keeps frexp on the same side), hence the
    same scale, hence — round(q·s/s) = q — the same int8 bytes.  Cost is
    under one bit of the 8 (|q| lands in [64,127] instead of [.,127])."""
    a = arr.astype(np.float32)
    m = np.maximum(np.max(np.abs(a), axis=-2), 1e-8)
    _, e = np.frexp(m)                           # m = f * 2^e, f in [.5,1)
    scale = np.ldexp(np.float32(1.0), e - 7).astype(np.float32)
    q = np.clip(np.round(a / np.expand_dims(scale, -2)),
                -127, 127).astype(np.int8)
    return q, scale


def dequantize_leaf(q: np.ndarray, scale: np.ndarray, dtype=np.float32):
    return (q.astype(np.float32)
            * np.expand_dims(scale, -2)).astype(dtype)


def migrate_layout(flat: Dict[str, np.ndarray],
                   template_shapes: Mapping[str, tuple],
                   template_dtypes: Optional[Mapping[str, Any]] = None
                   ) -> Dict[str, np.ndarray]:
    """Reconcile checkpoint leaves to the template's parameter layout
    *and precision*.

    ``template_shapes`` maps the target tree's flat keys to leaf shapes.
    A template key missing from ``flat`` is synthesized from the other
    layout: joined (``wq``/``wk``/``wv`` -> ``wqkv``, ``wi``/``wg`` ->
    ``wig``) by last-axis concatenation, or split from the concatenated
    leaf at the widths the template's part shapes dictate.  Leaves the
    template does not name are dropped once consumed; everything else
    passes through untouched.  Both directions are bitwise on weights.

    With ``template_dtypes`` given, a precision pass brackets the layout
    pass: int8 leaves whose ``<key>_scale`` sibling rides along are
    dequantized *first* unless the template wants that exact key int8
    (so an int8 concat can still split toward a legacy f32 template),
    and template keys declared int8 are quantized *last*
    (:func:`quantize_leaf`), growing the scale sibling the quantized
    model tree expects.  Quantize→dequantize→quantize is bitwise-stable
    on the int8 bytes and scales (power-of-two scales; see
    :func:`quantize_leaf`)."""
    out = dict(flat)
    dtypes = dict(template_dtypes or {})
    # precision pass, downward: dequantize any scale-carrying int8 leaf
    # the template does not want quantized (or does not name at all)
    for key in list(out):
        if key not in out:                 # a scale popped by a prior key
            continue
        skey = key + "_scale"
        if (np.dtype(out[key].dtype) == np.int8 and skey in out
                and np.dtype(dtypes.get(key, np.float32)) != np.int8):
            target = dtypes.get(key, np.float32)
            out[key] = dequantize_leaf(out[key], out[skey], target)
            if skey not in template_shapes:
                out.pop(skey)
    for key, shape in template_shapes.items():
        if key in out:
            continue
        prefix, _, base = key.rpartition("/")
        pfx = prefix + "/" if prefix else ""
        if base in LAYOUT_GROUPS:
            part_keys = [pfx + p for p in LAYOUT_GROUPS[base]]
            if all(p in flat for p in part_keys):
                joined = np.concatenate([out[p] for p in part_keys],
                                        axis=-1)
                if joined.shape != tuple(shape):
                    raise ValueError(
                        f"{key}: joined parts have shape {joined.shape} "
                        f"!= template {tuple(shape)} (checkpoint and "
                        f"template disagree on the group's widths)")
                out[key] = joined
                for p in part_keys:
                    out.pop(p, None)
        elif base in _PART_TO_CAT:
            cat, parts = _PART_TO_CAT[base]
            cat_key = pfx + cat
            if cat_key in flat:
                widths = [template_shapes[pfx + p][-1] for p in parts]
                if sum(widths) != flat[cat_key].shape[-1]:
                    raise ValueError(
                        f"{cat_key}: concatenated width "
                        f"{flat[cat_key].shape[-1]} != template parts "
                        f"{widths}")
                off = 0
                for p, w in zip(parts, widths):
                    out[pfx + p] = out[cat_key][..., off:off + w]
                    off += w
                out.pop(cat_key, None)
    # precision pass, upward: quantize toward int8 template leaves
    for key, dtype in dtypes.items():
        if np.dtype(dtype) != np.int8:
            continue
        leaf = out.get(key)
        if leaf is None or np.dtype(leaf.dtype) == np.int8:
            continue                       # absent, or already quantized
        q, s = quantize_leaf(leaf)
        skey = key + "_scale"
        if skey in template_shapes and s.shape != tuple(
                template_shapes[skey]):
            raise ValueError(
                f"{skey}: quantized scales have shape {s.shape} != "
                f"template {tuple(template_shapes[skey])}")
        out[key] = q
        out[skey] = s
    return out


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(treedef_template, flat: Dict[str, np.ndarray]):
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(
        treedef_template)[0]
    leaves = []
    for path, _ in paths_and_leaves:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    treedef = jax.tree_util.tree_structure(treedef_template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 keep_period: Optional[int] = None):
        self.directory = directory
        self.keep = keep
        self.keep_period = keep_period
        os.makedirs(directory, exist_ok=True)
        self._save_thread: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None

    # ---- paths ----

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name[5:]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ---- save ----

    def save(self, step: int, tree, *, extra: Optional[dict] = None,
             blocking: bool = True, migrate_to=None):
        """Snapshot ``tree`` (sync) and write it (async unless blocking).

        ``migrate_to``: optional template pytree (real arrays or
        ShapeDtypeStructs, e.g. from ``jax.eval_shape``) whose parameter
        *layout* the checkpoint should be written in — how a
        concat-layout process emits legacy per-matrix checkpoints (and
        vice versa) without touching its live params."""
        self.wait()  # one in-flight save at a time
        host_flat = {k: np.asarray(jax.device_get(v))
                     for k, v in _flatten(tree).items()}
        if migrate_to is not None:
            tmpl_flat = _flatten(migrate_to)
            host_flat = migrate_layout(
                host_flat,
                {k: tuple(v.shape) for k, v in tmpl_flat.items()},
                {k: v.dtype for k, v in tmpl_flat.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "param_layout": layout_of(host_flat),
            "precision": precision_of(host_flat),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host_flat.items()},
        }
        if blocking:
            self._write(step, host_flat, manifest)
        else:
            self._save_thread = threading.Thread(
                target=self._write_guarded, args=(step, host_flat, manifest),
                daemon=True)
            self._save_thread.start()

    def _write_guarded(self, step, host_flat, manifest):
        try:
            self._write(step, host_flat, manifest)
        except BaseException as e:  # surfaced by wait()
            self._save_error = e

    def _write(self, step: int, host_flat, manifest):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in host_flat.items():
            fname = key.replace("/", "__") + ".npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)              # the atomic commit point
        self._gc()

    def wait(self):
        """Block until any in-flight async save lands; re-raise its error."""
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        protect = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_period:
            protect |= {s for s in steps if s % self.keep_period == 0}
        for s in steps:
            if s not in protect:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ---- restore ----

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: int, template, *, shardings=None):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of (Named)Shardings matching
        ``template`` — each leaf is device_put against it, which reshards
        onto whatever mesh the caller is running now (elastic restart).
        The checkpoint's parameter layout need not match the template's:
        leaves are migrated (:func:`migrate_layout`) toward the template,
        so legacy per-matrix checkpoints load into concat-layout models
        and back — bidirectional, bitwise on weights.
        """
        d = self._step_dir(step)
        tmpl_flat = _flatten(template)
        stored = set(self.manifest(step)["leaves"])
        # load the template's leaves plus only the other-layout
        # counterparts migration needs — a partial-template restore
        # (params-only from a train checkpoint) never reads opt state
        needed = set(tmpl_flat) & stored
        for key in set(tmpl_flat) - stored:
            prefix, _, base = key.rpartition("/")
            pfx = prefix + "/" if prefix else ""
            if base in LAYOUT_GROUPS:
                needed |= {pfx + p for p in LAYOUT_GROUPS[base]} & stored
            elif base in _PART_TO_CAT:
                needed |= {pfx + _PART_TO_CAT[base][0]} & stored
        # an int8 checkpoint's scale siblings ride along even when the
        # (f32) template does not name them — dequantization needs them
        for key in list(needed):
            skey = key + "_scale"
            if skey in stored and skey not in tmpl_flat:
                needed.add(skey)
        flat_np = {}
        for key in needed:
            fname = key.replace("/", "__") + ".npy"
            flat_np[key] = np.load(os.path.join(d, fname))
        flat_np = migrate_layout(
            flat_np, {k: tuple(v.shape) for k, v in tmpl_flat.items()},
            {k: v.dtype for k, v in tmpl_flat.items()})
        tree = _unflatten(template, flat_np)

        def put(leaf, tmpl, sh):
            arr = np.asarray(leaf).astype(tmpl.dtype)
            if sh is not None:
                return jax.device_put(arr, sh)
            return jax.device_put(arr)

        if shardings is not None:
            return jax.tree.map(put, tree, template, shardings)
        return jax.tree.map(lambda l, t: put(l, t, None), tree, template)
