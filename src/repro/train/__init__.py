"""Training substrate: optimizer, step builder, loop, fault tolerance."""
from repro.train.optim import (OptConfig, init_opt_state, adamw_update,
                               lr_at_step, opt_state_specs)
from repro.train.step import build_train_step, build_eval_step

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_at_step",
           "opt_state_specs", "build_train_step", "build_eval_step"]
