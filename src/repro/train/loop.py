"""Fault-tolerant training loop.

Deployability features (each exercised by tests, scaled to this host):

- **Checkpoint/restart**: resumes from the newest valid checkpoint; the
  data pipeline resumes from the step counter alone (deterministic
  synthesis), so a restart replays no data and skips none.
- **Preemption safety**: SIGTERM/SIGINT flip a flag; the loop finishes
  the in-flight step, force-saves, then exits cleanly (the TPU
  maintenance-event pattern).
- **Straggler mitigation**: per-step wall-times feed an EWMA; steps
  slower than ``straggler_factor ×`` the EWMA are logged as straggler
  events with the slowdown factor.  On a real multi-host deployment this
  signal drives hot-spare swap-in; here it exercises the detection path
  and the accounting (events land in the metrics stream).
- **Elastic restart**: `CheckpointManager.restore(shardings=...)` reshards
  the state onto whatever mesh the relaunched job built (see
  launch/train.py --elastic-from).

The loop is deliberately framework-free: pure functions + explicit state,
so the same loop drives unit tests (tiny model, CPU) and the launcher.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLMDataset
from repro.train.optim import OptConfig


@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    checkpoint_every: int = 100
    log_every: int = 10
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.1
    async_checkpoint: bool = True


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags outlier steps (simulated swap hook)."""

    factor: float = 2.0
    alpha: float = 0.1
    ewma: Optional[float] = None
    events: List[Dict[str, float]] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ewma is not None and dt > self.factor * self.ewma:
            is_straggler = True
            self.events.append({"step": step, "dt": dt,
                                "slowdown": dt / self.ewma})
        self.ewma = dt if self.ewma is None else (
            self.alpha * dt + (1 - self.alpha) * self.ewma)
        return is_straggler


class PreemptionGuard:
    """Flips on SIGTERM/SIGINT; loop drains the current step then saves."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:   # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


def train_loop(step_fn: Callable, params, opt_state,
               dataset: SyntheticLMDataset, loop_cfg: LoopConfig,
               ckpt: Optional[CheckpointManager] = None,
               start_step: int = 0,
               metrics_sink: Optional[Callable[[int, Dict], None]] = None,
               preemption: Optional[PreemptionGuard] = None,
               batch_put: Optional[Callable] = None,
               save_extra: Optional[Dict[str, Any]] = None):
    """Run until total_steps or preemption.  Returns final state + report.

    ``save_extra`` is merged into every checkpoint's ``extra`` manifest
    record — how launch code threads run metadata (notably the model's
    ``param_layout`` plan) into the train→serve handoff."""
    monitor = StragglerMonitor(loop_cfg.straggler_factor,
                               loop_cfg.ewma_alpha)
    guard = preemption or PreemptionGuard(install=False)
    history: List[Dict[str, Any]] = []
    step = start_step
    dataset.restore({"step": start_step, "seed": dataset.cfg.seed})

    while step < loop_cfg.total_steps and not guard.requested:
        batch = next(dataset)
        if batch_put is not None:
            batch = batch_put(batch)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        straggled = monitor.observe(step, dt)

        if step % loop_cfg.log_every == 0 or straggled:
            rec = {k: float(np.asarray(v)) for k, v in metrics.items()}
            rec.update(step=step, step_time_s=dt, straggler=straggled)
            history.append(rec)
            if metrics_sink:
                metrics_sink(step, rec)

        step += 1
        if ckpt and step % loop_cfg.checkpoint_every == 0:
            ckpt.save(step, {"params": params, "opt_state": opt_state},
                      extra={"data": dataset.state(), **(save_extra or {})},
                      blocking=not loop_cfg.async_checkpoint)

    if ckpt:
        ckpt.wait()                      # drain any in-flight async save
        if guard.requested or step % loop_cfg.checkpoint_every:
            ckpt.save(step, {"params": params, "opt_state": opt_state},
                      extra={"data": dataset.state(),
                             "preempted": guard.requested,
                             **(save_extra or {})},
                      blocking=True)
    report = {
        "final_step": step,
        "preempted": guard.requested,
        "straggler_events": monitor.events,
        "history": history,
    }
    return params, opt_state, report


def resume_or_init(ckpt: Optional[CheckpointManager], init_fn: Callable,
                   shardings=None):
    """Restore the newest checkpoint or initialize fresh.

    Returns (params, opt_state, start_step).  ``shardings`` (optional
    {'params':..., 'opt_state':...}) enables elastic restore onto the
    current mesh.
    """
    if ckpt is not None:
        latest = ckpt.latest_step()
        if latest is not None:
            template = init_fn()
            tmpl_tree = {"params": template[0], "opt_state": template[1]}
            sh = None
            if shardings is not None:
                sh = {"params": shardings["params"],
                      "opt_state": shardings["opt_state"]}
            tree = ckpt.restore(latest, tmpl_tree, shardings=sh)
            return tree["params"], tree["opt_state"], latest
    params, opt_state = init_fn()
    return params, opt_state, 0
