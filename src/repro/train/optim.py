"""Sharded AdamW with fp32 master weights, cosine schedule, global-norm
clipping, and error-feedback gradient compression.

No optax in this environment — the optimizer is implemented directly on
pytrees.  All state tensors inherit the parameter's logical sharding
(ZeRO-style: m/v/master are sharded exactly like the FSDP'd parameter),
so optimizer memory scales 1/N_devices with the data axis.

Gradient compression (``ParallelConfig.grad_compression``):
  none     — gradients reduced in the compute dtype (params are bf16, so
             the implicit GSPMD all-reduce already moves 2 bytes/param).
  bf16     — explicit cast (documents intent; no-op for bf16 params).
  int8_ef  — error-feedback int8 quantization (1-bit-Adam-family trick):
             q = Q(g + e); e' = g + e - D(q); update uses D(q).  The
             residual state rides in opt_state["ef"].  On a real DCN
             deployment the quantized tensor is what crosses the pod
             boundary; see parallel/compress.py for the wire format.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 200
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # int8 error-feedback compression (set via ParallelConfig)
    compression: str = "none"     # none | bf16 | int8_ef


def lr_at_step(cfg: OptConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio·lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    denom = max(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / denom, 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio)
                    * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        # copy=True: for f32 params astype would alias the param buffer,
        # and donating (params, opt_state) would then donate it twice
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    }
    if cfg.compression == "int8_ef":
        state["ef"] = jax.tree.map(f32, params)
    return state


def opt_state_specs(param_specs, cfg: OptConfig):
    """Optimizer-state logical axes == parameter logical axes (ZeRO)."""
    is_tup = lambda x: isinstance(x, tuple)
    specs = {
        "step": (),
        "m": param_specs,
        "v": param_specs,
        "master": param_specs,
    }
    if cfg.compression == "int8_ef":
        specs["ef"] = param_specs
    return specs


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _apply_compression(grads, state, mode: str):
    if mode in ("none",):
        return jax.tree.map(lambda g: g.astype(jnp.float32), grads), state
    if mode == "bf16":
        return jax.tree.map(
            lambda g: g.astype(jnp.bfloat16).astype(jnp.float32),
            grads), state
    if mode == "int8_ef":
        ef = state["ef"]

        def one(g, e):
            t = g.astype(jnp.float32) + e
            q, scale = _quantize_int8(t)
            deq = q.astype(jnp.float32) * scale
            return deq, t - deq

        pairs = jax.tree.map(one, grads, ef)
        deq = jax.tree.map(lambda p: p[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda p: p[1], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        state = dict(state, ef=new_ef)
        return deq, state
    raise ValueError(f"unknown compression {mode!r}")


def adamw_update(grads, state, params, cfg: OptConfig):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    grads, state = _apply_compression(grads, state, cfg.compression)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * clip, grads)

    step = state["step"] + 1
    lr = lr_at_step(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def one(g, m, v, master):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * upd
        return m, v, master

    out = jax.tree.map(one, grads, state["m"], state["v"], state["master"])
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    new_m, new_v, new_master = pick(0), pick(1), pick(2)
    new_params = jax.tree.map(
        lambda mst, p: mst.astype(p.dtype), new_master, params)
    new_state = dict(state, step=step, m=new_m, v=new_v, master=new_master)
    stats = {"grad_norm": gnorm, "lr": lr,
             "clip_factor": clip}
    return new_params, new_state, stats
