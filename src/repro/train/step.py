"""Train/eval step builders: value_and_grad + microbatch accumulation +
sharded AdamW, with explicit in/out shardings for pjit.

``build_train_step`` returns (step_fn, shardings) where step_fn is NOT yet
jitted — launch/train.py and launch/dryrun.py jit it with the sharding
trees so the same function serves real execution and .lower()/.compile()
dry-runs.

Microbatch gradient accumulation is a lax.scan over a leading microbatch
axis: memory scales with one microbatch while the HLO stays one program
(no python unrolling — compile time matters at 88 layers).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import ExecutionPolicy
from repro.models.config import ParallelConfig
from repro.parallel.sharding import ShardCtx, tree_shardings
from repro.train.optim import OptConfig, adamw_update, init_opt_state, opt_state_specs


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    """[B, ...] -> [n, B/n, ...] for every batch leaf."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def build_train_step(model, opt_cfg: OptConfig,
                     ctx: Optional[ShardCtx] = None,
                     policy: Optional[ExecutionPolicy] = None):
    """Returns (train_step, shardings dict).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

    ``policy`` overrides the model's resolved ExecutionPolicy for this
    step's lowering decisions (resolved once, at build time — the step is
    jitted downstream, so per-call policy switches would be stale).
    """
    if policy is not None:
        model = model.with_policy(policy)
    par: ParallelConfig = model.par
    ctx = ctx if ctx is not None else model.ctx

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if par.grad_accum > 1:
            micro = _split_microbatches(batch, par.grad_accum)

            def body(acc, mb):
                loss, metrics, grads = grads_of(params, mb)
                acc_grads, acc_loss = acc
                acc_grads = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), acc_grads, grads)
                return (acc_grads, acc_loss + loss), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / par.grad_accum, grads)
            loss = loss_sum / par.grad_accum
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        new_params, new_opt_state, stats = adamw_update(
            grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt_state, metrics

    shardings = _train_shardings(model, opt_cfg, ctx)
    return train_step, shardings


def _train_shardings(model, opt_cfg: OptConfig, ctx: Optional[ShardCtx]):
    if ctx is None or ctx.mesh is None:
        return None
    pspecs = model.param_specs()
    param_sh = tree_shardings(ctx, pspecs)
    opt_sh = {
        "step": ctx.sharding(()),
        "m": param_sh, "v": param_sh, "master": param_sh,
    }
    if opt_cfg.compression == "int8_ef":
        opt_sh["ef"] = param_sh
    batch_sh = ctx.sharding(("act_batch", None))
    metric_sh = ctx.sharding(())
    return {
        "params": param_sh,
        "opt_state": opt_sh,
        "batch_leaf": batch_sh,
        "metrics": metric_sh,
        # layout metadata rides next to the sharding trees so the
        # train→serve handoff (launch code, checkpoint extra) preserves
        # the init-time ParamLayout decision — param_specs above already
        # describe the planned (possibly concatenated) leaves
        "param_layout": getattr(model, "param_layout", None),
    }


def batch_shardings(model, ctx: Optional[ShardCtx], batch_tree):
    """Per-leaf shardings for a batch pytree (tokens/labels 2-D;
    frames/patch_embeds 3-D)."""
    if ctx is None or ctx.mesh is None:
        return None

    def leaf(x):
        nd = len(x.shape)
        if nd == 1:
            return ctx.sharding(("act_batch",))
        if nd == 2:
            return ctx.sharding(("act_batch", None))
        return ctx.sharding(("act_batch",) + (None,) * (nd - 1))

    return jax.tree.map(leaf, batch_tree)


def build_eval_step(model, ctx: Optional[ShardCtx] = None,
                    policy: Optional[ExecutionPolicy] = None):
    if policy is not None:
        model = model.with_policy(policy)

    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return dict(metrics, loss=loss)
    return eval_step


def init_train_state(model, opt_cfg: OptConfig, rng):
    """(params, opt_state) on the current default device(s)."""
    params = model.init_params(rng)
    opt_state = init_opt_state(params, opt_cfg)
    return params, opt_state
