"""Cell builder: (architecture × input-shape × mesh) -> lowerable step.

One "cell" is the unit of the dry-run matrix: it binds a full-size model
config, the assigned input shape, a mesh, and the parallelism policy, and
returns the jitted-but-not-yet-lowered step function plus the
ShapeDtypeStruct arguments and explicit in/out shardings.

Shape kinds map to step functions per the assignment:
  train_4k     -> train_step   (fwd + bwd + sharded AdamW)
  prefill_32k  -> prefill_step (fwd building the decode cache)
  decode_32k   -> serve_step   (one token against a seq_len cache)
  long_500k    -> serve_step   (sub-quadratic archs only)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import make_ctx
from repro.models import build_model
from repro.models.config import (ModelConfig, ParallelConfig, ShapeConfig,
                                 SHAPES, shape_applicable)
from repro.parallel.sharding import ShardCtx, sanitize_tree, tree_shardings
from repro.train.optim import OptConfig, init_opt_state
from repro.train.step import build_train_step


def _model_axis_size(mesh) -> int:
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def default_parallel(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                     **overrides) -> ParallelConfig:
    """Paper-faithful baseline parallelism policy per shape kind.

    Cache layout: heads-sharded when num_kv_heads divides the model axis
    (the low-communication layout), else seq-sharded (GQA few-heads);
    batch=1 long-context shards seq over the whole chip plane.
    """
    if shape.name == "long_500k":
        layout = "seq_all"
    elif cfg.num_kv_heads and \
            cfg.num_kv_heads % max(_model_axis_size(mesh), 1) == 0:
        layout = "batch_heads"
    else:
        layout = "batch_seq"
    base = dict(
        fsdp=True,
        seq_shard_acts=True,
        cache_layout=layout,
        remat="full" if shape.kind == "train" else "none",
        grad_accum=1,
        grad_compression="none",
    )
    base.update(overrides)
    return ParallelConfig(**base)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    par: ParallelConfig
    ctx: ShardCtx
    model: Any
    fn: Any                       # jitted (AOT-lowerable) step
    args: Tuple                   # ShapeDtypeStruct pytrees
    kind: str

    def lower(self):
        return self.fn.lower(*self.args)


def _batch_shardings(ctx: ShardCtx, batch_tree):
    def leaf(x):
        axes = ("act_batch",) + (None,) * (len(x.shape) - 1)
        return ctx.sharding(axes)
    return jax.tree.map(leaf, batch_tree)


def build_cell(arch: str, shape_name: str, mesh,
               opt_cfg: Optional[OptConfig] = None,
               par_overrides: Optional[Dict] = None,
               reduced: bool = False,
               shape_cfg: Optional[ShapeConfig] = None) -> Cell:
    cfg = configs.get_reduced(arch) if reduced else configs.get_config(arch)
    shape = shape_cfg if shape_cfg is not None else SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        raise ValueError(
            f"{arch} × {shape_name}: inapplicable (full-attention arch; "
            f"long_500k needs sub-quadratic attention)")
    par = default_parallel(cfg, shape, mesh=mesh, **(par_overrides or {}))
    ctx = make_ctx(mesh, par, cfg)
    model = build_model(cfg, par, ctx)
    opt_cfg = opt_cfg or OptConfig(compression=par.grad_compression)

    replicated = ctx.sharding(()) if mesh is not None else None
    param_specs = configs.params_specs(model)
    # sanitize: jit arg shardings must divide exactly (40 experts or 8 KV
    # heads on a 16-way axis, vocab 49155, ... would reject otherwise)
    param_sh = sanitize_tree(
        tree_shardings(ctx, model.param_specs()), param_specs)

    if shape.kind == "train":
        step_fn, _ = build_train_step(model, opt_cfg, ctx)
        opt_specs = jax.eval_shape(
            lambda p: init_opt_state(p, opt_cfg), param_specs)
        opt_sh = {"step": replicated, "m": param_sh, "v": param_sh,
                  "master": param_sh}
        if opt_cfg.compression == "int8_ef":
            opt_sh["ef"] = param_sh
        batch_specs = configs.batch_specs(cfg, shape)
        batch_sh = sanitize_tree(_batch_shardings(ctx, batch_specs),
                                 batch_specs)
        fn = jax.jit(step_fn,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, replicated),
                     donate_argnums=(0, 1))
        args = (param_specs, opt_specs, batch_specs)
        return Cell(arch, shape, cfg, par, ctx, model, fn, args, "train")

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(params, batch)

        batch_specs = configs.batch_specs(cfg, shape)
        batch_sh = sanitize_tree(_batch_shardings(ctx, batch_specs),
                                 batch_specs)
        out_sds = jax.eval_shape(prefill_step, param_specs, batch_specs)
        cache_sh = tree_shardings(ctx, model.cache_specs())
        logits_sh = ctx.sharding(("act_batch", "act_vocab"))
        out_sh = sanitize_tree((logits_sh, cache_sh), out_sds)
        fn = jax.jit(prefill_step,
                     in_shardings=(param_sh, batch_sh),
                     out_shardings=out_sh)
        args = (param_specs, batch_specs)
        return Cell(arch, shape, cfg, par, ctx, model, fn, args, "prefill")

    # decode (decode_32k / long_500k): one serve_step against a full cache
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    tokens_spec, cache_specs_tree = configs.decode_specs(model, shape)
    cache_sh = sanitize_tree(tree_shardings(ctx, model.cache_specs()),
                             cache_specs_tree)
    tok_sh = sanitize_tree(ctx.sharding(("act_batch",)), tokens_spec) \
        if shape.global_batch > 1 else replicated
    logits_sds = jax.ShapeDtypeStruct(
        (shape.global_batch, cfg.vocab_size), jnp.float32)
    logits_sh = sanitize_tree(
        ctx.sharding(("act_batch", "act_vocab"))
        if shape.global_batch > 1 else ctx.sharding((None, "act_vocab")),
        logits_sds)
    # out_shardings: (logits, cache) — cache keeps its input sharding
    fn = jax.jit(serve_step,
                 in_shardings=(param_sh, tok_sh, cache_sh),
                 out_shardings=(logits_sh, cache_sh),
                 donate_argnums=(2,))
    args = (param_specs, tokens_spec, cache_specs_tree)
    return Cell(arch, shape, cfg, par, ctx, model, fn, args, "decode")


def build_serve_cells(arch: str, serve_cfg, n_cells: int = 1, *,
                      mesh=None, reduced: bool = True,
                      par_overrides: Optional[Dict] = None,
                      seed: int = 0, policy=None):
    """N data-parallel serving cells for ``arch`` behind one router.

    Unlike :func:`build_cell` (ShapeDtypeStructs for AOT lowering), this
    builds a *running* fleet: one param init whose device buffers every
    cell shares, N ``BatchedEngine`` cells each sized by ``serve_cfg``
    (so ``n_cells`` multiplies the fleet's slot and page capacity), one
    :class:`~repro.serve.router.CellRouter` as the admission point."""
    from repro.serve.router import make_cells
    cfg = configs.get_reduced(arch) if reduced else configs.get_config(arch)
    par = ParallelConfig(remat="none", **(par_overrides or {}))
    ctx = make_ctx(mesh, par, cfg)
    model = build_model(cfg, par, ctx)
    params = model.init_params(jax.random.PRNGKey(seed))
    return make_cells(model, params, serve_cfg, n_cells, policy=policy)
