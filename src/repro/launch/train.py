"""Training driver.

Runs real training on whatever devices exist (CPU here; the same code
path drives a TPU slice — the mesh shape is the only difference).  For
container-scale runs use a reduced config + small mesh:

  PYTHONPATH=src python -m repro.launch.train \\
      --arch granite-8b --reduced --steps 50 --batch 8 --seq 128 \\
      --mesh 1x1 --ckpt-dir /tmp/ckpt

Fault-tolerance paths exercised: checkpoint/restart (rerun the same
command — it resumes), preemption (SIGTERM → drain + save), straggler
logging, elastic restart (change --mesh between runs; restore reshards).
"""
import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.launch.mesh import make_ctx, make_mesh
from repro.models import build_model
from repro.models.config import ParallelConfig
from repro.parallel.sharding import tree_shardings
from repro.train import OptConfig, build_train_step, init_opt_state
from repro.train.loop import (LoopConfig, PreemptionGuard, resume_or_init,
                              train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=("none", "full", "dots"))
    ap.add_argument("--compression", default="none",
                    choices=("none", "bf16", "int8_ef"))
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL, e.g. 1x1, 2x2 (needs devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", default=None, help="write JSON report here")
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    par = ParallelConfig(remat=args.remat, grad_accum=args.grad_accum,
                         grad_compression=args.compression)

    dshape = tuple(int(x) for x in args.mesh.split("x"))
    n_dev = dshape[0] * dshape[1]
    mesh = make_mesh(dshape, ("data", "model")) if n_dev > 1 else None
    ctx = make_ctx(mesh, par, cfg)
    model = build_model(cfg, par, ctx)

    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1),
                        compression=args.compression)
    step_fn, shardings = build_train_step(model, opt_cfg, ctx)
    if mesh is not None:
        param_sh = tree_shardings(ctx, model.param_specs())
        opt_sh = {"step": ctx.sharding(()), "m": param_sh, "v": param_sh,
                  "master": param_sh}
        if opt_cfg.compression == "int8_ef":
            opt_sh["ef"] = param_sh
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1),
                          out_shardings=(param_sh, opt_sh, None))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    data_cfg = DataConfig(
        global_batch=args.batch, seq_len=args.seq,
        vocab_size=cfg.vocab_size, seed=args.seed, family=cfg.family,
        num_frames=cfg.encdec.num_frames if cfg.encdec else 0,
        num_patches=cfg.vlm.num_patches if cfg.vlm else 0,
        d_model=cfg.d_model)
    dataset = SyntheticLMDataset(data_cfg).start()

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    def init_fn():
        params = model.init_params(jax.random.PRNGKey(args.seed))
        return params, init_opt_state(params, opt_cfg)

    restore_sh = None
    if mesh is not None:
        param_sh = tree_shardings(ctx, model.param_specs())
        restore_sh = {"params": param_sh,
                      "opt_state": {"step": ctx.sharding(()),
                                    "m": param_sh, "v": param_sh,
                                    "master": param_sh}}
    params, opt_state, start = resume_or_init(ckpt, init_fn, restore_sh)
    if start:
        print(f"[train] resumed from checkpoint at step {start}")

    def batch_put(batch):
        # VLM reduced: trim tokens so patches + tokens fit model seq plan
        return {k: jnp.asarray(v) for k, v in batch.items()}

    def sink(step, rec):
        print(f"[step {step:5d}] loss={rec['loss']:.4f} "
              f"lr={rec.get('lr', 0):.2e} "
              f"gnorm={rec.get('grad_norm', 0):.3f} "
              f"dt={rec['step_time_s'] * 1e3:.0f}ms"
              + (" STRAGGLER" if rec.get("straggler") else ""))

    guard = PreemptionGuard()
    loop_cfg = LoopConfig(total_steps=args.steps,
                          checkpoint_every=args.ckpt_every,
                          log_every=args.log_every)
    layout = getattr(model, "param_layout", None)
    params, opt_state, report = train_loop(
        step_fn, params, opt_state, dataset, loop_cfg, ckpt,
        start_step=start, metrics_sink=sink, preemption=guard,
        batch_put=batch_put,
        save_extra={"param_layout": dataclasses.asdict(layout)}
        if layout is not None else None)
    dataset.stop()
    print(f"[train] done at step {report['final_step']} "
          f"(preempted={report['preempted']}, "
          f"stragglers={len(report['straggler_events'])})")
    if args.report:
        os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(report, f, indent=1)


if __name__ == "__main__":
    main()
