"""Serving driver: batched engine with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve \\
      --arch granite-8b --reduced --slots 4 --requests 10 --max-new 16
"""
import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import build_model
from repro.models.config import ParallelConfig
from repro.serve import BatchedEngine, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    par = ParallelConfig(remat="none")
    model = build_model(cfg, par)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    serve_cfg = ServeConfig(batch_slots=args.slots,
                            max_seq_len=args.max_seq,
                            max_new_tokens=args.max_new)
    engine = BatchedEngine(model, params, serve_cfg)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        2, cfg.vocab_size, args.prompt_len).tolist(),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  rid={r.rid} generated={r.generated[:8]}...")


if __name__ == "__main__":
    main()
