from repro.launch.hostdev import ensure_host_devices
ensure_host_devices()
# The two lines above MUST run before any jax import: jax freezes the
# device count at first initialization, and the production-mesh dry-run
# needs 512 placeholder host devices (REPRO_SIM_DEVICES overrides).
# Only entrypoints do this — tests and benchmarks see the real device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds the cell (full-size config, ShapeDtypeStruct inputs, explicit
     in/out shardings — launch/cells.py),
  3. ``jit(...).lower(**specs).compile()`` — success proves the sharding
     config is coherent end-to-end (no allocation anywhere),
  4. prints ``compiled.memory_analysis()`` (fits-in-HBM evidence) and
     ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline),
  5. parses the post-optimization HLO for collective wire bytes,
  6. writes one JSON artifact per cell under --out for EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh both
  python -m repro.launch.dryrun --all --mesh single --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import cells as cells_lib
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, shape_applicable
from repro.roofline import analysis as roofline
from repro.roofline.hlo_parser import analyze_hlo


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             par_overrides=None, verbose: bool = True) -> dict:
    multi = mesh_kind == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "status": "ok",
           "par_overrides": par_overrides or {}}
    t0 = time.time()
    try:
        cell = cells_lib.build_cell(arch, shape_name, mesh,
                                    par_overrides=par_overrides)
        with mesh:
            lowered = cell.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        mem_stats = {}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            mem_stats[field] = getattr(mem, field, None)
        if verbose:
            print(f"  memory_analysis: {mem_stats}")
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost = dict(cost) if cost else {}
        xla_flops = float(cost.get("flops", 0.0) or 0.0)
        xla_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)

        # Loop-trip-count-aware analysis (cost_analysis counts while
        # bodies once — useless under scanned layers; see hlo_parser).
        hlo = compiled.as_text()
        h = analyze_hlo(hlo, chips)
        flops = h["flops"]
        bytes_accessed = h["hbm_bytes"]
        csum = h["collectives"]
        if verbose:
            print(f"  hlo analysis: flops={flops:.3e} "
                  f"bytes={bytes_accessed:.3e} "
                  f"(xla one-iteration: flops={xla_flops:.3e})")

        cfg = cell.cfg
        shape = cell.shape
        mflops = roofline.model_flops(cfg, shape)
        # Memory term: compulsory-traffic model (the CPU HLO's fusion
        # granularity overstates TPU HBM traffic ~10×; the HLO surface
        # count is recorded alongside as the pessimistic bound).
        model_axis = 16
        wsh = chips if cell.par.fsdp else model_axis
        analytic = roofline.analytic_hbm_bytes(
            cfg, shape, chips, weight_shards=wsh,
            kv_cache_int8=cell.par.kv_cache_int8)
        terms = roofline.roofline_terms(
            flops_per_chip=flops,
            bytes_per_chip=analytic["total"],
            wire_bytes_per_chip=csum["total_wire_bytes"],
            chips=chips, mflops=mflops)

        rec.update(
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            kind=cell.kind,
            memory=mem_stats,
            flops_per_chip=flops,
            bytes_per_chip=analytic["total"],
            bytes_breakdown=analytic,
            hlo_surface_bytes_per_chip=bytes_accessed,
            xla_cost={"flops": xla_flops, "bytes": xla_bytes},
            collectives=csum,
            roofline=terms,
            hlo_bytes=len(hlo),
        )
        if verbose:
            print(f"  collectives: {csum['n_ops']} ops, "
                  f"{csum['total_wire_bytes']:.3e} wire B/chip")
            print(f"  roofline: compute={terms['t_compute_s']:.4f}s "
                  f"memory={terms['t_memory_s']:.4f}s "
                  f"collective={terms['t_collective_s']:.4f}s "
                  f"dominant={terms['dominant']} "
                  f"fraction={terms['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001 — recorded, the matrix must finish
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"  ERROR {type(e).__name__}: {e}")
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--par", nargs="*", default=[],
                    help="ParallelConfig overrides, key=value")
    args = ap.parse_args()

    par_overrides = {}
    for kv in args.par:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            par_overrides[k] = v == "True"
        elif v.isdigit():
            par_overrides[k] = int(v)
        else:
            par_overrides[k] = v

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    if args.all:
        archs = list(configs.ARCHS)
        shapes = list(SHAPES)
    else:
        archs = [args.arch] if args.arch else list(configs.ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)

    results = []
    for arch in archs:
        cfg = configs.get_config(arch)
        for shape_name in shapes:
            shape = SHAPES[shape_name]
            if not shape_applicable(cfg, shape):
                print(f"[SKIP] {arch} × {shape_name}: long_500k needs "
                      f"sub-quadratic attention")
                results.append({"arch": arch, "shape": shape_name,
                                "status": "skip",
                                "reason": "full-attention arch"})
                continue
            for mesh_kind in meshes:
                fname = os.path.join(
                    args.out, f"{args.tag}_{arch}_{shape_name}_{mesh_kind}.json")
                if args.skip_existing and os.path.exists(fname):
                    print(f"[CACHED] {arch} × {shape_name} × {mesh_kind}")
                    continue
                print(f"[RUN] {arch} × {shape_name} × {mesh_kind}"
                      + (f" par={par_overrides}" if par_overrides else ""))
                rec = run_cell(arch, shape_name, mesh_kind,
                               par_overrides=par_overrides or None)
                results.append(rec)
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"  -> {fname} ({rec['status']}, {rec['total_s']}s)")

    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_err = sum(1 for r in results if r.get("status") == "error")
    n_skip = sum(1 for r in results if r.get("status") == "skip")
    print(f"\ndry-run complete: {n_ok} ok, {n_err} error, {n_skip} skip")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
