"""Production mesh definitions.

TPU v5e pod = 16×16 = 256 chips.  Single-pod mesh: (data=16, model=16).
Multi-pod adds a leading ``pod`` axis (2 pods = 512 chips): plain data
parallelism across pods, so the only cross-pod traffic is the gradient
all-reduce — deliberately matched to the ICI-vs-DCN bandwidth asymmetry.

Functions, not module constants: importing this module must never touch
jax device state (device count is frozen at first use, and tests want 1
device while the dry-run wants 512).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.models.config import ParallelConfig
from repro.parallel.sharding import ShardCtx


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use (1,1)/(2,2); elastic restarts resize)."""
    return jax.make_mesh(shape, axes)


def make_ctx(mesh: Optional[Mesh], par: ParallelConfig) -> ShardCtx:
    return ShardCtx(mesh=mesh, fsdp=par.fsdp,
                    seq_shard_acts=par.seq_shard_acts,
                    cache_layout=par.cache_layout)


# Hardware constants for the roofline (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
