"""Production mesh definitions.

TPU v5e pod = 16×16 = 256 chips.  Single-pod mesh: (data=16, model=16).
Multi-pod adds a leading ``pod`` axis (2 pods = 512 chips): plain data
parallelism across pods, so the only cross-pod traffic is the gradient
all-reduce — deliberately matched to the ICI-vs-DCN bandwidth asymmetry.

Functions, not module constants: importing this module must never touch
jax device state (device count is frozen at first use, and tests want 1
device while the dry-run wants 512).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.models.config import ParallelConfig
from repro.parallel.sharding import ShardCtx


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use (1,1)/(2,2); elastic restarts resize)."""
    return jax.make_mesh(shape, axes)


def make_ctx(mesh: Optional[Mesh], par: ParallelConfig,
             cfg=None) -> ShardCtx:
    """``cfg`` (a ModelConfig) gates the dedicated ``qkv_heads`` rule:
    the persisted [wq|wk|wv] concat shards over the model axis only when
    every segment's head count divides it — otherwise a shard boundary
    would cut across the q/k/v seams (8 KV heads on a 16-way axis) and
    the concat would stop being layout-neutral, so it replicates."""
    qkv_ok = True
    if cfg is not None and mesh is not None:
        t = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        hkv = cfg.num_kv_heads or cfg.num_heads
        qkv_ok = (cfg.num_heads % t == 0 and hkv % t == 0)
    return ShardCtx(mesh=mesh, fsdp=par.fsdp,
                    seq_shard_acts=par.seq_shard_acts,
                    cache_layout=par.cache_layout,
                    qkv_heads_shardable=qkv_ok)


# Hardware constants for the roofline (TPU v5e, per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
