"""Simulated multi-device host platform — one place for the XLA_FLAGS hack.

JAX freezes the device count at first initialization, so any entrypoint
that wants N simulated CPU devices (the production-mesh dry-run, the
collective profiler, the mesh-collective tests, router experiments) must
set ``--xla_force_host_platform_device_count`` **before importing jax**.
Three call sites used to each carry their own copy of that dance; they
now all route through :func:`ensure_host_devices`, and the
``REPRO_SIM_DEVICES`` env var overrides the requested count (``0``
disables the flag entirely — the real single-device platform), so tests
can spawn N simulated cells deterministically without editing scripts.

This module deliberately imports nothing from jax.
"""
from __future__ import annotations

import os

#: env override: the simulated device count, "0" = leave XLA untouched
ENV_VAR = "REPRO_SIM_DEVICES"

#: the production dry-run's multi-pod placeholder count (2 x 16 x 16)
DEFAULT_DEVICES = 512

_FLAG = "--xla_force_host_platform_device_count"


def sim_device_count(default: int = DEFAULT_DEVICES) -> int:
    """The effective simulated device count: env override, else default."""
    raw = os.environ.get(ENV_VAR)
    if raw is None:
        return default
    try:
        return max(0, int(raw))
    except ValueError:
        return default


def ensure_host_devices(count: int | None = None) -> int:
    """Install the forced host device count into ``XLA_FLAGS``.

    Must run before the first jax import (jax snapshots the flag at
    initialization); safe to call repeatedly — an existing forced count
    in ``XLA_FLAGS`` is replaced, other flags are preserved.  Returns
    the count installed (0 = nothing touched).
    """
    n = sim_device_count(DEFAULT_DEVICES if count is None else count)
    if n <= 0:
        return 0
    flags = [f for f in os.environ.get("XLA_FLAGS", "").split()
             if not f.startswith(_FLAG)]
    flags.append(f"{_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)
    return n
