"""Serving substrate: batched engine + data-parallel cell router."""
from repro.serve.engine import (BatchedEngine, PagePool, Request,
                                ServeConfig)
from repro.serve.router import CellRouter, make_cells

__all__ = ["ServeConfig", "BatchedEngine", "Request", "PagePool",
           "CellRouter", "make_cells"]
