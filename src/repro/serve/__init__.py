"""Serving substrate: batched engine with continuous batching."""
from repro.serve.engine import (BatchedEngine, PagePool, Request,
                                ServeConfig)

__all__ = ["ServeConfig", "BatchedEngine", "Request", "PagePool"]
