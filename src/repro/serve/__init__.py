"""Serving substrate: batched engine with continuous batching."""
from repro.serve.engine import ServeConfig, BatchedEngine, Request

__all__ = ["ServeConfig", "BatchedEngine", "Request"]
