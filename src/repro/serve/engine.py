"""Batched serving engine with a host-sync-free decode tick.

The decode state is a fixed [B, ...] cache pytree; requests claim a slot,
prefill writes that slot's cache entries, and every engine tick advances
ALL active slots by one token — the standard fixed-shape continuous-
batching layout (vLLM-style slots, without paging; the cache seq dim is
pre-sized to ``max_seq_len``).

The tick is **one device program and zero host transfers**:
``last_tokens``, the slot-liveness mask, and the per-slot remaining-token
budget are device-resident, and the jitted tick fuses decode + greedy
argmax + EOS/length masking, donating the cache and state buffers so the
update happens in place.  Per-token results accumulate as device arrays in
a history buffer; :meth:`sync` drains them to the ``Request`` objects with
a single stacked transfer.  Host synchronization happens only at
*admission* boundaries (a new request needs a prefill and a slot decision)
— never inside the steady-state tick loop.  This is the serving-side
application of the paper's §VII.C lesson: round-trips off the fast path
compound directly into tail latency.

Per-slot prefill uses a single-sequence prefill jit and writes the result
into the batch cache at the slot index (dynamic_update_slice), so a new
request joins without recompiling or disturbing other slots.  Admission is
batched: all admissible pending requests are prefilled, then their first
tokens cross to the host in one stacked transfer.

``serve_step`` (what the decode_32k / long_500k dry-run cells lower) is
exactly one engine tick: (params, tokens[B], cache) -> (logits, cache).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

#: max ticks between harvest syncs once admissions have drained: bounds
#: how much masked decode work a fully-EOS'd batch can waste
_SYNC_STRIDE = 64


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int          # B — concurrent decode slots
    max_seq_len: int          # cache capacity per slot
    max_new_tokens: int = 64
    eos_id: int = 1
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 64
    # filled by the engine
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig, policy=None):
        # ``policy`` (an ExecutionPolicy) overrides the model's resolved
        # lowering policy for this engine's jitted prefill/tick programs —
        # resolved once here, at trace-ownership time, so the engine's
        # compiled programs and the policy can never disagree.
        if policy is not None:
            model = model.with_policy(policy)
        self.model = model
        self.policy = getattr(model, "policy", None)
        # The layout the model's policy planned (models/config.ParamLayout).
        # The decode tick's q/k/v and ln2→[wi|wg] fusions activate only
        # when ``params`` actually carries the concatenated tensors —
        # block_decode inspects the pytree, so serving legacy params under
        # a fusing policy degrades gracefully to the PR 4 tick instead of
        # paying a per-token weight-concat tax.
        self.param_layout = getattr(model, "param_layout", None)
        self.params = params
        self.cfg = cfg
        b = cfg.batch_slots
        self.cache = model.init_cache(b, cfg.max_seq_len)
        self.slots: List[Optional[Request]] = [None] * b
        # ---- device-resident tick state (never read per tick) ----
        self.last_tokens = jnp.zeros((b,), jnp.int32)
        self.live = jnp.zeros((b,), jnp.bool_)
        self.remaining = jnp.zeros((b,), jnp.int32)
        self._history: List[jax.Array] = []   # [B] token vecs since sync
        self.tick_count = 0
        self.trace_count = 0                  # tick compilations (regression)
        self._prefill_one = jax.jit(self._prefill_one_impl)
        # Donate liveness/budget/cache so the update is in place on
        # backends that support donation (no-op warning on CPU).  The
        # token vector is NOT donated: each tick's output token array is
        # retained in self._history until sync(), and becomes the next
        # tick's input — donating it would delete a retained buffer.
        donate = (2, 3, 4) if jax.default_backend() in ("tpu", "gpu") \
            else ()
        self._tick = jax.jit(self._tick_impl, donate_argnums=donate)

    # ---- slot management ----

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                return i
        return None

    def _prefill_one_impl(self, params, tokens):
        """Single-sequence prefill -> (last_logits, cache_for_batch1)."""
        return self.model.prefill(params, {"tokens": tokens})

    def add_request(self, req: Request) -> bool:
        """Claim a slot and prefill it.  False if engine is full."""
        return self.admit([req]) == 1

    def admit(self, reqs: List[Request]) -> int:
        """Batched admission: prefill as many of ``reqs`` (in order) as
        there are free slots, then fetch all first tokens in ONE host
        transfer.  Returns how many requests were admitted."""
        self.sync()                    # make slot liveness current
        staged = []                    # (req, slot, first_token_device)
        for req in reqs:
            slot = self._free_slot()
            if slot is None:
                break
            # reap the finished occupant (exactly the slot we claim)
            req.slot = slot
            self.slots[slot] = req
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill_one(self.params, toks)
            self._write_slot(slot, cache1, len(req.prompt))
            staged.append((req, slot,
                           jnp.argmax(logits[0]).astype(jnp.int32)))
        if not staged:
            return 0
        idx = jnp.asarray([s for _, s, _ in staged], jnp.int32)
        firsts_dev = jnp.stack([t for _, _, t in staged])
        budgets = jnp.asarray(
            [max(r.max_new_tokens - 1, 0) for r, _, _ in staged], jnp.int32)
        firsts = np.asarray(firsts_dev)          # the one admission sync
        alive = []
        for (req, _, _), tok in zip(staged, firsts):
            tok = int(tok)
            req.generated.append(tok)
            req.done = (tok == self.cfg.eos_id
                        or len(req.generated) >= req.max_new_tokens)
            alive.append(not req.done)
        self.last_tokens = self.last_tokens.at[idx].set(firsts_dev)
        self.live = self.live.at[idx].set(jnp.asarray(alive))
        self.remaining = self.remaining.at[idx].set(budgets)
        return len(staged)

    def _write_slot(self, slot: int, cache1, prompt_len: int):
        """Copy a batch-1 prefill cache into batch slot ``slot``."""
        def write(full, one):
            # leading layout: either [layers, B, ...] or [B(=slots), ...]
            if one.ndim >= 2 and full.shape[0] == one.shape[0] \
                    and full.ndim == one.ndim \
                    and full.shape[1] == len(self.slots):
                # [layers, B, ...]: pad seq dims up to capacity
                pad = [(0, 0)] * one.ndim
                for ax in range(2, one.ndim):
                    pad[ax] = (0, full.shape[ax] - one.shape[ax])
                one_p = jnp.pad(one, pad)
                idx = (0, slot) + (0,) * (one.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    full, one_p.astype(full.dtype)[:, :1], idx)
            # [B, ...]
            pad = [(0, 0)] * one.ndim
            for ax in range(1, one.ndim):
                pad[ax] = (0, full.shape[ax] - one.shape[ax])
            one_p = jnp.pad(one, pad)
            idx = (slot,) + (0,) * (one.ndim - 1)
            return jax.lax.dynamic_update_slice(
                full, one_p.astype(full.dtype)[:1], idx)

        self.cache = jax.tree.map(write, self.cache, cache1)

    # ---- ticking ----

    def _tick_impl(self, params, tokens, live, remaining, cache):
        """Fused decode tick: decode + argmax + EOS/length masking.

        One compiled program; every input/output stays on device.  Dead
        slots keep their token frozen (the cache still advances, into
        masked positions — the fixed-shape batching contract)."""
        self.trace_count += 1            # python side effect: traces only
        logits, cache = self.model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(live, nxt, tokens)
        remaining = jnp.where(live, remaining - 1, remaining)
        live = live & (nxt != self.cfg.eos_id) & (remaining > 0)
        return nxt, live, remaining, cache

    def step(self) -> None:
        """One decode tick for all slots — zero host transfers.

        Emitted tokens land in the device-side history; call :meth:`sync`
        (or :meth:`run`, which does) to drain them into the requests."""
        nxt, self.live, self.remaining, self.cache = self._tick(
            self.params, self.last_tokens, self.live, self.remaining,
            self.cache)
        self.last_tokens = nxt
        self._history.append(nxt)
        self.tick_count += 1

    def sync(self) -> None:
        """Drain the device-side token history into the Request objects
        with a single stacked device->host transfer."""
        if not self._history:
            return
        hist = np.asarray(jnp.stack(self._history))   # [T, B], one transfer
        self._history = []
        for t in range(hist.shape[0]):
            for slot, req in enumerate(self.slots):
                if req is None or req.done:
                    continue
                tok = int(hist[t, slot])
                req.generated.append(tok)
                if tok == self.cfg.eos_id or \
                        len(req.generated) >= req.max_new_tokens:
                    req.done = True

    def run(self, requests: List[Request],
            max_ticks: int = 10_000) -> List[Request]:
        """Continuous batching: admit whenever a slot frees, tick until
        all requests finish.  Host syncs happen only at admission/harvest
        boundaries; between them the tick loop is transfer-free."""
        pending = list(requests)
        admitted: List[Request] = []
        while self.tick_count < max_ticks:
            if pending:
                n = self.admit(pending)       # syncs + reaps done slots
                admitted.extend(pending[:n])
                del pending[:n]
            else:
                self.sync()
            active = [r for r in self.slots if r is not None and not r.done]
            if not pending and not active:
                break
            if pending:
                # full house: tick once, then re-check for freed slots
                self.step()
            else:
                # no admissions left: run a transfer-free stretch, capped
                # so EOS-finished batches don't burn unbounded masked
                # ticks before the next sync notices everyone is done
                bound = max(r.max_new_tokens - len(r.generated)
                            for r in active)
                bound = min(bound, _SYNC_STRIDE,
                            max_ticks - self.tick_count)
                for _ in range(max(1, bound)):
                    self.step()
        self.sync()
        return admitted
