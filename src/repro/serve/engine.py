"""Batched serving engine with a host-sync-free decode tick.

The decode state is a fixed [B, ...] cache pytree; requests claim a slot,
prefill writes that slot's cache entries, and every engine tick advances
ALL active slots by one token — the standard fixed-shape continuous-
batching layout (vLLM-style slots; the cache seq dim is pre-sized to
``max_seq_len``).

With ``ServeConfig.page_size`` set, the cache strips become a **paged KV
cache**: a global pool of fixed-size pages plus device-resident per-slot
block tables (``models/transformer.py::init_paged_cache``).  Admission is
then by *page budget* — a request reserves exactly the pages its
``prompt + max_new_tokens`` frontier can reach, so short prompts stop
paying the ``max_seq_len`` capacity tax and the engine accepts work until
the pool is actually exhausted, not until slots are dense-full.  Reaping a
finished request releases its pages back to the pool (host-side refcounts
in :class:`PagePool`), and leading full prompt pages are **shared by
refcount** across requests with a common prefix — the frontier/tail page
is always freshly allocated, so the one page a slot writes during decode
is never aliased (copy-on-write without the copy).  Table rows of reaped
slots reset to the sentinel ``num_pages``: inside the tick their writes
drop and their gathers clamp onto masked data, which is what keeps the
tick ONE compiled program with zero host transfers under paging.

The tick is **one device program and zero host transfers**:
``last_tokens``, the slot-liveness mask, and the per-slot remaining-token
budget are device-resident, and the jitted tick fuses decode + greedy
argmax + EOS/length masking, donating the cache and state buffers so the
update happens in place.  Per-token results accumulate as device arrays in
a history buffer; :meth:`sync` drains them to the ``Request`` objects with
a single stacked transfer.  Host synchronization happens only at
*admission* boundaries (a new request needs a prefill and a slot decision)
— never inside the steady-state tick loop.  This is the serving-side
application of the paper's §VII.C lesson: round-trips off the fast path
compound directly into tail latency.

Per-slot prefill uses a single-sequence prefill jit and writes the result
into the batch cache at the slot index (dynamic_update_slice), so a new
request joins without recompiling or disturbing other slots.  Admission is
batched: all admissible pending requests are prefilled, then their first
tokens cross to the host in one stacked transfer.

``serve_step`` (what the decode_32k / long_500k dry-run cells lower) is
exactly one engine tick: (params, tokens[B], cache) -> (logits, cache).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

#: max ticks between harvest syncs once admissions have drained: bounds
#: how much masked decode work a fully-EOS'd batch can waste
_SYNC_STRIDE = 64


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int          # B — concurrent decode slots
    max_seq_len: int          # cache capacity per slot
    max_new_tokens: int = 64
    eos_id: int = 1
    greedy: bool = True
    # ---- paged KV cache (None = dense per-slot strips) ----
    page_size: Optional[int] = None    # tokens per KV page
    num_pages: Optional[int] = None    # pool size; None = dense-equivalent
    prefix_sharing: bool = True        # refcount-share full prompt pages
    # pool sized by a DEVICE-BYTE budget instead of a page count (used
    # when num_pages is None): capacity reflects the page footprint, so
    # an int8 KV cache fits ~4·hd/(hd+4)× more pages in the same bytes —
    # the quantization win expressed as admission capacity, not just
    # bandwidth (BatchedEngine.page_footprint_bytes)
    kv_pool_bytes: Optional[int] = None

    @property
    def paged(self) -> bool:
        return self.page_size is not None

    @property
    def max_pages_per_slot(self) -> int:
        assert self.page_size is not None
        return -(-self.max_seq_len // self.page_size)


class PagePool:
    """Host-side allocator for the global KV page pool.

    Pure bookkeeping — the pages themselves live on device inside the
    engine's cache pytree; this class only decides which page ids a
    request holds.  Every held page is refcounted: fresh pages start at
    1, prefix-shared pages gain a reference per sharer, and a page
    returns to the free list **only when its refcount reaches 0** (the
    invariant the conformance suite pins).

    Prefix sharing indexes *full* prompt pages by a chain hash (each
    page's hash folds in its predecessor's, so a hit guarantees the whole
    leading path matches, not just one page).  Only pages below a
    request's reservation tail are ever published or matched — the
    frontier page a slot writes during decode is always freshly
    allocated, so sharing never aliases a written page.
    """

    def __init__(self, num_pages: int, page_size: int):
        self.num_pages = num_pages
        self.page_size = page_size
        # pop() takes from the end: keep ids ascending for determinism
        self._free = list(range(num_pages - 1, -1, -1))
        self.refcount: dict = {}
        self._prefix: dict = {}       # chain hash -> page id
        self._hash_of: dict = {}      # page id -> chain hash (cleanup)
        self.shared_hits = 0          # pages NOT allocated thanks to sharing

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def occupied_pages(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, have {len(self._free)}")
        ids = [self._free.pop() for _ in range(n)]
        for p in ids:
            self.refcount[p] = 1
        return ids

    def retain(self, page_id: int) -> None:
        assert self.refcount.get(page_id, 0) > 0, page_id
        self.refcount[page_id] += 1

    def release(self, page_id: int) -> None:
        rc = self.refcount[page_id] - 1
        if rc > 0:
            self.refcount[page_id] = rc
            return
        # refcount 0: ONLY now does the page return to the free list
        del self.refcount[page_id]
        h = self._hash_of.pop(page_id, None)
        if h is not None:
            self._prefix.pop(h, None)
        self._free.append(page_id)

    def lookup_prefix(self, chain_hash) -> Optional[int]:
        return self._prefix.get(chain_hash)

    def publish_prefix(self, chain_hash, page_id: int) -> None:
        if chain_hash not in self._prefix and page_id not in self._hash_of:
            self._prefix[chain_hash] = page_id
            self._hash_of[page_id] = chain_hash

    @staticmethod
    def prefix_hashes(prompt: List[int], page_size: int) -> List:
        """One chain hash per FULL page of prompt tokens."""
        out, h = [], hash(("uisa-kv-page-chain",))
        for i in range(len(prompt) // page_size):
            h = hash((h, tuple(prompt[i * page_size:(i + 1) * page_size])))
            out.append(h)
        return out


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 64
    # filled by the engine
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # admission verdict: the request's page reservation can never fit the
    # pool (reserve > num_pages), so it is marked done without a slot
    # instead of livelocking the run() loop (ISSUE 9)
    rejected: bool = False


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig, policy=None):
        # ``policy`` (an ExecutionPolicy) overrides the model's resolved
        # lowering policy for this engine's jitted prefill/tick programs —
        # resolved once here, at trace-ownership time, so the engine's
        # compiled programs and the policy can never disagree.
        if policy is not None:
            model = model.with_policy(policy)
        self.model = model
        self.policy = getattr(model, "policy", None)
        # The layout the model's policy planned (models/config.ParamLayout).
        # The decode tick's q/k/v and ln2→[wi|wg] fusions activate only
        # when ``params`` actually carries the concatenated tensors —
        # block_decode inspects the pytree, so serving legacy params under
        # a fusing policy degrades gracefully to the PR 4 tick instead of
        # paying a per-token weight-concat tax.
        self.param_layout = getattr(model, "param_layout", None)
        self.params = params
        self.cfg = cfg
        b = cfg.batch_slots
        self._paged = cfg.paged
        if self._paged:
            self._max_pages = cfg.max_pages_per_slot
            # dense-equivalent pool by default; cfg.num_pages < B·maxp is
            # the page-budget admission regime (capacity by pages), and
            # cfg.kv_pool_bytes sizes the pool by device bytes — where an
            # int8 cache's smaller page footprint becomes extra capacity
            if cfg.num_pages is not None:
                self.num_pages = cfg.num_pages
            elif cfg.kv_pool_bytes is not None:
                self.num_pages = max(
                    cfg.kv_pool_bytes // self.page_footprint_bytes(), 1)
            else:
                self.num_pages = b * self._max_pages
            self.pool: Optional[PagePool] = PagePool(self.num_pages,
                                                     cfg.page_size)
            self._slot_pages: List[List[int]] = [[] for _ in range(b)]
            self.cache = model.init_paged_cache(
                b, self.num_pages, cfg.page_size, self._max_pages)
        else:
            self.pool = None
            self.cache = model.init_cache(b, cfg.max_seq_len)
        # per-tick device-resident stats vectors (paged mode), drained by
        # sync() into tick_stats rows alongside the token history
        self._stats_history: List[jax.Array] = []
        self.tick_stats: List[dict] = []
        self.slots: List[Optional[Request]] = [None] * b
        # ---- device-resident tick state (never read per tick) ----
        self.last_tokens = jnp.zeros((b,), jnp.int32)
        self.live = jnp.zeros((b,), jnp.bool_)
        self.remaining = jnp.zeros((b,), jnp.int32)
        self._history: List[jax.Array] = []   # [B] token vecs since sync
        self.tick_count = 0
        self.trace_count = 0                  # tick compilations (regression)
        self._prefill_one = jax.jit(self._prefill_one_impl)
        # Donate liveness/budget/cache so the update is in place on
        # backends that support donation (no-op warning on CPU).  The
        # token vector is NOT donated: each tick's output token array is
        # retained in self._history until sync(), and becomes the next
        # tick's input — donating it would delete a retained buffer.
        donate = (2, 3, 4) if jax.default_backend() in ("tpu", "gpu") \
            else ()
        self._tick = jax.jit(self._tick_impl, donate_argnums=donate)

    def page_footprint_bytes(self) -> int:
        """Device bytes one KV page costs across the layer stack: K + V
        pool blocks, plus the per-(token,head) f32 scale blocks when the
        cache is int8.  A token-position then costs ``hd + 4`` bytes per
        head per direction instead of ``4*hd`` — the 4·hd/(hd+4)
        capacity multiplier a fixed ``kv_pool_bytes`` budget realizes."""
        mcfg = self.model.cfg
        hkv, hd = mcfg.num_kv_heads, mcfg.resolved_head_dim
        ps = self.cfg.page_size
        if getattr(self.model.par, "kv_cache_int8", False):
            per_layer = 2 * hkv * ps * (hd + 4)
        else:
            per_layer = 2 * hkv * ps * hd * np.dtype(mcfg.dtype).itemsize
        return mcfg.num_layers * per_layer

    # ---- slot management ----

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                return i
        return None

    def _prefill_one_impl(self, params, tokens):
        """Single-sequence prefill -> (last_logits, cache_for_batch1)."""
        return self.model.prefill(params, {"tokens": tokens})

    def add_request(self, req: Request) -> bool:
        """Claim a slot and prefill it.  False if engine is full."""
        return self.admit([req]) == 1

    def admit(self, reqs: List[Request]) -> int:
        """Batched admission: prefill as many of ``reqs`` (in order) as
        there are free slots — and, under paging, free *pages* — then
        fetch all first tokens in ONE host transfer.  Returns how many
        requests were admitted."""
        self.sync()                    # make slot liveness current
        if self._paged:
            self._reap_done_pages()    # page budget current before admitting
        staged = []                    # (req, slot, first_token_device)
        consumed = 0                   # prefix of reqs taken (staged+rejected)
        for req in reqs:
            if self._paged and self._page_reserve(req) > self.num_pages:
                # the reservation exceeds the pool's *total* — no amount
                # of draining ever admits this request; reject it here so
                # run() never spins on it (the ISSUE 9 livelock)
                req.rejected = True
                req.done = True
                consumed += 1
                continue
            slot = self._free_slot()
            if slot is None:
                break
            if self._paged:
                plan = self._plan_pages(req)
                if plan is None:
                    break              # pool exhausted: stop admitting
            # reap the finished occupant (exactly the slot we claim)
            req.slot = slot
            self.slots[slot] = req
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            logits, cache1 = self._prefill_one(self.params, toks)
            if self._paged:
                self._write_slot_paged(slot, cache1, len(req.prompt),
                                       *plan)
            else:
                self._write_slot(slot, cache1, len(req.prompt))
            staged.append((req, slot,
                           jnp.argmax(logits[0]).astype(jnp.int32)))
            consumed += 1
        if not staged:
            return consumed
        idx = jnp.asarray([s for _, s, _ in staged], jnp.int32)
        firsts_dev = jnp.stack([t for _, _, t in staged])
        budgets = jnp.asarray(
            [max(r.max_new_tokens - 1, 0) for r, _, _ in staged], jnp.int32)
        firsts = np.asarray(firsts_dev)          # the one admission sync
        alive = []
        for (req, _, _), tok in zip(staged, firsts):
            tok = int(tok)
            req.generated.append(tok)
            req.done = (tok == self.cfg.eos_id
                        or len(req.generated) >= req.max_new_tokens)
            alive.append(not req.done)
        self.last_tokens = self.last_tokens.at[idx].set(firsts_dev)
        self.live = self.live.at[idx].set(jnp.asarray(alive))
        self.remaining = self.remaining.at[idx].set(budgets)
        return consumed

    def _write_slot(self, slot: int, cache1, prompt_len: int):
        """Copy a batch-1 prefill cache into batch slot ``slot``."""
        def write(full, one):
            # leading layout: either [layers, B, ...] or [B(=slots), ...]
            if one.ndim >= 2 and full.shape[0] == one.shape[0] \
                    and full.ndim == one.ndim \
                    and full.shape[1] == len(self.slots):
                # [layers, B, ...]: pad seq dims up to capacity
                pad = [(0, 0)] * one.ndim
                for ax in range(2, one.ndim):
                    pad[ax] = (0, full.shape[ax] - one.shape[ax])
                one_p = jnp.pad(one, pad)
                idx = (0, slot) + (0,) * (one.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    full, one_p.astype(full.dtype)[:, :1], idx)
            # [B, ...]
            pad = [(0, 0)] * one.ndim
            for ax in range(1, one.ndim):
                pad[ax] = (0, full.shape[ax] - one.shape[ax])
            one_p = jnp.pad(one, pad)
            idx = (slot,) + (0,) * (one.ndim - 1)
            return jax.lax.dynamic_update_slice(
                full, one_p.astype(full.dtype)[:1], idx)

        self.cache = jax.tree.map(write, self.cache, cache1)

    # ---- paged slot management ----

    def _reap_done_pages(self) -> None:
        """Release every finished slot's pages and reset its table row to
        the sentinel.  Safe while the slot's ``pos`` keeps advancing in
        the tick: sentinel entries drop writes, so a page handed to the
        next request can never be touched by its previous owner."""
        for slot, req in enumerate(self.slots):
            if req is None or not req.done or not self._slot_pages[slot]:
                continue
            for p in self._slot_pages[slot]:
                self.pool.release(p)
            self._slot_pages[slot] = []
            self.cache["block_tables"] = \
                self.cache["block_tables"].at[slot].set(self.num_pages)

    def _page_reserve(self, req: Request) -> int:
        """Pages ``req``'s ``prompt + max_new_tokens - 1`` frontier can
        ever reach (the :meth:`_plan_pages` reservation size) — admission
        rejects outright when this exceeds the pool's total."""
        ps = self.cfg.page_size
        total = min(len(req.prompt) + max(req.max_new_tokens, 1) - 1,
                    self.cfg.max_seq_len)
        total = max(total, len(req.prompt))
        return -(-total // ps)

    def _plan_pages(self, req: Request):
        """Reserve the pages ``req`` can ever reach, sharing leading full
        prompt pages by refcount.  Returns ``(page_ids, n_shared)`` or
        None when the pool cannot cover the reservation (nothing is
        mutated on failure).

        The reservation covers ``prompt + max_new_tokens - 1`` token
        positions (the final sampled token is never written back), capped
        at ``max_seq_len`` — so the tick allocates nothing and admission
        is the only allocation boundary.  Sharing is capped at
        ``reserve - 1`` pages: the tail page is always exclusively owned,
        which is what makes decode writes alias-free by construction."""
        ps = self.cfg.page_size
        reserve = self._page_reserve(req)
        shared: List[int] = []
        hashes = (PagePool.prefix_hashes(req.prompt, ps)[:reserve - 1]
                  if self.cfg.prefix_sharing else [])
        for h in hashes:
            pid = self.pool.lookup_prefix(h)
            if pid is None:
                break
            shared.append(pid)
        if reserve - len(shared) > self.pool.free_pages:
            return None
        for pid in shared:
            self.pool.retain(pid)
        self.pool.shared_hits += len(shared)
        page_ids = shared + self.pool.alloc(reserve - len(shared))
        for h, pid in zip(hashes, page_ids):
            self.pool.publish_prefix(h, pid)
        return page_ids, len(shared)

    def _write_slot_paged(self, slot: int, cache1, prompt_len: int,
                          page_ids: List[int], n_shared: int) -> None:
        """Scatter a batch-1 prefill cache into the slot's reserved pages.

        Only the *fresh* prompt pages are written — shared prefix pages
        already hold identical bytes and are never rewritten (the
        refcount invariant backs the aliasing argument, this backs the
        data one).  Reserved-but-unreached generation pages keep stale
        pool contents; every read of them sits past the ``pos`` mask."""
        ps = self.cfg.page_size
        self._slot_pages[slot] = page_ids
        row = np.full((self._max_pages,), self.num_pages, np.int32)
        row[:len(page_ids)] = page_ids
        tables = self.cache["block_tables"].at[slot].set(jnp.asarray(row))
        pos = self.cache["pos"].at[slot].set(prompt_len)
        new_cache = dict(self.cache, block_tables=tables, pos=pos)
        n_prompt_pages = -(-prompt_len // ps)
        write_ids = page_ids[n_shared:n_prompt_pages]
        if write_ids:
            ids = jnp.asarray(write_ids, jnp.int32)
            pad = n_prompt_pages * ps - prompt_len
            pairs = [("k_pages", "k"), ("v_pages", "v")]
            if "k_scale_pages" in new_cache:
                # int8 pools: the prefill's quantized strips carry scale
                # strips ([L,1,Hkv,plen,1]) that scatter through the same
                # page ids into the parallel scale pools
                pairs += [("k_scale_pages", "k_scale"),
                          ("v_scale_pages", "v_scale")]
            for pool_name, strip_name in pairs:
                strip = cache1[strip_name][:, 0]        # [L,Hkv,plen,hd]
                if pad:
                    strip = jnp.pad(
                        strip, ((0, 0), (0, 0), (0, pad), (0, 0)))
                nl, hkv, _, hd = strip.shape
                pages = strip.reshape(nl, hkv, n_prompt_pages, ps, hd
                                      ).transpose(0, 2, 1, 3, 4)
                pages = pages[:, n_shared:n_prompt_pages]
                new_cache[pool_name] = new_cache[pool_name].at[:, ids].set(
                    pages.astype(new_cache[pool_name].dtype))
        self.cache = new_cache

    # ---- ticking ----

    def _tick_impl(self, params, tokens, live, remaining, cache):
        """Fused decode tick: decode + argmax + EOS/length masking.

        One compiled program; every input/output stays on device.  Dead
        slots keep their token frozen (the cache still advances, into
        masked positions — the fixed-shape batching contract)."""
        self.trace_count += 1            # python side effect: traces only
        logits, cache = self.model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(live, nxt, tokens)
        remaining = jnp.where(live, remaining - 1, remaining)
        live = live & (nxt != self.cfg.eos_id) & (remaining > 0)
        if not self._paged:
            return nxt, live, remaining, cache
        # per-tick observability, computed inside the one program: live
        # slot count + pages actually reached by live frontiers.  A tiny
        # device vector appended to history — harvested by sync(), so the
        # tick stays transfer-free.
        # ceil, not floor+1: a frontier sitting exactly on a page boundary
        # (pos == k·ps) has written k pages, not k+1 (ISSUE 9 off-by-one)
        frontier = jnp.where(live, -(-cache["pos"] // self.cfg.page_size),
                             0)
        stats = jnp.stack([jnp.sum(live.astype(jnp.int32)),
                           jnp.sum(frontier).astype(jnp.int32)])
        return nxt, live, remaining, cache, stats

    def step(self) -> None:
        """One decode tick for all slots — zero host transfers.

        Emitted tokens land in the device-side history; call :meth:`sync`
        (or :meth:`run`, which does) to drain them into the requests."""
        out = self._tick(self.params, self.last_tokens, self.live,
                         self.remaining, self.cache)
        if self._paged:
            nxt, self.live, self.remaining, self.cache, stats = out
            self._stats_history.append(stats)
        else:
            nxt, self.live, self.remaining, self.cache = out
        self.last_tokens = nxt
        self._history.append(nxt)
        self.tick_count += 1

    def _pending_harvest(self) -> dict:
        """The device half of :meth:`sync`: stack the token history (and
        the paged per-tick stats vectors) into device arrays and clear
        the buffers.  Nothing is transferred here — the caller fetches
        the returned dict (this engine's own :meth:`sync`, or a
        :class:`~repro.serve.router.CellRouter` stacking *every* cell's
        pending harvest into one ``device_get``)."""
        pending: dict = {}
        if self._history:
            pending["hist"] = jnp.stack(self._history)   # [T, B]
            self._history = []
        if self._stats_history:
            pending["stats"] = jnp.stack(self._stats_history)   # [T, 2]
            pending["stats_base"] = self.tick_count \
                - len(self._stats_history)
            self._stats_history = []
        return pending

    def _apply_harvest(self, harvest: dict) -> None:
        """The host half of :meth:`sync`: replay a fetched harvest into
        the Request objects and the tick_stats rows."""
        hist = harvest.get("hist")
        if hist is not None:
            for t in range(hist.shape[0]):
                for slot, req in enumerate(self.slots):
                    if req is None or req.done:
                        continue
                    tok = int(hist[t, slot])
                    req.generated.append(tok)
                    if tok == self.cfg.eos_id or \
                            len(req.generated) >= req.max_new_tokens:
                        req.done = True
        rows = harvest.get("stats")
        if rows is not None:
            base = int(harvest["stats_base"])
            for i in range(rows.shape[0]):
                # device columns are per-tick; the pool columns are the
                # host allocator's view at harvest time (admission-grain)
                self.tick_stats.append({
                    "tick": base + i,
                    "live_slots": int(rows[i, 0]),
                    "frontier_pages": int(rows[i, 1]),
                    "pool_occupied_pages": self.pool.occupied_pages,
                    "pool_utilization":
                        self.pool.occupied_pages / max(self.num_pages, 1),
                    "shared_prefix_hits": self.pool.shared_hits,
                })

    def sync(self) -> None:
        """Drain the device-side token history into the Request objects
        with a single stacked device->host transfer (the paged per-tick
        stats vectors ride in the same fetch)."""
        pending = self._pending_harvest()
        if pending:
            self._apply_harvest(jax.device_get(pending))

    def run(self, requests: List[Request],
            max_ticks: int = 10_000) -> List[Request]:
        """Continuous batching: admit whenever a slot frees, tick until
        all requests finish.  Host syncs happen only at admission/harvest
        boundaries; between them the tick loop is transfer-free."""
        pending = list(requests)
        admitted: List[Request] = []
        while self.tick_count < max_ticks:
            n = 0
            if pending:
                n = self.admit(pending)       # syncs + reaps done slots
                admitted.extend(pending[:n])
                del pending[:n]
            else:
                self.sync()
            active = [r for r in self.slots if r is not None and not r.done]
            if not pending and not active:
                break
            if pending and not active and n == 0:
                # nothing running and nothing admissible: ticking cannot
                # free capacity, so spinning to max_ticks would livelock.
                # (Rejection above consumes never-admittable requests;
                # this guards the residual stuck-admission case.)
                break
            if pending:
                # full house: tick once, then re-check for freed slots
                self.step()
            else:
                # no admissions left: run a transfer-free stretch, capped
                # so EOS-finished batches don't burn unbounded masked
                # ticks before the next sync notices everyone is done
                bound = max(r.max_new_tokens - len(r.generated)
                            for r in active)
                bound = min(bound, _SYNC_STRIDE,
                            max_ticks - self.tick_count)
                for _ in range(max(1, bound)):
                    self.step()
        self.sync()
        return admitted
