"""Batched serving engine with continuous batching over decode slots.

The decode state is a fixed [B, ...] cache pytree; requests claim a slot,
prefill writes that slot's cache entries, and every engine tick advances
ALL active slots by one token (one jitted ``decode_step``).  Finished or
empty slots keep decoding garbage into masked positions — the standard
fixed-shape continuous-batching layout (vLLM-style slots, without paging;
the cache seq dim is pre-sized to ``max_seq_len``).

Per-slot prefill uses a single-sequence prefill jit and writes the result
into the batch cache at the slot index (dynamic_update_slice), so a new
request joins without recompiling or disturbing other slots.

``serve_step`` (what the decode_32k / long_500k dry-run cells lower) is
exactly one engine tick: (params, tokens[B], cache) -> (logits, cache).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ServeConfig:
    batch_slots: int          # B — concurrent decode slots
    max_seq_len: int          # cache capacity per slot
    max_new_tokens: int = 64
    eos_id: int = 1
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 64
    # filled by the engine
    slot: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchedEngine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.cache = model.init_cache(cfg.batch_slots, cfg.max_seq_len)
        self.slots: List[Optional[Request]] = [None] * cfg.batch_slots
        self.last_tokens = np.zeros((cfg.batch_slots,), np.int32)
        self._decode = jax.jit(model.decode_step)
        self._prefill_one = jax.jit(self._prefill_one_impl)

    # ---- slot management ----

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None or r.done:
                return i
        return None

    def _prefill_one_impl(self, params, tokens):
        """Single-sequence prefill -> (last_logits, cache_for_batch1)."""
        return self.model.prefill(params, {"tokens": tokens})

    def add_request(self, req: Request) -> bool:
        """Claim a slot and prefill it.  False if engine is full."""
        slot = self._free_slot()
        if slot is None:
            return False
        req.slot = slot
        self.slots[slot] = req
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill_one(self.params, toks)
        self._write_slot(slot, cache1, len(req.prompt))
        nxt = int(jnp.argmax(logits[0]))
        self.last_tokens[slot] = nxt
        req.generated.append(nxt)
        return True

    def _write_slot(self, slot: int, cache1, prompt_len: int):
        """Copy a batch-1 prefill cache into batch slot ``slot``."""
        def write(full, one):
            # leading layout: either [layers, B, ...] or [B(=slots), ...]
            if one.ndim >= 2 and full.shape[0] == one.shape[0] \
                    and full.ndim == one.ndim \
                    and full.shape[1] == len(self.slots):
                # [layers, B, ...]: pad seq dims up to capacity
                pad = [(0, 0)] * one.ndim
                for ax in range(2, one.ndim):
                    pad[ax] = (0, full.shape[ax] - one.shape[ax])
                one_p = jnp.pad(one, pad)
                idx = (0, slot) + (0,) * (one.ndim - 2)
                return jax.lax.dynamic_update_slice(
                    full, one_p.astype(full.dtype)[:, :1], idx)
            # [B, ...]
            pad = [(0, 0)] * one.ndim
            for ax in range(1, one.ndim):
                pad[ax] = (0, full.shape[ax] - one.shape[ax])
            one_p = jnp.pad(one, pad)
            idx = (slot,) + (0,) * (one.ndim - 1)
            return jax.lax.dynamic_update_slice(
                full, one_p.astype(full.dtype)[:1], idx)

        self.cache = jax.tree.map(write, self.cache, cache1)

    # ---- ticking ----

    def step(self) -> Dict[int, int]:
        """One decode tick for all slots; returns {rid: new_token}."""
        tokens = jnp.asarray(self.last_tokens)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        out = {}
        for slot, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(nxt[slot])
            req.generated.append(tok)
            self.last_tokens[slot] = tok
            out[req.rid] = tok
            if tok == self.cfg.eos_id or \
                    len(req.generated) >= req.max_new_tokens:
                req.done = True
        return out

    def run(self, requests: List[Request],
            max_ticks: int = 10_000) -> List[Request]:
        """Continuous batching: admit whenever a slot frees, tick until
        all requests finish."""
        pending = list(requests)
        admitted: List[Request] = []
        ticks = 0
        while (pending or any(r is not None and not r.done
                              for r in self.slots)) and ticks < max_ticks:
            while pending and self._free_slot() is not None:
                req = pending.pop(0)
                # reap the finished occupant, if any
                slot = self._free_slot()
                if self.slots[slot] is not None:
                    self.slots[slot] = None
                self.add_request(req)
                admitted.append(req)
            self.step()
            ticks += 1
        return admitted
