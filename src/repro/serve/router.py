"""Data-parallel router over N serving cells (ISSUE 10).

Scale-out for the paged serving engine: N independent
:class:`~repro.serve.engine.BatchedEngine` cells — each keeping its
one-compiled-program / zero-per-tick-transfer invariant — behind one
admission point.  The router is pure host-side policy; it adds **no**
per-tick host synchronization:

- **Admission** routes each request (FIFO, like the engine's own
  ``admit``) to a cell chosen by, in order:

  1. *prefix affinity* — the cell whose :class:`PagePool` holds the
     deepest chain-hash match for the request's leading full prompt
     pages.  Shared-prefix requests land on the cell that owns the
     pages, so refcount sharing keeps working across a fleet (pages are
     device-resident per cell; a prefix split across cells shares
     nothing).
  2. *least-loaded page budget* — most free pages (dense cells: most
     free slots); ties break to the lowest cell index for determinism.

  Failover walks the remaining candidates when the chosen cell cannot
  take the request (pool exhausted, slots full); a request no candidate
  can take stops admission (FIFO order is preserved — the engine
  contract).  A request whose page reservation exceeds *every* usable
  cell's total pool is rejected outright (the engine's own
  never-admittable rule, applied fleet-wide).

- **Draining**: :meth:`drain` removes a cell from admission (its
  resident requests finish normally — the failover path for a cell
  whose pool is exhausted or needs recycling); :meth:`undrain` restores
  it.

- **Harvest**: :meth:`sync` collects every cell's pending device-side
  history/stats (:meth:`BatchedEngine._pending_harvest`) and fetches
  them in **one** ``jax.device_get``, then replays each cell's host
  bookkeeping — N cells cost one stacked transfer per harvest, exactly
  like one cell.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax

from repro.serve.engine import (BatchedEngine, PagePool, Request,
                                _SYNC_STRIDE)


class CellRouter:
    def __init__(self, cells: Sequence[BatchedEngine],
                 prefix_affinity: bool = True):
        if not cells:
            raise ValueError("CellRouter needs at least one cell")
        self.cells: List[BatchedEngine] = list(cells)
        self.prefix_affinity = prefix_affinity
        self._drained = set()
        self.tick_count = 0

    # ---- observability ----

    @property
    def num_cells(self) -> int:
        return len(self.cells)

    def active_requests(self) -> List[Request]:
        return [r for c in self.cells for r in c.slots
                if r is not None and not r.done]

    def drain(self, cell: int) -> None:
        """Stop admitting to ``cell`` (resident requests finish)."""
        self._drained.add(cell)

    def undrain(self, cell: int) -> None:
        self._drained.discard(cell)

    @property
    def drained(self) -> frozenset:
        return frozenset(self._drained)

    def cell_stats(self) -> List[dict]:
        """Per-cell load/occupancy snapshot (the profile script's rows)."""
        out = []
        for i, c in enumerate(self.cells):
            row = {"cell": i, "drained": i in self._drained,
                   "ticks": c.tick_count,
                   "live_slots": sum(1 for r in c.slots
                                     if r is not None and not r.done),
                   "slots": len(c.slots)}
            if c.pool is not None:
                row.update(
                    num_pages=c.num_pages,
                    occupied_pages=c.pool.occupied_pages,
                    utilization=c.pool.occupied_pages
                    / max(c.num_pages, 1),
                    shared_prefix_hits=c.pool.shared_hits)
            out.append(row)
        return out

    # ---- admission policy ----

    def _usable(self, req: Request) -> List[int]:
        """Cells that could *ever* hold ``req``: not drained, pool total
        covers the page reservation (dense cells always qualify)."""
        out = []
        for i, c in enumerate(self.cells):
            if i in self._drained:
                continue
            if c.pool is not None and c._page_reserve(req) > c.num_pages:
                continue
            out.append(i)
        return out

    def _affinity_depth(self, cell: BatchedEngine, req: Request) -> int:
        """Leading full prompt pages of ``req`` already resident in
        ``cell``'s pool (the chain-hash guarantees the whole path)."""
        if cell.pool is None or not cell.cfg.prefix_sharing:
            return 0
        depth = 0
        for h in PagePool.prefix_hashes(req.prompt, cell.cfg.page_size):
            if cell.pool.lookup_prefix(h) is None:
                break
            depth += 1
        return depth

    def _load_key(self, i: int):
        """Least-loaded rank: most free pages (dense: most free slots)
        first, then lowest index — a deterministic total order."""
        c = self.cells[i]
        if c.pool is not None:
            free = c.pool.free_pages
        else:
            free = sum(1 for r in c.slots if r is None or r.done)
        return (-free, i)

    def _candidates(self, req: Request) -> List[int]:
        usable = self._usable(req)
        usable.sort(key=self._load_key)
        if self.prefix_affinity and usable:
            depths = {i: self._affinity_depth(self.cells[i], req)
                      for i in usable}
            best = max(depths.values())
            if best > 0:
                # affinity cells first (deepest match, then load), the
                # load-ordered rest as failover
                usable.sort(key=lambda i: (-depths[i],) + self._load_key(i))
        return usable

    def admit(self, reqs: List[Request]) -> int:
        """Route as many of ``reqs`` (in order) as the fleet can take.

        Each request tries its candidate cells in policy order — the
        failover walk — and admission stops at the first request no cell
        can take (FIFO, the single-engine contract).  Returns the
        consumed prefix length (admitted + rejected)."""
        consumed = 0
        for req in reqs:
            candidates = self._candidates(req)
            if not candidates:
                if any(i not in self._drained
                       for i in range(len(self.cells))):
                    # admitting cells exist but none can EVER hold the
                    # reservation: reject fleet-wide (the engine's own
                    # never-admittable rule), keep consuming
                    req.rejected = True
                    req.done = True
                    consumed += 1
                    continue
                break                    # everything drained: hold the queue
            placed = False
            for i in candidates:
                if self.cells[i].admit([req]) == 1:
                    placed = True
                    break
            if not placed:
                break                    # fleet saturated: FIFO stop
            consumed += 1
        return consumed

    # ---- the transfer-free tick fan-out ----

    def step(self) -> None:
        """One decode tick on every cell — zero host transfers (each
        cell's tick is its own compiled program; the router adds only
        python dispatch)."""
        for c in self.cells:
            c.step()
        self.tick_count += 1

    def sync(self) -> None:
        """Harvest every cell in ONE stacked device->host fetch."""
        pendings = [c._pending_harvest() for c in self.cells]
        if not any(pendings):
            return
        fetched = jax.device_get(pendings)       # the one transfer
        for cell, harvest in zip(self.cells, fetched):
            if harvest:
                cell._apply_harvest(harvest)

    # ---- the serve loop ----

    def run(self, requests: List[Request],
            max_ticks: int = 10_000) -> List[Request]:
        """Continuous batching across the fleet — the router-level mirror
        of :meth:`BatchedEngine.run` (same livelock guards, same
        harvest-bounded transfer-free stretches)."""
        pending = list(requests)
        admitted: List[Request] = []
        while self.tick_count < max_ticks:
            n = 0
            if pending:
                n = self.admit(pending)   # per-cell admit syncs + reaps
                admitted.extend(pending[:n])
                del pending[:n]
            else:
                self.sync()
            active = self.active_requests()
            if not pending and not active:
                break
            if pending and not active and n == 0:
                break                     # nothing can free capacity
            if pending:
                self.step()
            else:
                bound = max(r.max_new_tokens - len(r.generated)
                            for r in active)
                bound = min(bound, _SYNC_STRIDE,
                            max_ticks - self.tick_count)
                for _ in range(max(1, bound)):
                    self.step()
        self.sync()
        return admitted


def make_cells(model, params, cfg, n_cells: int,
               policy=None) -> CellRouter:
    """N identical cells over shared model+params, one router.

    ``cfg`` describes ONE cell (so ``n_cells`` multiplies the fleet's
    slot and page capacity); params are shared device buffers — data
    parallelism over requests, not replication cost."""
    cells = [BatchedEngine(model, params, cfg, policy=policy)
             for _ in range(n_cells)]
    return CellRouter(cells)
