"""qwen3-32b — dense with qk_norm, GQA.

[hf:Qwen/Qwen3-8B; hf]
64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936, head_dim=128,
per-head RMS q/k normalization (the qwen3 signature).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="qwen3-32b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    dtype="float32",
)
