"""mistral-nemo-12b — dense, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    max_seq_len=131072,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="mistral-nemo-12b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    rope_theta=1000000.0,
    dtype="float32",
)
