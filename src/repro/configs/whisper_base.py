"""whisper-base — encoder-decoder audio backbone, conv frontend stubbed.

[arXiv:2212.04356; unverified]
6L (decoder) d_model=512 8H d_ff=2048 vocab=51865; 6-layer encoder over
1500 stub frame embeddings.  LayerNorm + GELU + learned positions per the
whisper lineage.  The assigned decode shapes stretch the decoder context
far past whisper's real 448 — they lower fine; the pos_embed table is
sized to cover them.
"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    encdec=EncDecConfig(encoder_layers=6, num_frames=1500),
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    max_seq_len=36864,          # covers decode_32k cache + margin
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="whisper-base-reduced",
    family="encdec",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    encdec=EncDecConfig(encoder_layers=2, num_frames=16),
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    max_seq_len=128,
    dtype="float32",
)
