"""granite-moe-3b-a800m — 40 experts, top-8, tiny expert d_ff=512.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.
The tiny per-expert FFN makes dispatch overhead the dominant cost — the
stress case for the routing path.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, capacity_factor=1.25,
                  group_size=4096),
    tie_embeddings=True,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=4, capacity_factor=1.25,
                  group_size=64),
    tie_embeddings=True,
    dtype="float32",
)
