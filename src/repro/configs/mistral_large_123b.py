"""mistral-large-123b — the largest assigned dense config.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768, head_dim=128.
The memory-pressure case: FSDP + TP are mandatory for this to fit.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1000000.0,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="mistral-large-123b-reduced",
    family="dense",
    num_layers=3,
    d_model=96,
    num_heads=6,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    dtype="float32",
)
