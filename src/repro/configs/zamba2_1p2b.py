"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention block.

[arXiv:2411.15242; hf]
38L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=32000, ssm_state=64.
One transformer block's weights are shared across periodic applications
(every 6 mamba layers); Zamba2's per-application LoRA deltas are
simplified away (DESIGN.md §5).  Sub-quadratic: runs long_500k.
"""
from repro.models.config import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk_size=256),
    hybrid=HybridConfig(attn_every=6),
    subquadratic=True,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk_size=16),
    hybrid=HybridConfig(attn_every=2),
    subquadratic=True,
    dtype="float32",
)
