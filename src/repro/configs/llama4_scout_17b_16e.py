"""llama4-scout-17b-16e — MoE 16 experts top-1 + 1 shared expert.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
'Early fusion' refers to the multimodal frontend, which per the
assignment is out of scope for the LM backbone; we build the text MoE
decoder.  Llama4 routes top-1 with a shared expert, which we keep.
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(num_experts=16, top_k=1, capacity_factor=1.25,
                  group_size=4096, shared_experts=1),
    rope_theta=500000.0,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="llama4-scout-17b-16e-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=1, capacity_factor=1.25,
                  group_size=64, shared_experts=1),
    rope_theta=500000.0,
    dtype="float32",
)
