"""llava-next-mistral-7b — VLM: mistral-7b text backbone + patch stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The anyres vision tower is a STUB per the assignment: ``input_specs``
supplies 576 precomputed patch embeddings that are prepended to the text
sequence (so the backbone sees exactly the assigned seq_len positions).
"""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    vlm=VLMConfig(num_patches=576),
    rope_theta=1000000.0,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="llava-next-mistral-7b-reduced",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    vlm=VLMConfig(num_patches=8),
    dtype="float32",
)
