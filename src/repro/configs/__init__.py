"""Assigned-architecture registry (10 archs) + dry-run input specs.

Every module in this package defines:
  CONFIG   — the exact assigned full-size ModelConfig
  REDUCED  — a same-family config small enough for a CPU smoke test

``get_config(name)`` / ``get_reduced(name)`` resolve by arch id (dashes or
underscores).  ``input_specs(cfg, shape, par)`` builds the
ShapeDtypeStruct stand-ins each dry-run cell lowers against — no device
allocation anywhere on this path.
"""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import (ModelConfig, ParallelConfig, ShapeConfig,
                                 SHAPES, shape_applicable)

ARCHS = (
    "llama4-scout-17b-16e",
    "granite-moe-3b-a800m",
    "mistral-nemo-12b",
    "granite-8b",
    "qwen3-32b",
    "mistral-large-123b",
    "whisper-base",
    "zamba2-1.2b",
    "mamba2-2.7b",
    "llava-next-mistral-7b",
)


def _module(name: str):
    mod_name = name.replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}


# ---------------------------------------------------------------------------
# Dry-run input specs
# ---------------------------------------------------------------------------


def runnable_cells():
    """All (arch, shape) pairs; skipped cells carry a reason string."""
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if not shape_applicable(cfg, shape):
                cells.append((arch, shape.name, "SKIP: full-attention arch; "
                              "long_500k requires sub-quadratic attention"))
            else:
                cells.append((arch, shape.name, None))
    return cells


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Train/prefill batch as ShapeDtypeStructs.

    VLM: the patch stub occupies part of the assigned seq_len so the
    backbone sees exactly shape.seq_len positions.
    """
    b = shape.global_batch
    s = shape.seq_len
    specs = {}
    if cfg.family == "vlm":
        s_txt = s - cfg.vlm.num_patches
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vlm.num_patches, cfg.d_model), jnp.float32)
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s_txt), jnp.int32)
        return specs
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.family in ("encdec", "audio"):
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.num_frames, cfg.d_model), jnp.float32)
    return specs


def decode_specs(model, shape: ShapeConfig):
    """(tokens, cache) ShapeDtypeStructs for one serve_step."""
    b = shape.global_batch
    cache = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len))
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    return tokens, cache


def params_specs(model):
    """Parameter tree as ShapeDtypeStructs (no allocation)."""
    return jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
