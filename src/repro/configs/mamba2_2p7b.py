"""mamba2-2.7b — attention-free SSM (SSD / state-space duality).

[arXiv:2405.21060; unverified]
64L d_model=2560 (attn-free) vocab=50280, ssm_state=128.
d_inner = 2·d_model = 5120, 80 heads of dim 64, 1 B/C group.
Sub-quadratic: the long_500k cell is the showcase (state is O(1) in
sequence length).  The attention kernel is inapplicable to this family
(DESIGN.md §5); UISA governs the SSD chunk GEMMs and scan reductions.
"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, n_groups=1,
                  conv_width=4, chunk_size=256),
    subquadratic=True,
    tie_embeddings=True,
    dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="mamba2-2.7b-reduced",
    family="ssm",
    num_layers=3,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, n_groups=1,
                  conv_width=4, chunk_size=16),
    subquadratic=True,
    tie_embeddings=True,
    dtype="float32",
)
