"""Roofline derivation from compiled dry-run artifacts."""
from repro.roofline.analysis import (parse_collectives, roofline_terms,
                                     collective_summary, model_flops)

__all__ = ["parse_collectives", "roofline_terms", "collective_summary",
           "model_flops"]
