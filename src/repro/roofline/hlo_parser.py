"""Trip-count-aware post-optimization-HLO analyzer.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — under scanned
layers (all our models scan) it undercounts FLOPs/bytes/collectives by
~num_layers×.  This module parses the partitioned HLO text into its
computation graph, discovers loop trip counts from the loop conditions,
and accumulates three quantities with correct loop multiplicity:

  flops       — 2·M·N·K per dot (from result shape × contracted dims),
                recursing into fusions and while/call/conditional bodies.
  hbm_bytes   — Σ (operand + result bytes) over non-fused surface ops:
                fusion nodes count their boundary tensors only (their
                internals stay in registers/VMEM), control ops are free.
                This is the fusion-boundary traffic model of HBM load.
  collectives — wire-byte records (roofline/analysis.py ring model),
                multiplied by enclosing trip counts.

All shapes in the partitioned module are already per-device, so every
number this produces is per-chip.

Trip counts: scan-lowered loops compare the induction variable against a
literal; we take the max integer constant in the condition computation
(exact for every loop this framework emits; falls back to 1).
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-_]+)\s*(?:\([^{]*)?\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"^((?:\([^)]*\)|\S+)\s+)?([\w\-]+)\(")
_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w\.\-_]+)"),
    "condition": re.compile(r"condition=%?([\w\.\-_]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-_]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-_]+)"),
}
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_COMP_RE = re.compile(r"true_computation=%?([\w\.\-_]+)")
_FALSE_COMP_RE = re.compile(r"false_computation=%?([\w\.\-_]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLLECTIVE_OPS = {"all-reduce", "all-gather", "reduce-scatter",
                   "all-to-all", "collective-permute",
                   "all-reduce-start", "all-gather-start",
                   "collective-permute-start", "reduce-scatter-start",
                   "all-to-all-start"}
_CONTROL_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "partition-id", "replica-id",
                "iota", "copy-start", "copy-done"}


def _shape_elems_bytes(dtype: str, dims: str) -> Tuple[int, float]:
    elems = 1
    if dims:
        for d in dims.split(","):
            elems *= int(d)
    return elems, elems * _DTYPE_BYTES.get(dtype, 0)


def _all_shapes_bytes(text: str) -> float:
    return sum(_shape_elems_bytes(m.group(1), m.group(2))[1]
               for m in _SHAPE_RE.finditer(text))


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_dtype: str
    result_dims: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)
    # symbol table: op name -> (dtype, dims)
    symbols: Dict[str, Tuple[str, str]] = dataclasses.field(
        default_factory=dict)
    max_int_const: int = 0


class HloModule:
    def __init__(self, text: str, total_devices: int):
        self.total_devices = total_devices
        self.comps: Dict[str, Computation] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo_flops: Dict[str, float] = {}
        self._memo_bytes: Dict[str, float] = {}
        self._memo_coll: Dict[str, List[dict]] = {}

    # ---- parsing ----

    def _parse(self, text: str):
        cur: Optional[Computation] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if cur is None:
                m = _COMP_START_RE.match(line)
                if m and "=" not in line.split("(")[0]:
                    cur = Computation(m.group(2))
                    if m.group(1):
                        self.entry = m.group(2)
                continue
            if line.strip() == "}":
                self.comps[cur.name] = cur
                cur = None
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rhs = m.group(1), m.group(2)
            mo = _OPCODE_RE.match(rhs)
            opcode = mo.group(2) if mo else ""
            sh = _SHAPE_RE.search(rhs.split("(")[0] or rhs)
            dtype, dims = (sh.group(1), sh.group(2)) if sh else ("", "")
            cur.symbols[name] = (dtype, dims)
            cur.ops.append(Op(name, opcode, dtype, dims, line))
            for c in _CONST_RE.finditer(rhs):
                cur.max_int_const = max(cur.max_int_const, int(c.group(1)))

    # ---- loop structure ----

    def trip_count(self, while_line: str) -> int:
        m = _ATTR_COMP_RE["condition"].search(while_line)
        if not m:
            return 1
        cond = self.comps.get(m.group(1))
        if cond is None or cond.max_int_const <= 0:
            return 1
        return cond.max_int_const

    def _callees(self, op: Op) -> List[Tuple[str, int]]:
        """[(computation, multiplier)] invoked by this op."""
        line = op.line
        if op.opcode == "while":
            body = _ATTR_COMP_RE["body"].search(line)
            if body:
                return [(body.group(1), self.trip_count(line))]
            return []
        out = []
        for key in ("to_apply", "calls"):
            m = _ATTR_COMP_RE[key].search(line)
            if m:
                out.append((m.group(1), 1))
        mb = _BRANCHES_RE.search(line)
        if mb:
            for name in mb.group(1).split(","):
                out.append((name.strip().lstrip("%"), 1))
        for rx in (_TRUE_COMP_RE, _FALSE_COMP_RE):
            m = rx.search(line)
            if m:
                out.append((m.group(1), 1))
        return out

    # ---- FLOPs ----

    def _dot_flops(self, op: Op, comp: Computation) -> float:
        res_elems, _ = _shape_elems_bytes(op.result_dtype, op.result_dims)
        cd = _LHS_CDIMS_RE.search(op.line)
        if not cd:
            return 2.0 * res_elems          # degenerate dot
        cdims = [int(x) for x in cd.group(1).split(",") if x]
        operands = op.line.split("(", 1)[1]
        names = re.findall(r"%([\w\.\-_]+)", operands)
        if not names:
            return 2.0 * res_elems
        lhs = comp.symbols.get(names[0])
        if lhs is None:
            return 2.0 * res_elems
        ldims = [int(x) for x in lhs[1].split(",") if x]
        k = 1
        for d in cdims:
            if d < len(ldims):
                k *= ldims[d]
        return 2.0 * res_elems * k

    def comp_flops(self, name: str) -> float:
        if name in self._memo_flops:
            return self._memo_flops[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        self._memo_flops[name] = 0.0       # cycle guard
        for op in comp.ops:
            if op.opcode == "dot":
                total += self._dot_flops(op, comp)
            elif op.opcode == "convolution":
                # rough: 2 * result_elems * (kernel elems / out channels)
                res_elems, _ = _shape_elems_bytes(op.result_dtype,
                                                  op.result_dims)
                total += 2.0 * res_elems
            for callee, mult in self._callees(op):
                total += mult * self.comp_flops(callee)
        self._memo_flops[name] = total
        return total

    # ---- bytes (fusion-boundary traffic) ----

    def _op_bytes(self, op: Op, comp: Computation) -> float:
        _, res_bytes = _shape_elems_bytes(op.result_dtype, op.result_dims)
        operands = op.line.split("(", 1)[1] if "(" in op.line else ""
        opd_bytes = 0.0
        for nm in re.findall(r"%([\w\.\-_]+)", operands.split(")")[0]):
            sym = comp.symbols.get(nm)
            if sym:
                _, b = _shape_elems_bytes(*sym)
                opd_bytes += b
        return res_bytes + opd_bytes

    def comp_bytes(self, name: str) -> float:
        if name in self._memo_bytes:
            return self._memo_bytes[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0
        total = 0.0
        self._memo_bytes[name] = 0.0
        for op in comp.ops:
            if op.opcode in _CONTROL_OPS:
                continue
            callees = self._callees(op)
            if op.opcode in ("while", "call", "conditional"):
                for callee, mult in callees:
                    total += mult * self.comp_bytes(callee)
                continue
            # fusion / plain op: surface bytes only
            total += self._op_bytes(op, comp)
        self._memo_bytes[name] = total
        return total

    # ---- collectives ----

    def _is_rs_rewritable(self, op: Op, comp: Computation,
                          res_bytes: float, g: int) -> bool:
        """True when this all-reduce matches the all-reduce+slice pattern
        the TPU backend's ReduceScatterCreator rewrites to reduce-scatter.

        The CPU backend never forms reduce-scatter, so every TP partial-
        sum combine whose result is immediately re-sharded (our seq-
        sharded residual layout) shows up as a full-price all-reduce
        here.  Pricing it as RS models the TPU lowering, not a wish:
        consumers must all take ≤ 1/g of the result.
        """
        if g <= 1:
            return False
        pat = re.compile(r"%" + re.escape(op.name) + r"(?![\w\.\-])")
        consumers = []
        for other in comp.ops:
            if other.name == op.name:
                continue
            tail = other.line.split("=", 1)[-1]
            if pat.search(tail):
                consumers.append(other)
        if not consumers:
            return False
        limit = res_bytes / g * 1.5
        for c in consumers:
            _, cb = _shape_elems_bytes(c.result_dtype, c.result_dims)
            if cb == 0 or cb > limit:
                return False
        return True

    def _coll_record(self, op: Op, comp: Computation) -> dict:
        line = op.line
        _, res_bytes = _shape_elems_bytes(op.result_dtype, op.result_dims)
        if not res_bytes:
            res_bytes = _all_shapes_bytes(line.split("(")[0])
        operands = line.split("(", 1)[1] if "(" in line else ""
        opd_bytes = _all_shapes_bytes(operands.split(")")[0])
        m = _GROUPS_IOTA_RE.search(line)
        if m:
            g = int(m.group(2))
        else:
            m2 = _GROUPS_EXPL_RE.search(line)
            g = len(m2.group(1).split(",")) if m2 else self.total_devices
        g = max(g, 1)
        base = op.opcode.replace("-start", "")
        if base == "all-reduce":
            if self._is_rs_rewritable(op, comp, res_bytes, g):
                wire = res_bytes * (g - 1) / g
                base = "all-reduce(->rs)"
            else:
                wire = 2.0 * res_bytes * (g - 1) / g
        elif base == "all-gather":
            wire = res_bytes * (g - 1) / g
        elif base == "reduce-scatter":
            wire = max(opd_bytes, res_bytes) * (g - 1) / g
        elif base == "all-to-all":
            wire = res_bytes * (g - 1) / g
        else:                               # collective-permute
            wire = res_bytes
        return {"op": base, "result_bytes": res_bytes,
                "operand_bytes": opd_bytes, "group_size": g,
                "wire_bytes": wire, "count": 1,
                "shape": f"{op.result_dtype}[{op.result_dims}]"}

    def comp_collectives(self, name: str) -> List[dict]:
        if name in self._memo_coll:
            return self._memo_coll[name]
        comp = self.comps.get(name)
        if comp is None:
            return []
        recs: List[dict] = []
        self._memo_coll[name] = []
        for op in comp.ops:
            if op.opcode in _COLLECTIVE_OPS:
                recs.append(self._coll_record(op, comp))
            for callee, mult in self._callees(op):
                for r in self.comp_collectives(callee):
                    r2 = dict(r)
                    r2["wire_bytes"] = r["wire_bytes"] * mult
                    r2["count"] = r["count"] * mult
                    recs.append(r2)
        self._memo_coll[name] = recs
        return recs

    # ---- public ----

    def analyze(self) -> dict:
        entry = self.entry or next(iter(self.comps))
        colls = self.comp_collectives(entry)
        by_op = defaultdict(lambda: {"count": 0, "wire_bytes": 0.0})
        wire_f32 = 0.0
        for r in colls:
            by_op[r["op"]]["count"] += r["count"]
            by_op[r["op"]]["wire_bytes"] += r["wire_bytes"]
            if r["shape"].startswith("f32"):
                wire_f32 += r["wire_bytes"]
        total = sum(r["wire_bytes"] for r in colls)
        # XLA CPU legalizes bf16 arithmetic to f32, so activation/weight
        # collectives appear as f32 in the partitioned module; the TPU
        # target keeps them bf16.  corrected = f32 wire halved (models
        # hold all large cross-chip tensors in bf16; genuinely-f32
        # cross-chip tensors, e.g. CE scalars, are vanishingly small).
        corrected = total - wire_f32 / 2.0
        return {
            "flops": self.comp_flops(entry),
            "hbm_bytes": self.comp_bytes(entry),
            "collectives": {
                "total_wire_bytes": corrected,
                "raw_wire_bytes_cpu_f32": total,
                "wire_bytes_f32_share": wire_f32 / total if total else 0.0,
                "n_ops": int(sum(r["count"] for r in colls)),
                "by_op": {k: dict(v) for k, v in by_op.items()},
            },
        }


def analyze_hlo(text: str, total_devices: int) -> dict:
    """Per-chip flops / hbm_bytes / collective wire bytes, loop-aware."""
    return HloModule(text, total_devices).analyze()
