"""Three-term roofline from the compiled dry-run.

    compute term    = HLO_FLOPs  / peak_FLOP/s        (per chip)
    memory term     = HLO_bytes  / HBM_bw             (per chip)
    collective term = wire_bytes / link_bw            (per chip)

``cost_analysis()`` of the SPMD-partitioned executable reports *per-chip*
FLOPs and bytes, so all three terms are per-chip seconds and directly
comparable; the dominant one is the step-time lower bound.

collective_bytes is NOT in cost_analysis: ``parse_collectives`` scans the
post-optimization HLO for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops and models per-chip wire traffic with
the standard ring costs:

    all-reduce      2·S·(G-1)/G      (reduce-scatter + all-gather phases)
    all-gather      S·(G-1)/G        (S = gathered result size)
    reduce-scatter  S_in·(G-1)/G
    all-to-all      S·(G-1)/G
    collective-permute  S

where G is the replica-group size parsed from the op's replica_groups.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig, ShapeConfig

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[256,4096,128]{3,2,1,0}"  or  "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# start-style:  %x = TYPE all-gather(...)  /  fusion-wrapped variants
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# iota-format groups: replica_groups=[2,256]<=[512]
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
# explicit groups: replica_groups={{0,1,2},{3,4,5}}
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# permute pairs: source_target_pairs={{0,1},{1,2}}
_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    elems = 1
    if dims:
        for d in dims.split(","):
            elems *= int(d)
    return elems * _DTYPE_BYTES[dtype]


def _result_bytes(line: str) -> float:
    """Sum of the op's result-tuple byte size (first shape group(s))."""
    # take shapes before the opcode name (the '=' left side result types)
    head = line.split("(", 1)[0]
    total = 0.0
    for m in _SHAPE_RE.finditer(head):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _operand_bytes(line: str) -> float:
    tail = line.split("(", 1)[1] if "(" in line else ""
    total = 0.0
    for m in _SHAPE_RE.finditer(tail.split(")")[0]):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        n_groups, g = int(m.group(1)), int(m.group(2))
        return max(g, 1)
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int) -> List[Dict]:
    """Scan post-optimization HLO; one record per collective op."""
    out = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in line:
            continue                      # count the -start only
        res = _result_bytes(line)
        opd = _operand_bytes(line)
        g = _group_size(line, total_devices)
        if op == "all-reduce":
            wire = 2.0 * res * (g - 1) / max(g, 1)
        elif op == "all-gather":
            wire = res * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = opd * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            wire = res * (g - 1) / max(g, 1)
        else:                             # collective-permute
            wire = res
        out.append({"op": op, "result_bytes": res, "operand_bytes": opd,
                    "group_size": g, "wire_bytes": wire})
    return out


def collective_summary(records: List[Dict]) -> Dict:
    by_op = defaultdict(lambda: {"count": 0, "wire_bytes": 0.0})
    for r in records:
        by_op[r["op"]]["count"] += 1
        by_op[r["op"]]["wire_bytes"] += r["wire_bytes"]
    total = sum(v["wire_bytes"] for v in by_op.values())
    return {"total_wire_bytes": total, "by_op": dict(by_op),
            "n_ops": len(records)}


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig,
                       chips: int,
                       weight_shards: Optional[int] = None,
                       kv_cache_int8: bool = False) -> Dict:
    """Compulsory per-chip HBM traffic for one step (TPU fusion model).

    The CPU-compiled HLO's fusion granularity is far finer than the TPU
    target's (flash-attention/MLP chains that live in VMEM on TPU hit
    fusion boundaries on CPU), so surface-byte counts from the dry-run
    HLO overstate HBM traffic by ~10×.  This model counts only the
    traffic NO schedule can avoid, per chip:

      weights    P/chips × bytes × passes   (3 for train: fwd+remat+bwd)
      optimizer  38 B/param/chip (grad rw4+4, m rw, v rw, master rw @f32,
                 param write @bf16) — train only
      acts       per-token-per-layer boundary tensors × tokens/chips ×
                 3 (train) or 1 (prefill); flash/MLP internals excluded
                 (VMEM-resident on the TPU target)
      moe        dispatch/combine one-hot [S,E,C] tensors (GShard
                 baseline) — the honest cost of one-hot routing
      cache      full read (+ slot write) for decode; write for prefill
      logits     B·S·V f32 × 3 for train (fwd write, bwd read+write)

    Returned dict itemizes the terms (EXPERIMENTS.md shows the split).
    """
    db = 2  # bf16 weights/activations
    P = cfg.param_count()
    L = cfg.num_layers
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size

    if shape.kind == "train":
        tokens, passes, logit_passes = B * S, 3, 3
    elif shape.kind == "prefill":
        tokens, passes, logit_passes = B * S, 1, 0
    else:
        tokens, passes, logit_passes = B, 1, 0

    # weight_shards: how many ways the resident weights are split
    # (== chips under FSDP+TP; == model-axis size when fsdp=False and
    # each data replica holds a full TP shard)
    wsh = weight_shards or chips
    weights = P * db * (3 if shape.kind == "train" else 1) / wsh
    optimizer = 38.0 * P / chips if shape.kind == "train" else 0.0

    # per-token per-layer activation boundary elements
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        act_elems = 5 * d + 2 * h * hd + 2 * hkv * hd + 2 * dff
    elif cfg.family in ("ssm",):
        d_in = cfg.ssm.expand * d
        act_elems = 3 * d + 6 * d_in
    else:  # hybrid: mamba backbone + amortized shared attn block
        d_in = cfg.ssm.expand * d
        act_elems = 3 * d + 6 * d_in + (5 * d + 4 * h * hd + 2 * dff) \
            / max(cfg.hybrid.attn_every, 1)
    acts = act_elems * db * tokens * L * passes / chips

    moe_bytes = 0.0
    if cfg.moe is not None:
        m = cfg.moe
        cap_per_token = m.top_k * m.capacity_factor
        ec = cfg.moe.num_experts * max(
            8, int(m.group_size * cap_per_token / m.num_experts))
        # dispatch + combine one-hots, written + read, f32
        moe_bytes = tokens * ec * 4.0 * 2 * 2 * passes / chips

    cache_bytes = 0.0
    if shape.kind in ("prefill", "decode"):
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            # int8 cache: 1 byte/elem + f32 scale per (token, head)
            kv_db = (1.0 + 4.0 / hd) if kv_cache_int8 else db
            kv = L * B * hkv * S * hd * kv_db * 2       # K and V
            cache_bytes = kv / chips
        elif cfg.family == "ssm":
            s_ = cfg.ssm
            nh = s_.expand * d // s_.head_dim
            cache_bytes = (L * B * nh * s_.state_dim * s_.head_dim * 4 * 2
                           / chips)
        else:  # hybrid
            s_ = cfg.ssm
            nh = s_.expand * d // s_.head_dim
            n_apps = L // cfg.hybrid.attn_every
            cache_bytes = (L * B * nh * s_.state_dim * s_.head_dim * 4 * 2
                           + n_apps * B * hkv * S * hd * db * 2) / chips
        if shape.kind == "decode":
            cache_bytes *= 1.0      # full read dominates; slot write ~0
    logits = (B * S * v * 4.0 * logit_passes / chips
              if shape.kind == "train"
              else B * v * 4.0 / chips)

    total = weights + optimizer + acts + moe_bytes + cache_bytes + logits
    return {"total": total, "weights": weights, "optimizer": optimizer,
            "acts": acts, "moe_dispatch": moe_bytes, "cache": cache_bytes,
            "logits": logits}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Useful-work FLOPs: 6·N·D train, 2·N·D prefill, 2·N·B decode
    (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # one decoded token


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   wire_bytes_per_chip: float, chips: int,
                   mflops: float,
                   peak: float = PEAK_FLOPS_BF16,
                   hbm: float = HBM_BW,
                   link: float = ICI_BW) -> Dict:
    t_compute = flops_per_chip / peak
    t_memory = bytes_per_chip / hbm
    t_collective = wire_bytes_per_chip / link
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = mflops / chips / peak if mflops else 0.0
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "step_lower_bound_s": bound,
        "model_flops": mflops,
        "model_flops_per_chip": mflops / chips if mflops else 0.0,
        "useful_compute_s": useful,
        # fraction of the bound that is useful model compute — the
        # roofline fraction this report optimizes
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        # how much of compiled compute is useful (remat/padding waste)
        "model_vs_hlo_flops": (mflops / chips) / flops_per_chip
        if flops_per_chip > 0 else 0.0,
    }
