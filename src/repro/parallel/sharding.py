"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP on one table).

Mesh axes (launch/mesh.py):
  pod    — DP across pods (only gradient all-reduce crosses it; matches the
           ICI-vs-DCN cost asymmetry)
  data   — DP/FSDP axis within a pod
  model  — TP/EP axis

Logical axes used by layers/params resolve through RULES.  GSPMD handles
non-divisible dimensions by padding (e.g. 40 heads on a 16-way model
axis), which the roofline's MODEL_FLOPS/HLO ratio makes visible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


def _rules(fsdp: bool, seq_shard_acts: bool, cache_layout: str,
           qkv_heads_shardable: bool = True):
    # cache_layout: how the decode KV cache maps onto the mesh —
    #   batch_heads  batch -> (pod,data), kv heads -> model
    #                (needs num_kv_heads divisible by the model axis)
    #   batch_seq    batch -> (pod,data), cache seq -> model
    #                (the GQA-few-heads layout: seq always divides)
    #   seq_all      cache seq -> (data, model)  (long-context, batch=1)
    assert cache_layout in ("batch_heads", "batch_seq", "seq_all")
    return {
        # ---- activations ----
        "act_batch": ("pod", "data"),
        "act_seq": "model" if seq_shard_acts else None,
        "act_seq_unsharded": None,
        "act_embed": None,
        "act_mlp": "model",
        "act_heads": "model",
        "act_kv_heads": "model" if cache_layout == "batch_heads" else None,
        "act_head_dim": None,
        "act_vocab": "model",
        "act_experts": "model",
        "act_capacity": None,
        "act_group": ("pod", "data"),
        "act_kv_seq": {"batch_heads": None, "batch_seq": "model",
                       "seq_all": ("data", "model")}[cache_layout],
        "act_cache_batch": None if cache_layout == "seq_all"
        else ("pod", "data"),
        "act_ssm_heads": "model",
        "act_ssm_state": None,
        "act_frames": None,
        # ---- parameters ----
        "embed": "data" if fsdp else None,     # FSDP/ZeRO-3 axis
        "vocab": "model",
        "q_heads": "model",
        "kv_heads": "model",
        # the persisted [wq|wk|wv] concat (ISSUE 10, carried from PR 5):
        # TP-shardable only when every segment's head count divides the
        # model axis — otherwise a shard boundary would cut across the
        # q/k/v seams and the concat would stop being layout-neutral
        # against separately-sharded wq/wk/wv, so it replicates instead
        "qkv_heads": "model" if qkv_heads_shardable else None,
        "heads_merged": "model",
        "head_dim": None,
        "mlp": "model",
        "experts": "model",
        "expert_mlp": None,
        "ssm_inner": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_width": None,
        "norm": None,
        "frames": None,
    }


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Resolves logical axis names to a PartitionSpec for the active mesh.

    ``mesh=None`` (unit tests, single-device) makes every operation the
    identity, so model code is mesh-agnostic.
    """

    mesh: Optional[Mesh] = None
    fsdp: bool = True
    seq_shard_acts: bool = True
    cache_layout: str = "batch_heads"
    #: whether the persisted [wq|wk|wv] concat may shard over the model
    #: axis (launch/mesh.py::make_ctx computes this from the config:
    #: num_heads AND num_kv_heads both divisible by the axis size)
    qkv_heads_shardable: bool = True

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        rules = _rules(self.fsdp, self.seq_shard_acts, self.cache_layout,
                       self.qkv_heads_shardable)
        axes = []
        used = set()
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            ax = rules[name]
            # an axis may appear at most once in a spec; later duplicates
            # degrade to replicated (GSPMD requirement)
            if isinstance(ax, tuple):
                ax = tuple(a for a in ax if a not in used
                           and (self.mesh is None or a in self.mesh.axis_names))
                used.update(ax)
                axes.append(ax if ax else None)
            else:
                if ax in used or (self.mesh is not None and ax is not None
                                  and ax not in self.mesh.axis_names):
                    axes.append(None)
                else:
                    if ax is not None:
                        used.add(ax)
                    axes.append(ax)
        return P(*axes)

    def sharding(self, logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical))


def shard(x: jax.Array, logical: Sequence[Optional[str]],
          ctx: Optional[ShardCtx]) -> jax.Array:
    """with_sharding_constraint against logical axes (identity w/o mesh)."""
    if ctx is None or ctx.mesh is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))


def tree_shardings(ctx: ShardCtx, spec_tree):
    """Map a pytree of logical-axis tuples to NamedShardings (or None)."""
    if ctx.mesh is None:
        return jax.tree.map(lambda _: None, spec_tree,
                            is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(lambda ax: ctx.sharding(ax), spec_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_sharding(ctx: ShardCtx):
    """Sharding for host-side [B, S] token batches."""
    if ctx.mesh is None:
        return None
    return ctx.sharding(("act_batch", "act_seq_unsharded"))


def sanitize_sharding(sh: Optional[NamedSharding], sds) -> Optional[NamedSharding]:
    """Drop spec axes that do not divide the argument's global dims.

    jit in_/out_shardings (unlike internal constraints, which GSPMD pads)
    require exact divisibility.  Assigned configs are full of non-2^k
    dims — 40 experts, vocab 49155/50280/51865, 8 KV heads on a 16-way
    axis — so argument shardings are sanitized per-leaf: for each dim,
    keep the longest axis-tuple prefix whose size product divides it.
    The dropped axis means that dim is replicated (recorded, visible in
    the dry-run memory analysis), never a compile failure.
    """
    if sh is None:
        return None
    mesh = sh.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = sh.spec
    dims = sds.shape
    new_axes = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(dims):
            new_axes.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        prod = 1
        for n in names:
            if dims[i] % (prod * sizes[n]) == 0:
                kept.append(n)
                prod *= sizes[n]
            else:
                break
        new_axes.append(tuple(kept) if len(kept) > 1
                        else (kept[0] if kept else None))
    return NamedSharding(mesh, P(*new_axes))


def sanitize_tree(shardings, sds_tree):
    """Map :func:`sanitize_sharding` over matching pytrees."""
    if shardings is None:
        return None
    return jax.tree.map(
        sanitize_sharding, shardings, sds_tree,
        is_leaf=lambda x: x is None or isinstance(x, NamedSharding))
