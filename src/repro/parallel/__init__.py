"""Distribution layer: logical-axis sharding, meshes, compression."""
from repro.parallel.sharding import (ShardCtx, shard, tree_shardings,
                                     batch_sharding)

__all__ = ["ShardCtx", "shard", "tree_shardings", "batch_sharding"]
