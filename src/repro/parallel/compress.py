"""Gradient-compression wire formats for the cross-pod (DCN) boundary.

The cost asymmetry this targets: intra-pod ICI is ~50 GB/s/link, while the
pod-to-pod boundary is the slow hop.  The mesh keeps plain data
parallelism across ``pod``, so the ONLY cross-pod traffic is the gradient
all-reduce — exactly the tensor worth compressing.

Two formats:

- ``bf16``: free (params/grads are already bf16); halves wire bytes vs
  fp32 reference.  This is the default the dry-run measures.
- ``int8``: per-tensor-scale symmetric quantization.  The error-feedback
  residual (train/optim.py) makes the quantization noise contractive, the
  standard 1-bit-Adam-family correctness argument.

``allreduce_int8`` implements the int8 exchange as all-gather(int8) +
local dequant-sum, because a raw int8 all-reduce would wrap: with P pods
the payload is N·(P-1) int8 bytes vs N·2·(P-1)/P·4 fp32 bytes — a 4-8×
wire saving for P ≤ 4 (and P is small: pods are expensive).  Callers run
it under shard_map with ``axis`` bound to the pod mesh axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def allreduce_int8(g: jax.Array, axis: str) -> jax.Array:
    """Mean over ``axis`` moving int8 on the wire (all-gather + local sum).

    Must run inside shard_map with ``axis`` a bound mesh axis name.
    """
    q, scale = quantize_int8(g)
    qs = jax.lax.all_gather(q, axis)             # int8 on the wire
    scales = jax.lax.all_gather(scale, axis)     # one f32 per pod
    deq = qs.astype(jnp.float32) * scales.reshape(
        (-1,) + (1,) * (qs.ndim - 1))
    return jnp.mean(deq, axis=0)


def allreduce_bf16(g: jax.Array, axis: str) -> jax.Array:
    """Mean over ``axis`` with a bf16 wire format (psum in bf16)."""
    n = jax.lax.psum(1, axis)
    return (jax.lax.psum(g.astype(jnp.bfloat16), axis)
            .astype(jnp.float32) / n)
