"""Flash attention Pallas kernel — the framework's compound hot-spot.

The paper lists attention as future work ("compound workloads ... remain
as future work", §VII.D); this framework supplies it because every
assigned architecture's serving/training path is attention- (or SSD-)
dominated.  Built entirely from UISA primitives + native features:

- online-softmax accumulators live in VMEM scratch (managed scratchpad),
- the KV loop is the sequential ('arbitrary') grid dimension with async
  block pipelining (async memory + barrier primitives),
- causal block *skipping* is masked-divergence predication lifted to the
  grid level (a native feature: it exploits dimension_semantics),
- the two matmuls route through the queried MXU tile.

Variants:
- ``native``: block-skip + MXU-aligned blocks + target-native row reduce.
- ``abstract+shuffle``: the online-softmax row-max/row-sum cross-lane
  stages run through the in-register rotate tree (primitive 11,
  ``row_reduce_shuffle``) — zero scratch round-trips.
- ``abstract``: the same stages tree-reduce through *scratchpad
  round-trips* (``scratch_tree_reduce``), no block-skip (mask-only,
  every block visited).

The jnp chunked oracle used by models for CPU dry-runs lives in
models/layers.py; the dense oracle is kernels/ref.py:attention.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, align_up, fold_rows, register_op_space,
                        row_reduce_shuffle, scratch_tree_bytes,
                        scratch_tree_reduce, tree_stages,
                        tuned_attention_blocks, validate_contract)
from repro.core.pipeline import CompilerParams
from repro.kernels import ref as _ref

NEG_INF = -1e30  # finite sentinel: keeps exp() NaN-free on fully-masked rows
LANES = TARGET.W
DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 256
register_op_space("flash_attention", "attention")


def resolve_blocks(mode: str, sq: int, skv: int, d: int,
                   block_q=None, block_kv=None,
                   plan_dialect: str | None = None):
    """Caller-pinned blocks win; otherwise the autotuner table (the
    ``plan_dialect`` slice; None = ambient policy's dialect), then the
    static defaults.  Shared by the kernel and ``structural_cost`` so the
    modeled block accounting matches the executed tiling."""
    if block_q is None or block_kv is None:
        tuned = tuned_attention_blocks(mode, sq, skv, d,
                                       dialect=plan_dialect)
        tq, tkv = tuned if tuned else (DEFAULT_BLOCK_Q, DEFAULT_BLOCK_KV)
        block_q = tq if block_q is None else block_q
        block_kv = tkv if block_kv is None else block_kv
    return block_q, block_kv

ABSTRACT_CONTRACT = KernelContract(
    kernel="flash_attention", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MASKED_DIVERGENCE,
        Primitive.MANAGED_SCRATCHPAD, Primitive.WORKGROUP_BARRIER,
        Primitive.HIERARCHICAL_MEMORY, Primitive.IDENTITY_REGISTERS,
        Primitive.ASYNC_MEMORY, Primitive.REGISTER_OCCUPANCY,
    }))
SHUFFLE_CONTRACT = KernelContract(
    kernel="flash_attention", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=ABSTRACT_CONTRACT.primitives | {Primitive.LANE_SHUFFLE})
NATIVE_CONTRACT = KernelContract(
    kernel="flash_attention", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"mxu_aligned_tiles", "dimension_semantics",
                               "multi_buffering"}))
for _c in (ABSTRACT_CONTRACT, SHUFFLE_CONTRACT, NATIVE_CONTRACT):
    validate_contract(_c)


def _row_reduce(x, op, mode: str, scratch_ref):
    """The cross-lane stage of online softmax, budget-selected.

    x: (bq, bkv) -> (bq, 1).  Native spends the target's fused reduce;
    shuffle spends primitive 11; abstract folds to one vreg then pays
    log2(W) scratchpad round-trips (§VII.C).
    """
    if mode == "native":
        return op.reduce(x)
    if mode == "abstract+shuffle":
        return row_reduce_shuffle(x, op.combine)
    return scratch_tree_reduce(fold_rows(x, op.combine), scratch_ref,
                               op.combine)


class _Max:
    combine = staticmethod(jnp.maximum)
    reduce = staticmethod(
        lambda x: jnp.max(x, axis=-1, keepdims=True))


class _Sum:
    combine = staticmethod(jnp.add)
    reduce = staticmethod(
        lambda x: jnp.sum(x, axis=-1, keepdims=True))


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  red_ref, *, scale: float, causal: bool, kv_offset: int,
                  block_q: int, block_kv: int, n_kv: int, mode: str,
                  skip: bool, kv_len: int | None = None, q_axis: int = 2,
                  kv_axis: int = 3, epilogue=None, pos_ref=None,
                  skip_dead: bool = False, k_scale_ref=None,
                  v_scale_ref=None):
    """One online-softmax block program.

    ``kv_len`` is the true (unpadded) kv length: when the sequence was
    padded to a block multiple and the causal mask (which already covers
    the pad for valid rows) is off, the padded zero-keys must be masked
    explicitly or they receive softmax weight.  ``q_axis``/``kv_axis``
    name the grid dimensions carrying the q-block and kv-block indices
    (the fused ``flash_attention_matmul`` lowering reorders the grid so
    heads are sequential).  ``epilogue`` is the hook the fused lowerings
    plug into: called with the finalized ``acc / l`` block *in VMEM*
    instead of the plain ``o_ref`` store — the attention output then
    never exists in HBM (kernels/fused.py).  ``pos_ref`` is the
    decode-shaped mask source: a per-sequence (1, 1) int32 block holding
    the number of valid cache entries minus one — keys at columns
    ``> pos`` are masked, replacing the static causal triangle with the
    traced per-slot cache frontier (the serve tick's batch mixes
    positions, so the mask cannot be a static kv_offset).

    ``k_scale_ref``/``v_scale_ref`` are the int8-KV dequant hooks: when
    set, k/v blocks arrive as int8 values and the (bkv, 1) per-token
    scale blocks rescale them *in VMEM* — quantized cache pages never
    stage through HBM at f32 width (ISSUE 7).
    """
    qi, ki = pl.program_id(q_axis), pl.program_id(kv_axis)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)              # (bkv, d)
        if k_scale_ref is not None:
            k = k * k_scale_ref[0, 0]                    # (bkv, 1) bcast
        if v_scale_ref is not None:
            v = v * v_scale_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        if pos_ref is not None:
            cols = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(cols <= pos_ref[0, 0], s, NEG_INF)
        elif causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0) + kv_offset
            cols = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        elif kv_len is not None and kv_len < n_kv * block_kv:
            # non-causal with a padded kv axis: the causal mask is not
            # there to hide the zero-key pad, so mask it explicitly
            cols = ki * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(cols < kv_len, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        m_cur = jnp.maximum(m_prev, _row_reduce(s, _Max, mode, red_ref))
        corr = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        l_ref[...] = l_ref[...] * corr + _row_reduce(p, _Sum, mode, red_ref)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_cur

    if skip_dead and pos_ref is not None:
        # Paged decode: grid-level predication on the *traced* per-slot
        # frontier — table entries whose first logical column lies past
        # ``pos`` are dead (reserved-but-unreached or sentinel) and are
        # skipped entirely, so the kv walk only visits live pages.
        @pl.when(ki * block_kv <= pos_ref[0, 0])
        def _():
            body()
    elif causal and skip:
        # Native: grid-level predication — skip blocks entirely above the
        # diagonal (first kv column of the block vs last q row).
        first_col = ki * block_kv
        last_row = qi * block_q + block_q - 1 + kv_offset
        @pl.when(first_col <= last_row)
        def _():
            body()
    else:
        body()

    @pl.when(ki == n_kv - 1)
    def _store():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        out = acc_ref[...] / l
        if epilogue is None:
            o_ref[0, 0] = out.astype(o_ref.dtype)
        else:
            epilogue(out)


@functools.partial(jax.jit, static_argnames=(
    "causal", "mode", "interpret", "block_q", "block_kv", "kv_offset",
    "plan_dialect"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, kv_offset: int | None = None,
                    mode: str = "native", interpret: bool = True,
                    block_q: int | None = None,
                    block_kv: int | None = None,
                    plan_dialect: str | None = None) -> jax.Array:
    """q: [B,H,Sq,D]; k/v: [B,Hkv,Skv,D] (GQA via index-map head folding).

    ``plan_dialect`` (static) pins which dialect's tuned block table the
    trace binds; None degrades to the ambient policy's dialect."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    if kv_offset is None:
        kv_offset = skv - sq
    scale = 1.0 / (d ** 0.5)

    block_q, block_kv = resolve_blocks(mode, sq, skv, d, block_q, block_kv,
                                       plan_dialect)
    block_q = min(block_q, align_up(sq, 128))
    block_kv = min(block_kv, align_up(skv, 128))
    if mode != "native":
        # The abstract/shuffle cross-lane stages fold rows into 128-lane
        # vregs, so their kv block must be a lane multiple.
        block_kv = max(LANES, (block_kv // LANES) * LANES)
    q_p = _pad_seq(q, block_q)
    k_p = _pad_seq(k, block_kv)
    v_p = _pad_seq(v, block_kv)
    sqp, skvp = q_p.shape[2], k_p.shape[2]
    grid = (b, h, sqp // block_q, skvp // block_kv)
    skip = (mode == "native")

    params = None
    if mode == "native":
        params = CompilerParams(dimension_semantics=(
            "parallel", "parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, kv_offset=kv_offset,
            block_q=block_q, block_kv=block_kv, n_kv=grid[3], mode=mode,
            skip=skip, kv_len=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q_p.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m
            pltpu.VMEM((block_q, 1), jnp.float32),      # l
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
            # row-reduce staging: only the abstract budget round-trips
            pltpu.VMEM((block_q, LANES) if mode == "abstract"
                       else (8, LANES), jnp.float32),
        ],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_flash_attention_{mode.replace('+', '_')}",
    )(q_p, k_p, v_p)
    return out[:, :, :sq, :]


def _pad_seq(x: jax.Array, block: int) -> jax.Array:
    pad = (-x.shape[2]) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return x


def structural_cost(b: int, h: int, sq: int, skv: int, d: int,
                    causal: bool, mode: str,
                    block_q: int | None = None,
                    block_kv: int | None = None,
                    dtype=jnp.float32,
                    plan_dialect: str | None = None) -> dict:
    """Visited-block accounting + the §VII.C scratch-traffic delta.

    Grid-level predication (native block-skip) controls how many blocks
    run; the online-softmax cross-lane stages control what each visited
    block pays: two rowwise reductions (max, sum) per block, each either
    log2(W) scratch round-trips (abstract), log2(W) register shuffles
    (abstract+shuffle), or one native fused reduce.

    ``hbm_bytes`` is the logical stream traffic (read q/k/v once, write o
    once) and is mode-invariant — block revisits are VMEM pipelining the
    visited-block columns account for, and keeping the HBM term equal
    across modes keeps the §VII.C scratch ordering the auto-selection
    tiebreak.  The o write term is what the fused ``flash_attention →
    matmul`` lowering eliminates (kernels/fused.py)."""
    block_q, block_kv = resolve_blocks(mode, sq, skv, d, block_q, block_kv,
                                       plan_dialect)
    nq = -(-sq // block_q)
    nk = -(-skv // block_kv)
    total = nq * nk
    if causal and mode == "native":
        offset = skv - sq
        visited = sum(
            1 for qi in range(nq) for ki in range(nk)
            if ki * block_kv <= qi * block_q + block_q - 1 + offset)
    else:
        visited = total
    flops_per_block = 4 * block_q * block_kv * d
    reduces_per_block = 2                       # row-max + row-sum
    if mode == "abstract":
        round_trips = reduces_per_block * tree_stages(LANES)
        scratch_bytes = (b * h * visited * reduces_per_block *
                         scratch_tree_bytes(LANES, rows=block_q))
        shuffles = 0
    elif mode == "abstract+shuffle":
        round_trips = 0
        scratch_bytes = 0
        shuffles = reduces_per_block * tree_stages(LANES)
    else:                                       # native / library
        round_trips = 0
        scratch_bytes = 0
        shuffles = 0
    itemsize = jnp.dtype(dtype).itemsize
    return {
        "blocks_total": b * h * total,
        "blocks_visited": b * h * visited,
        "flops": b * h * visited * flops_per_block,
        "flops_dense": b * h * total * flops_per_block,
        "skip_fraction": 1.0 - visited / total,
        "hbm_bytes": b * h * d * (2 * sq + 2 * skv) * itemsize,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": shuffles,
    }


def _library_attention(q, k, v, *, causal: bool = True,
                       kv_offset=None, interpret=None,
                       block_q: int = 256, block_kv: int = 256,
                       plan_dialect: str | None = None):
    """XLA-native reference (the cuBLAS-analogue row of Table V)."""
    # library: XLA decides every staging parameter
    del kv_offset, interpret, block_q, block_kv, plan_dialect
    return _ref.attention(q, k, v, causal=causal)


# Registry: the compound hot-spot carries the full mode matrix.
for _mode, _contract in (("abstract", ABSTRACT_CONTRACT),
                         ("abstract+shuffle", SHUFFLE_CONTRACT),
                         ("native", NATIVE_CONTRACT)):
    REGISTRY.register("flash_attention", _mode,
                      functools.partial(flash_attention, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost, mode=_mode))
REGISTRY.register("flash_attention", IsaMode.LIBRARY, _library_attention,
                  cost=functools.partial(structural_cost, mode="library"))
