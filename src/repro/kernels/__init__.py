"""UISA Pallas kernels (paper Table V + framework hot-spots).

Each kernel ships abstract / abstract+shuffle / native variants under a
validated :class:`repro.core.KernelContract`, a jit'd dispatcher in
:mod:`repro.kernels.ops`, and a pure-jnp oracle in
:mod:`repro.kernels.ref`.
"""
from repro.kernels import ops  # noqa: F401
