"""Tiled GEMM under the UISA methodology (paper Table V, row 1).

Three Pallas variants of the *same* algorithm (single-pass tiled matmul
with f32 accumulation), differing only in which primitive budget they
spend — the TPU transposition of the paper's native/abstract CUDA/Metal
pairs:

- ``abstract``: universal primitives only.  Square tiles sized purely by
  the dialect scratchpad budget (Eq. 1 algebra; ``choose_block_bytes``),
  no matrix-tile alignment query, no pipeline annotations.  The MMA itself
  is the *opaque queryable* matrix op the abstract model permits (§V:
  "Optional: matrix MMA with queryable tiles").
- ``native``: full target feature set — block shapes aligned to the queried
  MXU tile (mxu_aligned_tiles), ``dimension_semantics`` annotations
  (parallel/parallel/arbitrary), larger rectangular tiles for reuse.
- ``library``: XLA's own dot (the cuBLAS analogue).

The paper found abstract ≥ native on both its platforms for GEMM (126.1% /
101.2%) because vendor-specific layout tricks encoded stale assumptions.
On TPU the structural prediction is the opposite — MXU alignment is load
bearing — which `structural_cost` quantifies and EXPERIMENTS.md discusses.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, UNIVERSAL_SET, align_up, choose_block_bytes,
                        register_op_space, tuned_block, validate_contract)
from repro.core.pipeline import CompilerParams

register_op_space("gemm", "gemm")

# --------------------------------------------------------------------------
# Contracts (validated at import: the abstract variant cannot regress into
# using native features without failing tests).
# --------------------------------------------------------------------------

ABSTRACT_CONTRACT = KernelContract(
    kernel="gemm", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.HIERARCHICAL_MEMORY, Primitive.WORKGROUP_BARRIER,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
        Primitive.REGISTER_OCCUPANCY,
    }))
NATIVE_CONTRACT = KernelContract(
    kernel="gemm", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"mxu_aligned_tiles", "dimension_semantics",
                               "multi_buffering"}))
validate_contract(ABSTRACT_CONTRACT)
validate_contract(NATIVE_CONTRACT)


def abstract_block_shape(dtype=jnp.float32) -> Tuple[int, int, int]:
    """Tile edge from the scratchpad budget alone (no MXU query).

    Working set of one step = 3 square tiles (A, B, acc).  Solve
    3·e²·bytes ≤ budget with double-buffered occupancy ≥ 2, then round
    *down* to the minimal legal TPU tile granule (8×128 layout => edge
    multiple of 128 on the minor dim; we keep square tiles, the abstract
    kernel's whole point is not to shape for the MXU).
    """
    itemsize = jnp.dtype(dtype).itemsize
    budget = choose_block_bytes(TARGET.S, n_buffers=2, min_occupancy=2)
    edge = int((budget / (3 * max(itemsize, 4))) ** 0.5)
    edge = max(128, (edge // 128) * 128)
    return (edge, edge, edge)


def native_block_shape(dtype=jnp.float32) -> Tuple[int, int, int]:
    """Rectangular tiles aligned to the queried matrix unit, shaped for
    A/B reuse: bm=512, bn=512, bk=2·tile for pipeline depth."""
    tile_m, tile_n, tile_k = TARGET.matrix_unit.tile
    return (4 * tile_m, 4 * tile_n, 2 * tile_k)


def block_shape_for(mode: str, m: int, n: int, k: int,
                    dtype=jnp.float32,
                    plan_dialect: str | None = None) -> Tuple[int, int, int]:
    """The (bm, bn, bk) tile for one call: autotuner winner first.

    Consulted by both the kernel and ``structural_cost`` (and by the
    fused ``rmsnorm_matmul`` lowering), so the modeled traffic and the
    executed tiling cannot drift apart.  ``plan_dialect`` names the table
    slice consulted (None = ambient policy's dialect).  The ``library``
    row is XLA's own tiling and is not tunable — callers keep their
    indicative constant.
    """
    tuned = tuned_block("gemm", mode, m, n, k, dialect=plan_dialect)
    if tuned is not None:
        return tuned
    if mode == "native":
        return native_block_shape(dtype)
    return abstract_block_shape(dtype)


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    """Shared body: the algorithm is identical across variants (the paper's
    'structurally equivalent implementations' requirement)."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("mode", "out_dtype", "interpret",
                                             "plan_dialect"))
def gemm(a: jax.Array, b: jax.Array, *, mode: str = "native",
         out_dtype=jnp.float32, interpret: bool = True,
         plan_dialect: str | None = None) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N], f32 accumulation, UISA-mode selectable."""
    assert a.shape[1] == b.shape[0], (a.shape, b.shape)
    m, k = a.shape
    _, n = b.shape
    if mode == "library":
        return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)

    if mode in ("abstract", "abstract+shuffle"):
        bm, bn, bk = block_shape_for(mode, m, n, k, a.dtype, plan_dialect)
        params = None
    elif mode == "native":
        bm, bn, bk = block_shape_for(mode, m, n, k, a.dtype, plan_dialect)
        params = CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    else:
        raise ValueError(f"unknown isa mode {mode!r}")

    # cap blocks at the (tile-rounded) problem size for small inputs
    bm, bn, bk = (min(bm, align_up(m, 128)), min(bn, align_up(n, 128)),
                  min(bk, align_up(k, 128)))
    a_p = _pad_to(a, bm, bk)
    b_p = _pad_to(b, bk, bn)
    mp, kp = a_p.shape
    _, np_ = b_p.shape
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_gemm_{mode.replace('+', '_')}",
    )(a_p, b_p)
    return out[:m, :n]


def structural_cost(m: int, n: int, k: int, mode: str,
                    dtype=jnp.float32,
                    plan_dialect: str | None = None) -> dict:
    """Modeled HBM traffic + FLOPs for the roofline discussion.

    A is re-read N/bn times, B re-read M/bm times, C written once — the
    classic tiled-GEMM traffic model.  This is the quantity the block
    shape actually controls, and the term the paper's Table V wall-clock
    differences trace back to.
    """
    itemsize = jnp.dtype(dtype).itemsize
    if mode == "library":
        bm = bn = bk = 512  # XLA's default-ish tiling; indicative only
    else:
        bm, bn, bk = block_shape_for(mode, m, n, k, dtype, plan_dialect)
    n_reads_a = max(1, -(-n // bn))
    n_reads_b = max(1, -(-m // bm))
    hbm_bytes = (m * k * itemsize * n_reads_a
                 + k * n * itemsize * n_reads_b
                 + m * n * jnp.dtype(jnp.float32).itemsize)
    mxu_tile = TARGET.matrix_unit.tile[0]
    pad = lambda d, b: -(-d // b) * b
    padded_flops = 2 * pad(m, bm) * pad(n, bn) * pad(k, bk)
    return {
        "flops": 2 * m * n * k,
        "padded_flops": padded_flops,
        "hbm_bytes": int(hbm_bytes),
        "block": (bm, bn, bk),
        "mxu_aligned": (bm % mxu_tile == 0 and bn % mxu_tile == 0
                        and bk % mxu_tile == 0),
        "vmem_working_set": (bm * bk + bk * bn) * itemsize + bm * bn * 4,
    }


# --------------------------------------------------------------------------
# Registry: contract-checked installation of every variant (Table V row 1).
# The cross-lane stage of GEMM *is* the MXU contraction, so there is no
# shuffle variant — requesting one takes the declared (recorded, warned)
# fallback instead of a silent rewrite.
# --------------------------------------------------------------------------

REGISTRY.register("gemm", IsaMode.ABSTRACT,
                  functools.partial(gemm, mode="abstract"),
                  contract=ABSTRACT_CONTRACT,
                  cost=functools.partial(structural_cost, mode="abstract"))
REGISTRY.register("gemm", IsaMode.NATIVE,
                  functools.partial(gemm, mode="native"),
                  contract=NATIVE_CONTRACT,
                  cost=functools.partial(structural_cost, mode="native"))
REGISTRY.register("gemm", IsaMode.LIBRARY,
                  functools.partial(gemm, mode="library"),
                  cost=functools.partial(structural_cost, mode="library"))
REGISTRY.declare_fallback(
    "gemm", IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
    reason="lane shuffle does not participate in the MXU contraction")
