"""Pure-jnp oracles for every kernel in this package.

Each Pallas kernel variant (abstract / abstract+shuffle / native) must be
allclose to the oracle here across the shape/dtype sweeps in
``tests/test_kernels_*.py``.  Oracles are written for clarity, not speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm(a: jax.Array, b: jax.Array,
         out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 accumulation."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def reduce_sum(x: jax.Array) -> jax.Array:
    """Scalar sum with f32 accumulation (paper's reduction benchmark)."""
    return jnp.sum(x.astype(jnp.float32))


def histogram(values: jax.Array, num_bins: int) -> jax.Array:
    """Counts of int32 values in [0, num_bins) (paper's histogram bench)."""
    clipped = jnp.clip(values.astype(jnp.int32), 0, num_bins - 1)
    return jnp.zeros((num_bins,), jnp.int32).at[clipped.reshape(-1)].add(1)


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)
            ).astype(x.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, scale: float | None = None) -> jax.Array:
    """Softmax attention oracle. q: [B,H,Sq,D], k/v: [B,Hkv,Skv,D].

    GQA handled by repeating kv heads.  f32 softmax.
    """
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != h:
        assert h % hkv == 0
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        skv = k.shape[2]
        qi = jnp.arange(sq)[:, None] + (skv - sq)  # align cache offsets
        ki = jnp.arange(skv)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
