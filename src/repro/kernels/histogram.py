"""Histogram — the paper's atomic-contention benchmark (Table V, row 3).

The GPU versions differ in *where* atomics land: the native CUDA kernel
privatizes one histogram per warp; the abstract kernel hammers a single
shared-scratchpad histogram.  The paper found them tied (100.4% / 102.1%)
because contention was insufficient for privatization to pay.

TPU transposition: the dialect has **no hardware atomics** (a true
divergence — core/primitives.py).  Both variants therefore lower
ATOMIC_RMW through the paper's own divergence resolution: *privatize +
deterministic reduce*:

- ``abstract``: one shared accumulator per grid step — a single one-hot
  comparison tensor summed over all block elements (vector-unit compare +
  add only; universal primitives).
- ``native``: per-sublane-group privatized counts produced by a one-hot
  **matmul** against a ones vector — routing the accumulation through the
  queried MXU tile (mxu_aligned_tiles) exactly like per-warp privatization
  routes it through warp-local shared memory — then a cross-private
  reduce.

Output accumulation across grid steps is sequential (workgroup-barrier
semantics), so results are deterministic, unlike GPU atomics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, TARGET,
                        validate_contract)

LANES = TARGET.W
_BLOCK_ROWS = 32          # 32×128 = 4096 values per grid step

ABSTRACT_CONTRACT = KernelContract(
    kernel="histogram", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MASKED_DIVERGENCE,
        Primitive.MANAGED_SCRATCHPAD, Primitive.WORKGROUP_BARRIER,
        Primitive.HIERARCHICAL_MEMORY, Primitive.IDENTITY_REGISTERS,
        Primitive.ASYNC_MEMORY, Primitive.ATOMIC_RMW,
    }))
NATIVE_CONTRACT = KernelContract(
    kernel="histogram", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"mxu_aligned_tiles", "dimension_semantics",
                               "multi_buffering"}))
validate_contract(ABSTRACT_CONTRACT)
validate_contract(NATIVE_CONTRACT)


def _histogram_kernel(x_ref, o_ref, *, mode: str, num_bins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = x_ref[...]                                    # (rows, LANES) int32
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, num_bins), 1)
    if mode == "abstract":
        # Single shared accumulator: every element compared against every
        # bin (masked-divergence compare), summed straight into one (1, B)
        # histogram — vector unit only.
        onehot = (vals.reshape(-1, 1) == bins).astype(jnp.float32)
        counts = jnp.sum(onehot, axis=0, keepdims=True)  # (1, B)
    elif mode == "native":
        # Privatized: one histogram per sublane-row of the block (the
        # 'wave-local' copy), produced by a ones-vector matmul through the
        # MXU, then reduced across privates.
        onehot = (vals.reshape(vals.shape[0], -1, 1) == bins[None]
                  ).astype(jnp.float32)                  # (rows, LANES, B)
        ones = jnp.ones((1, onehot.shape[1]), jnp.float32)
        private = jax.vmap(
            lambda oh: jnp.dot(ones, oh, preferred_element_type=jnp.float32)
        )(onehot)                                        # (rows, 1, B)
        counts = jnp.sum(private, axis=0)                # (1, B)
    else:
        raise ValueError(mode)
    o_ref[...] += counts.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_bins", "mode", "interpret"))
def histogram(values: jax.Array, num_bins: int = 256, *,
              mode: str = "native", interpret: bool = True) -> jax.Array:
    """Counts of int values in [0, num_bins); out-of-range values clipped."""
    if mode == "library":
        clipped = jnp.clip(values.astype(jnp.int32), 0, num_bins - 1)
        return jnp.zeros((num_bins,), jnp.int32).at[clipped.reshape(-1)].add(1)
    if mode == "abstract+shuffle":
        mode = "abstract"  # shuffle does not participate in histogram
    assert num_bins % LANES == 0 or num_bins <= LANES, num_bins

    flat = jnp.clip(values.astype(jnp.int32).reshape(-1), 0, num_bins - 1)
    n = flat.shape[0]
    per_block = _BLOCK_ROWS * LANES
    pad = (-n) % per_block
    if pad:
        # Padding sentinel = -1: matches no bin in the compare.
        flat = jnp.pad(flat, (0, pad), constant_values=-1)
    rows = flat.shape[0] // LANES
    x2d = flat.reshape(rows, LANES)
    grid = (rows // _BLOCK_ROWS,)
    bins_padded = max(num_bins, LANES)

    params = None
    if mode == "native":
        params = pltpu.CompilerParams(dimension_semantics=("arbitrary",))

    out = pl.pallas_call(
        functools.partial(_histogram_kernel, mode=mode, num_bins=bins_padded),
        grid=grid,
        in_specs=[pl.BlockSpec((_BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, bins_padded), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bins_padded), jnp.int32),
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_histogram_{mode}",
    )(x2d)
    return out[0, :num_bins]


def structural_cost(n: int, num_bins: int, mode: str) -> dict:
    """Contention / privatization structure for the benchmark report."""
    per_block = _BLOCK_ROWS * LANES
    blocks = -(-n // per_block)
    private_copies = _BLOCK_ROWS if mode == "native" else 1
    return {
        "hbm_bytes": n * 4 + num_bins * 4,
        "private_histograms_per_block": private_copies,
        "compare_ops": n * num_bins,            # identical across variants
        "mxu_routed": mode == "native",
        "atomic_free": True,                    # deterministic by design
        "blocks": blocks,
    }
