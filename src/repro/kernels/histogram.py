"""Histogram — the paper's atomic-contention benchmark (Table V, row 3).

The GPU versions differ in *where* atomics land: the native CUDA kernel
privatizes one histogram per warp; the abstract kernel hammers a single
shared-scratchpad histogram.  The paper found them tied (100.4% / 102.1%)
because contention was insufficient for privatization to pay.

TPU transposition: the dialect has **no hardware atomics** (a true
divergence — core/primitives.py).  All variants therefore lower
ATOMIC_RMW through the paper's own divergence resolution: *privatize +
deterministic reduce* — and the variants differ in how the per-element
one-hot indicators are merged, i.e. in the cross-lane stage:

- ``abstract``: one shared accumulator per grid step, merged through
  *scratchpad round-trips* — the (block, bins) indicator partials
  tree-reduce across the block axis via ``scratch_tree_reduce`` (log2 of
  the block's rows store/reload stages, program order as the barrier).
- ``abstract+shuffle``: per-sublane-row privatized counts whose lane
  merge is the in-register rotate tree (``lane_tree_reduce`` along the
  value-lane axis) — zero scratch traffic (§VII.C generalized).
- ``native``: per-sublane-group privatized counts produced by a one-hot
  **matmul** against a ones vector — routing the accumulation through the
  queried MXU tile exactly like per-warp privatization routes it through
  warp-local shared memory — then a cross-private reduce.

Output accumulation across grid steps is sequential (workgroup-barrier
semantics), so results are deterministic, unlike GPU atomics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, lane_tree_reduce, register_op_space,
                        scratch_tree_bytes, scratch_tree_reduce,
                        tree_stages, tuned_plan, validate_contract)

LANES = TARGET.W
_MAX_BLOCK_ROWS = 32      # 32×128 = 4096 values per grid step
register_op_space("histogram", "rowwise", max_block_rows=_MAX_BLOCK_ROWS,
                  pow2_blocks=True)

_ATOMIC_LOWERING = frozenset({
    Primitive.LOCKSTEP_GROUP, Primitive.MASKED_DIVERGENCE,
    Primitive.MANAGED_SCRATCHPAD, Primitive.WORKGROUP_BARRIER,
    Primitive.HIERARCHICAL_MEMORY, Primitive.IDENTITY_REGISTERS,
    Primitive.ASYNC_MEMORY, Primitive.ATOMIC_RMW,
})

ABSTRACT_CONTRACT = KernelContract(
    kernel="histogram", mode=IsaMode.ABSTRACT,
    primitives=_ATOMIC_LOWERING)
SHUFFLE_CONTRACT = KernelContract(
    kernel="histogram", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_ATOMIC_LOWERING | {Primitive.LANE_SHUFFLE})
NATIVE_CONTRACT = KernelContract(
    kernel="histogram", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"mxu_aligned_tiles", "dimension_semantics",
                               "multi_buffering"}))
for _c in (ABSTRACT_CONTRACT, SHUFFLE_CONTRACT, NATIVE_CONTRACT):
    validate_contract(_c)


def _plan(rows: int, mode: str, plan_dialect: str | None = None):
    # pow2 blocks: the abstract variant tree-reduces across the block's
    # flattened element axis, which must be a power of two.
    return tuned_plan("histogram", rows, LANES * 4, mode=mode,
                      dialect=plan_dialect,
                      max_block_rows=_MAX_BLOCK_ROWS,
                      pow2_blocks=True, semantics=("arbitrary",))


def _histogram_kernel(x_ref, o_ref, scratch_ref, *, mode: str,
                      num_bins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = x_ref[...]                                    # (rows, LANES) int32
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, num_bins), 1)
    if mode == "abstract":
        # Single shared accumulator: every element's one-hot indicator
        # (masked-divergence compare) merges through the scratchpad tree —
        # log2(rows·LANES) barrier-ordered round-trips per block.
        onehot = (vals.reshape(-1, 1) == bins).astype(jnp.float32)
        counts = scratch_tree_reduce(onehot, scratch_ref, axis=0)  # (1, B)
    elif mode == "abstract+shuffle":
        # Privatized per sublane-row; the per-row lane merge is the rotate
        # tree (primitive 11).  Layout keeps the value-lane axis MINOR
        # (rows, B, LANES) so the rotate is a true intra-vreg lane
        # rotation, not a second-minor relayout: zero scratch.
        onehot = (vals[:, None, :] == bins.reshape(-1)[None, :, None]
                  ).astype(jnp.float32)                  # (rows, B, LANES)
        private = lane_tree_reduce(onehot, axis=-1)[..., 0]  # (rows, B)
        counts = jnp.sum(private, axis=0, keepdims=True)     # register fold
    elif mode == "native":
        # Privatized: one histogram per sublane-row of the block (the
        # 'wave-local' copy), produced by a ones-vector matmul through the
        # MXU, then reduced across privates.
        onehot = (vals.reshape(vals.shape[0], -1, 1) == bins[None]
                  ).astype(jnp.float32)                  # (rows, LANES, B)
        ones = jnp.ones((1, onehot.shape[1]), jnp.float32)
        private = jax.vmap(
            lambda oh: jnp.dot(ones, oh, preferred_element_type=jnp.float32)
        )(onehot)                                        # (rows, 1, B)
        counts = jnp.sum(private, axis=0)                # (1, B)
    else:
        raise ValueError(mode)
    o_ref[...] += counts.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("num_bins", "mode", "interpret",
                                             "plan_dialect"))
def histogram(values: jax.Array, num_bins: int = 256, *,
              mode: str = "native", interpret: bool = True,
              plan_dialect: str | None = None) -> jax.Array:
    """Counts of int values in [0, num_bins); out-of-range values clipped."""
    if mode == "library":
        clipped = jnp.clip(values.astype(jnp.int32), 0, num_bins - 1)
        return jnp.zeros((num_bins,), jnp.int32).at[clipped.reshape(-1)].add(1)
    assert num_bins % LANES == 0 or num_bins <= LANES, num_bins

    flat = jnp.clip(values.astype(jnp.int32).reshape(-1), 0, num_bins - 1)
    pad = (-flat.shape[0]) % LANES
    if pad:
        # Padding sentinel = -1: matches no bin in the compare.
        flat = jnp.pad(flat, (0, pad), constant_values=-1)
    rows = flat.shape[0] // LANES
    plan = _plan(rows, mode, plan_dialect)
    block = plan.block_rows
    pad_r = plan.padded_rows - rows
    x2d = flat.reshape(rows, LANES)
    if pad_r:
        x2d = jnp.pad(x2d, ((0, pad_r), (0, 0)), constant_values=-1)
    bins_padded = max(num_bins, LANES)

    out = pl.pallas_call(
        functools.partial(_histogram_kernel, mode=mode,
                          num_bins=bins_padded),
        grid=plan.grid,
        in_specs=[pl.BlockSpec((block, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, bins_padded), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, bins_padded), jnp.int32),
        # only the abstract tree stages through scratch; other modes get
        # a minimal tile so the VMEM budget stays with the pipeline
        scratch_shapes=[pltpu.VMEM(
            (block * LANES, bins_padded) if mode == "abstract"
            else (8, LANES), jnp.float32)],
        compiler_params=plan.compiler_params,
        interpret=interpret,
        name=f"uisa_histogram_{mode.replace('+', '_')}",
    )(x2d)
    return out[0, :num_bins]


def structural_cost(n: int, num_bins: int, mode: str,
                    plan_dialect: str | None = None) -> dict:
    """Contention / privatization structure + the scratch-traffic delta."""
    rows = -(-n // LANES)
    plan = _plan(rows, mode if mode != "library" else "native",
                 plan_dialect)
    blocks = plan.grid[0]
    block_elems = plan.block_rows * LANES
    private_copies = plan.block_rows if mode in ("native",
                                                 "abstract+shuffle") else 1
    if mode == "abstract":
        round_trips = tree_stages(block_elems)
        scratch_bytes = blocks * scratch_tree_bytes(
            block_elems, rows=num_bins)  # tree runs across the elem axis
    else:
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": n * 4 + num_bins * 4,
        "private_histograms_per_block": private_copies,
        "compare_ops": n * num_bins,            # identical across variants
        "mxu_routed": mode == "native",
        "atomic_free": True,                    # deterministic by design
        "blocks": blocks,
        "block_rows": plan.block_rows,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
    }


# Registry: all variants lower ATOMIC_RMW through privatize+reduce, which
# the contracts encode (scratchpad+barrier companions) — validated on every
# dialect the registry is asked about, including the no-atomics TPU.
for _mode, _contract in (("abstract", ABSTRACT_CONTRACT),
                         ("abstract+shuffle", SHUFFLE_CONTRACT),
                         ("native", NATIVE_CONTRACT),
                         ("library", None)):
    REGISTRY.register("histogram", _mode,
                      functools.partial(histogram, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost, mode=_mode))
