"""Parallel reduction — the paper's *critical* benchmark (§VII.C).

The paper's finding: replacing intra-wave shuffle with barrier-mediated
scratchpad round-trips cost 37.5% on NVIDIA (62.5% of native) but only
2.2% on Apple — therefore shuffle must be the 11th mandatory primitive.

TPU transposition: the "wave" is the 128-lane vreg minor dimension.  The
final cross-lane reduction can be done two ways:

- ``abstract`` (10 primitives, no shuffle): log2(W)=7 *scratchpad
  round-trips* — each halving stage stores partials to a VMEM scratch
  buffer and reloads them, with the workgroup-barrier ordering the stages
  (on TPU: program order plays the barrier role; the *memory traffic* is
  what survives the transposition, and it is exactly what made the NVIDIA
  native kernel faster).
- ``abstract+shuffle``: a lane-rotate tree — ``x += roll(x, s)`` for
  s = 64..1 — all in registers, zero scratch traffic (pltpu.roll is the
  TPU realization of __shfl_down_sync / simd_shuffle_down).
- ``native``: lets the target pick (jnp.sum lowers to the VPU's native
  cross-lane reduce) + pipeline annotations.

`structural_cost` exposes the round-trip counts so benchmarks can show the
mechanism, not just the outcome.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, TARGET,
                        validate_contract)

LANES = TARGET.W          # 128 — queried, never assumed (Table III)
SUBLANES = 8
_BLOCK_ROWS = 512         # rows of 128 lanes per grid step (256 KB f32)

ABSTRACT_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
SHUFFLE_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=ABSTRACT_CONTRACT.primitives | {Primitive.LANE_SHUFFLE})
NATIVE_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"dimension_semantics", "multi_buffering"}))
for _c in (ABSTRACT_CONTRACT, SHUFFLE_CONTRACT, NATIVE_CONTRACT):
    validate_contract(_c)


def _final_lane_reduce_scratchpad(row, scratch_ref):
    """Abstract: tree-reduce a (1, LANES) partial through scratchpad
    round-trips — the 'five barrier-synchronized shared memory round
    trips' of the paper, which are log2(128)=7 here."""
    scratch_ref[0, :] = row[0, :]
    width = LANES // 2
    while width >= 1:
        # barrier (program order) | load two halves | store partial
        lo = scratch_ref[0, :width]
        hi = scratch_ref[0, width:2 * width]
        scratch_ref[0, :width] = lo + hi
        width //= 2
    return scratch_ref[0, 0]


def _final_lane_reduce_shuffle(row):
    """Abstract+shuffle: in-register rotate tree (primitive 11)."""
    x = row  # (1, LANES)
    shift = LANES // 2
    while shift >= 1:
        x = x + pltpu.roll(x, shift, 1)
        shift //= 2
    return x[0, 0]


def _reduction_kernel(x_ref, o_ref, scratch_ref, *, mode: str, n_rows: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0, 0] = jnp.float32(0.0)

    block = x_ref[...].astype(jnp.float32)           # (rows, LANES)
    if mode == "native":
        # Target-native cross-lane reduce: single fused op.
        part = jnp.sum(block)
    else:
        # Stage 1 (both abstract variants): sublane tree within scratchpad
        # tiles — sum rows down to one (1, LANES) partial.  This mirrors
        # the shared-memory block tree both the paper's kernels share.
        row = jnp.sum(block, axis=0, keepdims=True)  # (1, LANES)
        if mode == "abstract":
            part = _final_lane_reduce_scratchpad(row, scratch_ref)
        elif mode == "abstract+shuffle":
            part = _final_lane_reduce_shuffle(row)
        else:
            raise ValueError(mode)
    o_ref[0, 0] += part


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def reduce_sum(x: jax.Array, *, mode: str = "native",
               interpret: bool = True) -> jax.Array:
    """Sum all elements of ``x`` (any shape) with f32 accumulation."""
    if mode == "library":
        return jnp.sum(x.astype(jnp.float32))
    flat = x.reshape(-1)
    n = flat.shape[0]
    per_block = _BLOCK_ROWS * LANES
    pad = (-n) % per_block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.shape[0] // LANES
    x2d = flat.reshape(rows, LANES)
    grid = (rows // _BLOCK_ROWS,)

    params = None
    if mode == "native":
        params = pltpu.CompilerParams(dimension_semantics=("arbitrary",))

    out = pl.pallas_call(
        functools.partial(_reduction_kernel, mode=mode, n_rows=_BLOCK_ROWS),
        grid=grid,
        in_specs=[pl.BlockSpec((_BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_reduction_{mode.replace('+', '_')}",
    )(x2d)
    return out[0, 0]


def structural_cost(n: int, mode: str, dtype=jnp.float32) -> dict:
    """Bytes moved + scratch round-trips — the §VII.C mechanism, in numbers.

    The HBM traffic is identical across variants (bandwidth-bound kernel);
    what differs is the per-block scratch traffic of the final cross-lane
    stage.  On a latency-intolerant machine that difference is the paper's
    37.5%; on a latency-tolerant one it is the paper's 2.2%.
    """
    itemsize = jnp.dtype(dtype).itemsize
    per_block = _BLOCK_ROWS * LANES
    blocks = -(-n // per_block)
    if mode in ("library", "native"):
        round_trips = 0
        scratch_bytes = 0
    elif mode == "abstract+shuffle":
        round_trips = 0                      # in-register rotates
        scratch_bytes = 0
    else:  # abstract
        round_trips = int(math.log2(LANES))  # 7 halving stages
        # stage k reads 2·(LANES/2^k) + writes LANES/2^k f32 values
        scratch_bytes = blocks * sum(
            3 * (LANES >> k) * 4 for k in range(1, round_trips + 1))
    return {
        "hbm_bytes": n * itemsize,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": int(math.log2(LANES))
        if mode == "abstract+shuffle" else 0,
        "blocks": blocks,
    }
