"""Parallel reduction — the paper's *critical* benchmark (§VII.C).

The paper's finding: replacing intra-wave shuffle with barrier-mediated
scratchpad round-trips cost 37.5% on NVIDIA (62.5% of native) but only
2.2% on Apple — therefore shuffle must be the 11th mandatory primitive.

TPU transposition: the "wave" is the 128-lane vreg minor dimension.  The
final cross-lane reduction can be done two ways, both via the shared
primitive layer in :mod:`repro.core.shuffle`:

- ``abstract`` (10 primitives, no shuffle): log2(W)=7 *scratchpad
  round-trips* (``scratch_tree_reduce``) — each halving stage stores
  partials to a VMEM scratch buffer and reloads them, with the
  workgroup-barrier ordering the stages (on TPU: program order plays the
  barrier role; the *memory traffic* is what survives the transposition,
  and it is exactly what made the NVIDIA native kernel faster).
- ``abstract+shuffle``: the lane-rotate tree (``lane_tree_reduce``) — all
  in registers, zero scratch traffic.
- ``native``: lets the target pick (jnp.sum lowers to the VPU's native
  cross-lane reduce) + pipeline annotations.

Block staging comes from the shared Eq. 1 plan (``plan_row_pipeline``),
and `structural_cost` exposes the round-trip counts so benchmarks can
show the mechanism, not just the outcome.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, lane_tree_reduce, pad_rows,
                        register_op_space, scratch_tree_bytes,
                        scratch_tree_reduce, tree_stages, tuned_plan,
                        validate_contract)

LANES = TARGET.W          # 128 — queried, never assumed (Table III)
_MAX_BLOCK_ROWS = 512     # latency/tail cap: 512x128 f32 = 256 KB per step
register_op_space("reduction", "rowwise", max_block_rows=_MAX_BLOCK_ROWS)

ABSTRACT_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
SHUFFLE_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=ABSTRACT_CONTRACT.primitives | {Primitive.LANE_SHUFFLE})
NATIVE_CONTRACT = KernelContract(
    kernel="reduction", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"dimension_semantics", "multi_buffering"}))
for _c in (ABSTRACT_CONTRACT, SHUFFLE_CONTRACT, NATIVE_CONTRACT):
    validate_contract(_c)


def _plan(rows: int, mode: str, plan_dialect: str | None = None):
    return tuned_plan("reduction", rows, LANES * 4, mode=mode,
                      dialect=plan_dialect,
                      max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("arbitrary",))


def _reduction_kernel(x_ref, o_ref, scratch_ref, *, mode: str):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[0, 0] = jnp.float32(0.0)

    block = x_ref[...].astype(jnp.float32)           # (rows, LANES)
    if mode == "native":
        # Target-native cross-lane reduce: single fused op.
        part = jnp.sum(block)
    else:
        # Stage 1 (both abstract variants): sublane tree within scratchpad
        # tiles — sum rows down to one (1, LANES) partial.  This mirrors
        # the shared-memory block tree both the paper's kernels share.
        row = jnp.sum(block, axis=0, keepdims=True)  # (1, LANES)
        if mode == "abstract":
            part = scratch_tree_reduce(row, scratch_ref)[0, 0]
        elif mode == "abstract+shuffle":
            part = lane_tree_reduce(row)[0, 0]
        else:
            raise ValueError(mode)
    o_ref[0, 0] += part


@functools.partial(jax.jit,
                   static_argnames=("mode", "interpret", "plan_dialect"))
def reduce_sum(x: jax.Array, *, mode: str = "native",
               interpret: bool = True,
               plan_dialect: str | None = None) -> jax.Array:
    """Sum all elements of ``x`` (any shape) with f32 accumulation.

    ``plan_dialect`` names the dialect whose tuned staging plan the call
    binds (a *static* jit argument, so mixed-dialect processes retrace per
    dialect); None falls back to the ambient policy's dialect."""
    if mode == "library":
        return jnp.sum(x.astype(jnp.float32))
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    rows = flat.shape[0] // LANES
    plan = _plan(rows, mode, plan_dialect)
    x2d = pad_rows(flat.reshape(rows, LANES), plan)

    out = pl.pallas_call(
        functools.partial(_reduction_kernel, mode=mode),
        grid=plan.grid,
        in_specs=[pl.BlockSpec((plan.block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32)],
        compiler_params=plan.compiler_params,
        interpret=interpret,
        name=f"uisa_reduction_{mode.replace('+', '_')}",
    )(x2d)
    return out[0, 0]


def structural_cost(n: int, mode: str, dtype=jnp.float32,
                    plan_dialect: str | None = None) -> dict:
    """Bytes moved + scratch round-trips — the §VII.C mechanism, in numbers.

    The HBM traffic is identical across variants (bandwidth-bound kernel);
    what differs is the per-block scratch traffic of the final cross-lane
    stage.  On a latency-intolerant machine that difference is the paper's
    37.5%; on a latency-tolerant one it is the paper's 2.2%.
    """
    itemsize = jnp.dtype(dtype).itemsize
    rows = -(-n // LANES)
    plan = _plan(rows, mode if mode != "library" else "native",
                 plan_dialect)
    blocks = plan.grid[0]
    if mode == "abstract":
        round_trips = tree_stages(LANES)     # 7 halving stages
        scratch_bytes = blocks * scratch_tree_bytes(LANES)
    else:  # library / native / abstract+shuffle: no scratch round-trips
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": n * itemsize,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "blocks": blocks,
        "block_rows": plan.block_rows,
        "pipeline_occupancy": plan.occupancy,
    }


# Registry: the §VII.C kernel carries the full Table V mode matrix.
for _mode, _contract in (("abstract", ABSTRACT_CONTRACT),
                         ("abstract+shuffle", SHUFFLE_CONTRACT),
                         ("native", NATIVE_CONTRACT),
                         ("library", None)):
    REGISTRY.register("reduction", _mode,
                      functools.partial(reduce_sum, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost, mode=_mode))
