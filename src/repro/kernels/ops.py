"""Public kernel API — a thin compatibility shim over the lowering registry.

Importing this module installs every kernel variant in
:data:`repro.core.registry.REGISTRY`; the wrappers here only derive the
call's shape signature and hand dispatch to
:meth:`~repro.core.registry.LoweringRegistry.select`.  Callers pick a
lowering one of three ways, in precedence order:

1. ``mode=`` — kernel-layer compatibility (tests/benchmarks of a specific
   variant).  Equivalent to an :class:`ExecutionPolicy` with that mode.
2. ``policy=`` — an explicit :class:`ExecutionPolicy` threaded from the
   layers above (models/train/serve resolve theirs once from config).
3. ambient — a :func:`repro.core.registry.use_policy` context, else
   :data:`DEFAULT_POLICY` (the target-native variant, the seed default).

``interpret`` defaults to True off-TPU so the same code path is exercised
(and allclose-tested) on CPU; on a real TPU backend the Mosaic kernels
compile natively.  Unsupported mode requests follow *declared* registry
fallbacks (warned + recorded) — see ``gemm``'s abstract+shuffle row —
never silent rewrites.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import IsaMode
from repro.core.registry import (DEFAULT_POLICY, ExecutionPolicy, REGISTRY,
                                 resolve_policy, use_policy)
# importing the kernel modules installs their registry variants
from repro.kernels import attention as _attention  # noqa: F401
from repro.kernels import fused as _fused
from repro.kernels import collective as _collective
from repro.kernels import gemm as _gemm
from repro.kernels import histogram as _histogram
from repro.kernels import reduction as _reduction
from repro.kernels import rmsnorm as _rmsnorm
from repro.kernels import ssd as _ssd
from repro.kernels import ref as ref  # noqa: F401 (re-export for tests)

# Kernel-layer mode strings (the registry's POLICY_MODES additionally
# accepts "auto"); kept for API compatibility with the seed switchboard.
MODES = tuple(m.value for m in IsaMode)

#: representative shapes per op for cost-ranked selection probes — shared
#: by tests/test_registry.py and scripts/validate_contracts.py so the two
#: cannot drift when an op is added (register the op, add its row here).
PROBE_SHAPES = {
    "gemm": dict(m=1024, n=1024, k=1024),
    "reduction": dict(n=1 << 20),
    "rmsnorm": dict(rows=1024, d=1024),
    "histogram": dict(n=1 << 18, num_bins=256),
    "flash_attention": dict(b=1, h=4, sq=1024, skv=1024, d=64, causal=True),
    "rmsnorm_matmul": dict(rows=1024, d=1024, n=1024),
    "add_rmsnorm": dict(rows=1024, d=1024),
    "flash_attention_matmul": dict(b=1, h=4, sq=1024, skv=1024, d=64,
                                   n=256, causal=True),
    "rmsnorm_swiglu": dict(rows=1024, d=1024, f=1024),
    # quantized twins (ISSUE 7): same geometry as their f32 bases — the
    # cost delta under probe is purely the int8 stream width
    "rmsnorm_matmul_q8": dict(rows=1024, d=1024, n=1024),
    "flash_attention_matmul_q8": dict(b=1, h=4, sq=1024, skv=1024, d=64,
                                      n=256, causal=True),
    "rmsnorm_swiglu_q8": dict(rows=1024, d=1024, f=1024),
    # the fused chunked SSD scan (ISSUE 8): mamba2-default head geometry
    "ssd_scan": dict(b=1, seq=1024, h=8, p=64, g=1, n=128),
    # the batched decode recurrence (ISSUE 9): one serve-batch tick
    "ssd_decode": dict(b=8, h=8, p=64, g=1, n=128),
    # tensor-parallel twins (ISSUE 10): same geometry as their bases —
    # the cost delta under probe is the sharded weight stream vs the
    # collective term (zero at the ambient tp=1 these probes run at)
    "gemm_tp": dict(m=1024, n=1024, k=1024),
    "rmsnorm_matmul_tp": dict(rows=1024, d=1024, n=1024),
    "rmsnorm_swiglu_tp": dict(rows=1024, d=1024, f=1024),
    "flash_attention_matmul_tp": dict(b=1, h=4, sq=1024, skv=1024, d=64,
                                      n=256, causal=True),
}


@functools.lru_cache(maxsize=1)
def _backend_probe() -> str:
    # one backend query per process: the answer cannot change after the
    # first device op, and per-call dispatch sits on every kernel hot path
    return jax.default_backend()


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend
    (memoized — the probe used to re-query JAX on every kernel call)."""
    return _backend_probe() != "tpu"


def _resolve(mode, policy, interpret):
    pol = resolve_policy(mode, policy, DEFAULT_POLICY)
    if interpret is None:
        interpret = pol.interpret
    if interpret is None:
        interpret = default_interpret()
    return pol, interpret


def _dispatch(low, pol, *args, **kwargs):
    """Run a selected lowering with the policy's dialect bound statically.

    ``plan_dialect`` is threaded into every kernel entry point as a
    *static jit argument* (resolved once here from the policy every model
    layer threads), so the tuned-table slice a kernel consults is part of
    its jit cache key: a process mixing dialects at identical shapes
    retraces per dialect and runs each dialect's own staging plans,
    instead of reusing the first-traced plan (the PR 4 jit-cache-key gap,
    closed by ISSUE 5).  The policy stays ambient for the dynamic extent
    as before — nested registry dispatches still resolve against it."""
    with use_policy(pol):
        return low.impl(*args, plan_dialect=pol.dialect, **kwargs)


def run_op(op: str, *args, mode=None,
           policy: Optional[ExecutionPolicy] = None,
           interpret: Optional[bool] = None,
           shape: Optional[dict] = None, **kwargs):
    """Generic dispatch by registered op name (no per-op shim needed).

    ``shape`` feeds auto-selection's cost ranking (defaults to the op's
    :data:`PROBE_SHAPES` row); remaining args/kwargs go to the selected
    impl.  This is how the conformance suite and benchmarks run ops
    without a dedicated wrapper — in particular the ``_tp`` twins, whose
    selected impl *is* the base kernel (GSPMD owns physical sharding;
    the twin rows change the cost model, not the program)."""
    pol, interpret = _resolve(mode, policy, interpret)
    low = REGISTRY.select(op, pol, shape=shape or PROBE_SHAPES.get(op))
    return _dispatch(low, pol, *args, interpret=interpret, **kwargs)


def matmul(a: jax.Array, b: jax.Array, *, mode=None,
           policy: Optional[ExecutionPolicy] = None,
           out_dtype=jnp.float32, interpret: Optional[bool] = None):
    pol, interpret = _resolve(mode, policy, interpret)
    low = REGISTRY.select("gemm", pol, shape=dict(
        m=a.shape[0], n=b.shape[1], k=a.shape[1], dtype=a.dtype))
    return _dispatch(low, pol, a, b, out_dtype=out_dtype,
                     interpret=interpret)


def reduce_sum(x: jax.Array, *, mode=None,
               policy: Optional[ExecutionPolicy] = None,
               interpret: Optional[bool] = None):
    pol, interpret = _resolve(mode, policy, interpret)
    low = REGISTRY.select("reduction", pol, shape=dict(n=x.size))
    return _dispatch(low, pol, x, interpret=interpret)


def histogram(values: jax.Array, num_bins: int = 256, *, mode=None,
              policy: Optional[ExecutionPolicy] = None,
              interpret: Optional[bool] = None):
    pol, interpret = _resolve(mode, policy, interpret)
    low = REGISTRY.select("histogram", pol,
                          shape=dict(n=values.size, num_bins=num_bins))
    return _dispatch(low, pol, values, num_bins, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    kv_offset: Optional[int] = None, mode=None,
                    policy: Optional[ExecutionPolicy] = None,
                    interpret: Optional[bool] = None,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None):
    """None block sizes defer to the autotuner table (then the static
    defaults) via ``attention.resolve_blocks``; explicit values pin."""
    pol, interpret = _resolve(mode, policy, interpret)
    low = REGISTRY.select("flash_attention", pol, shape=dict(
        b=q.shape[0], h=q.shape[1], sq=q.shape[2], skv=k.shape[2],
        d=q.shape[3], causal=causal, block_q=block_q, block_kv=block_kv))
    return _dispatch(low, pol, q, k, v, causal=causal, kv_offset=kv_offset,
                     interpret=interpret, block_q=block_q,
                     block_kv=block_kv)


def rmsnorm(x, weight, *, eps: float = 1e-6, mode=None,
            policy: Optional[ExecutionPolicy] = None,
            interpret: Optional[bool] = None):
    pol, interpret = _resolve(mode, policy, interpret)
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    low = REGISTRY.select("rmsnorm", pol,
                          shape=dict(rows=rows, d=x.shape[-1]))
    return _dispatch(low, pol, x, weight, eps=eps, interpret=interpret)


def fused_rmsnorm_matmul(x: jax.Array, weight: jax.Array,
                         w_proj: jax.Array, *, eps: float = 1e-6,
                         mode=None,
                         policy: Optional[ExecutionPolicy] = None,
                         interpret: Optional[bool] = None,
                         w_scale: Optional[jax.Array] = None):
    """``rmsnorm(x, weight) @ w_proj`` without the HBM round trip.

    Dispatches the fused multi-op lowering; an illegal mode request
    follows the *declared* fallbacks (shuffle -> scratch tree, native ->
    the unfused XLA pair), warned and recorded — never silent.

    ``w_scale`` marks ``w_proj`` as int8 with per-channel scales.  The
    precision policy picks the op; this shim keeps operands coherent
    either way: a quantized selection forwards the scale (or quantizes f32
    weights on the fly), an f32 selection dequantizes int8 operands."""
    pol, interpret = _resolve(mode, policy, interpret)
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    low = REGISTRY.select("rmsnorm_matmul", pol, shape=dict(
        rows=rows, d=x.shape[-1], n=w_proj.shape[1]))
    if low.op.endswith("_q8"):
        return _dispatch(low, pol, x, weight, w_proj, eps=eps,
                         interpret=interpret, w_scale=w_scale)
    if w_scale is not None:
        w_proj = _fused.dequantize_weight(w_proj, w_scale, x.dtype)
    return _dispatch(low, pol, x, weight, w_proj, eps=eps,
                     interpret=interpret)


def fused_add_rmsnorm(x: jax.Array, residual: jax.Array,
                      weight: jax.Array, *, eps: float = 1e-6, mode=None,
                      policy: Optional[ExecutionPolicy] = None,
                      interpret: Optional[bool] = None):
    """``(rmsnorm(x + residual), x + residual)`` with the residual add
    fused into the norm's load stage (same fallback discipline as
    :func:`fused_rmsnorm_matmul`)."""
    pol, interpret = _resolve(mode, policy, interpret)
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    low = REGISTRY.select("add_rmsnorm", pol,
                          shape=dict(rows=rows, d=x.shape[-1]))
    return _dispatch(low, pol, x, residual, weight, eps=eps,
                     interpret=interpret)


def fused_flash_attention_matmul(q: jax.Array, k: jax.Array, v: jax.Array,
                                 w_out: jax.Array, *, causal: bool = True,
                                 kv_offset: Optional[int] = None, mode=None,
                                 policy: Optional[ExecutionPolicy] = None,
                                 interpret: Optional[bool] = None,
                                 block_q: Optional[int] = None,
                                 block_kv: Optional[int] = None,
                                 pos: Optional[jax.Array] = None,
                                 block_tables: Optional[jax.Array] = None,
                                 w_scale: Optional[jax.Array] = None,
                                 k_scale: Optional[jax.Array] = None,
                                 v_scale: Optional[jax.Array] = None):
    """``flash_attention(q, k, v)`` -> ``wo`` without the HBM round trip.

    The `[B,S,H,D]` online-softmax output is consumed from VMEM by the
    per-head wo slices (kernels/fused.py); declared fallbacks: shuffle ->
    scratch tree, native -> the unfused XLA pair.  ``pos`` ([B] int32
    cache frontiers) selects the decode shape: keys past each sequence's
    frontier are masked instead of the static causal triangle.

    ``block_tables`` ([B, max_pages] int32, with ``pos``) selects the
    *paged* decode shape: k/v are page pools ``[P, Hkv, page_size, D]``
    and the kernel's sequential kv walk gathers live pages through the
    table (kernels/fused.py).  Selection then ranks costs at the
    fully-occupied page count — the static worst case; the true
    occupancy is a traced quantity only the running engine knows."""
    pol, interpret = _resolve(mode, policy, interpret)
    if block_tables is not None:
        page_size = k.shape[2]
        maxp = block_tables.shape[1]
        shape = dict(
            b=q.shape[0], h=q.shape[1], sq=q.shape[2],
            skv=maxp * page_size, d=q.shape[3], n=w_out.shape[1],
            causal=False, block_q=block_q, block_kv=page_size,
            page_size=page_size, pages_occupied=q.shape[0] * maxp)
    else:
        shape = dict(
            b=q.shape[0], h=q.shape[1], sq=q.shape[2], skv=k.shape[2],
            d=q.shape[3], n=w_out.shape[1], causal=causal and pos is None,
            block_q=block_q, block_kv=block_kv)
    low = REGISTRY.select("flash_attention_matmul", pol, shape=shape)
    if low.op.endswith("_q8"):
        return _dispatch(low, pol, q, k, v, w_out,
                         causal=causal and pos is None,
                         kv_offset=kv_offset, interpret=interpret,
                         block_q=block_q, block_kv=block_kv, pos=pos,
                         block_tables=block_tables, w_scale=w_scale,
                         k_scale=k_scale, v_scale=v_scale)
    if w_scale is not None:
        w_out = _fused.dequantize_weight(w_out, w_scale, q.dtype)
    if k_scale is not None:
        k = (k.astype(jnp.float32) * k_scale).astype(q.dtype)
        v = (v.astype(jnp.float32) * v_scale).astype(q.dtype)
    return _dispatch(low, pol, q, k, v, w_out,
                     causal=causal and pos is None,
                     kv_offset=kv_offset, interpret=interpret,
                     block_q=block_q, block_kv=block_kv, pos=pos,
                     block_tables=block_tables)


def fused_rmsnorm_swiglu(x: jax.Array, weight: jax.Array,
                         w_cat: jax.Array, *, eps: float = 1e-6, mode=None,
                         policy: Optional[ExecutionPolicy] = None,
                         interpret: Optional[bool] = None,
                         w_scale: Optional[jax.Array] = None):
    """``silu(y @ wg) * (y @ wi)`` for ``y = rmsnorm(x, weight)`` in one
    kernel; ``w_cat`` is the concatenated ``[wi|wg]`` weight ``[D, 2F]``
    (same fallback + operand-coherence discipline as
    :func:`fused_rmsnorm_matmul`)."""
    pol, interpret = _resolve(mode, policy, interpret)
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    low = REGISTRY.select("rmsnorm_swiglu", pol, shape=dict(
        rows=rows, d=x.shape[-1], f=w_cat.shape[1] // 2))
    if low.op.endswith("_q8"):
        return _dispatch(low, pol, x, weight, w_cat, eps=eps,
                         interpret=interpret, w_scale=w_scale)
    if w_scale is not None:
        w_cat = _fused.dequantize_weight(w_cat, w_scale, x.dtype)
    return _dispatch(low, pol, x, weight, w_cat, eps=eps,
                     interpret=interpret)


def fused_ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
                   B_mat: jax.Array, C_mat: jax.Array, *,
                   chunk: Optional[int] = None,
                   initial_state: Optional[jax.Array] = None, mode=None,
                   policy: Optional[ExecutionPolicy] = None,
                   interpret: Optional[bool] = None):
    """The whole chunked SSD scan (`models/ssd.py`) in one kernel.

    Intra-chunk quadratic dots, the carried-state contribution, and the
    inter-chunk recurrence run in a single grid with the [N,P] state in
    VMEM scratch across the sequential chunk axis; the per-chunk
    intermediate tensors never stage through HBM.  Declared fallbacks:
    shuffle -> scratch-tree prefix scan, native -> the unfused jnp chunk
    path.  Returns the same ``(y, final_state)`` pair as the reference,
    so the final state seeds the decode recurrence unchanged."""
    pol, interpret = _resolve(mode, policy, interpret)
    b, l, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    low = REGISTRY.select("ssd_scan", pol, shape=dict(
        b=b, seq=l, h=h, p=p, g=g, n=n, chunk=chunk))
    return _dispatch(low, pol, x, dt, A, B_mat, C_mat,
                     initial_state=initial_state, chunk=chunk,
                     interpret=interpret)


def fused_ssd_decode(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                     A: jax.Array, B_t: jax.Array, C_t: jax.Array, *,
                     block_b: Optional[int] = None, mode=None,
                     policy: Optional[ExecutionPolicy] = None,
                     interpret: Optional[bool] = None):
    """One SSD decode tick (`models/ssd.py::ssd_decode_step`) in one kernel.

    Batches the one-token recurrence ``h <- exp(dt*A)*h + dt*B(x)x`` and
    the ``y = C*h`` readout across the serve batch with each slot's [N,P]
    state resident in VMEM for the tick; the state-sized ``dt*B(x)x``
    update tensor the unfused einsum trio materializes never stages
    through HBM.  Declared fallbacks: shuffle -> scratch-tree C*h reduce,
    native -> the unfused jnp einsum trio.  Returns the same
    ``(state, y)`` pair as the reference."""
    pol, interpret = _resolve(mode, policy, interpret)
    b, g, hg, n, p = state.shape
    low = REGISTRY.select("ssd_decode", pol, shape=dict(
        b=b, h=g * hg, p=p, g=g, n=n, block_b=block_b))
    return _dispatch(low, pol, state, x_t, dt_t, A, B_t, C_t,
                     block_b=block_b, interpret=interpret)


STRUCTURAL_COSTS = {
    "gemm": _gemm.structural_cost,
    "reduction": _reduction.structural_cost,
    "histogram": _histogram.structural_cost,
    "flash_attention": _attention.structural_cost,
    "rmsnorm": _rmsnorm.structural_cost,
    "rmsnorm_matmul": _fused.structural_cost_rmsnorm_matmul,
    "add_rmsnorm": _fused.structural_cost_add_rmsnorm,
    "flash_attention_matmul": _fused.structural_cost_flash_attention_matmul,
    "rmsnorm_swiglu": _fused.structural_cost_rmsnorm_swiglu,
    "rmsnorm_matmul_q8": _fused.structural_cost_rmsnorm_matmul_q8,
    "flash_attention_matmul_q8":
        _fused.structural_cost_flash_attention_matmul_q8,
    "rmsnorm_swiglu_q8": _fused.structural_cost_rmsnorm_swiglu_q8,
    "ssd_scan": _ssd.structural_cost_ssd_scan,
    "ssd_decode": _ssd.structural_cost_ssd_decode,
    **_collective.TP_COSTS,
}

#: Pallas-variant contracts per op, in portability order (registry view;
#: the library rows carry empty synthesized contracts and are omitted to
#: keep the seed-era shape of this table).
CONTRACTS = {
    op: tuple(c for c in REGISTRY.contracts(op)
              if c.mode is not IsaMode.LIBRARY)
    for op in REGISTRY.ops()
}
