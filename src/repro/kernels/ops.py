"""Public kernel API with ISA-mode dispatch — the Table V switchboard.

Everything above this layer (models, train/serve steps) calls these
wrappers; the active :class:`repro.core.IsaMode` decides which variant
runs.  ``interpret`` defaults to True off-TPU so the same code path is
exercised (and allclose-tested) on CPU; on a real TPU backend the Mosaic
kernels compile natively.

``ParallelConfig.use_pallas_attn`` gates whether models route their
attention hot-spot through the Pallas flash kernel: the multi-pod
dry-run lowers the pure-jnp chunked implementation (compilable for the
CPU placeholder backend), while TPU execution and the kernel-equivalence
tests use the Pallas path.  See DESIGN.md §2.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import IsaMode
from repro.kernels import attention as _attention
from repro.kernels import gemm as _gemm
from repro.kernels import histogram as _histogram
from repro.kernels import reduction as _reduction
from repro.kernels import rmsnorm as _rmsnorm
from repro.kernels import ref as ref  # noqa: F401 (re-export for tests)

MODES = tuple(m.value for m in IsaMode)


def default_interpret() -> bool:
    """Pallas interpret mode everywhere except a real TPU backend."""
    return jax.default_backend() != "tpu"


def _norm_mode(mode) -> str:
    if isinstance(mode, IsaMode):
        return mode.value
    if mode not in MODES:
        raise ValueError(f"unknown isa mode {mode!r}; valid: {MODES}")
    return mode


def matmul(a: jax.Array, b: jax.Array, *, mode="native",
           out_dtype=jnp.float32, interpret: Optional[bool] = None):
    mode = _norm_mode(mode)
    if mode == "abstract+shuffle":
        mode = "abstract"  # shuffle does not participate in GEMM
    interpret = default_interpret() if interpret is None else interpret
    return _gemm.gemm(a, b, mode=mode, out_dtype=out_dtype,
                      interpret=interpret)


def reduce_sum(x: jax.Array, *, mode="native",
               interpret: Optional[bool] = None):
    mode = _norm_mode(mode)
    interpret = default_interpret() if interpret is None else interpret
    return _reduction.reduce_sum(x, mode=mode, interpret=interpret)


def histogram(values: jax.Array, num_bins: int = 256, *, mode="native",
              interpret: Optional[bool] = None):
    mode = _norm_mode(mode)
    interpret = default_interpret() if interpret is None else interpret
    # abstract+shuffle dispatches to the rotate-tree private merge
    return _histogram.histogram(values, num_bins, mode=mode,
                                interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True,
                    kv_offset: Optional[int] = None, mode="native",
                    interpret: Optional[bool] = None,
                    block_q: int = 256, block_kv: int = 256):
    mode = _norm_mode(mode)
    interpret = default_interpret() if interpret is None else interpret
    if mode == "library":
        return ref.attention(q, k, v, causal=causal)
    return _attention.flash_attention(
        q, k, v, causal=causal, kv_offset=kv_offset, mode=mode,
        interpret=interpret, block_q=block_q, block_kv=block_kv)


def rmsnorm(x, weight, *, eps: float = 1e-6, mode="native",
            interpret: Optional[bool] = None):
    mode = _norm_mode(mode)
    interpret = default_interpret() if interpret is None else interpret
    return _rmsnorm.rmsnorm(x, weight, eps=eps, mode=mode,
                            interpret=interpret)


STRUCTURAL_COSTS = {
    "gemm": _gemm.structural_cost,
    "reduction": _reduction.structural_cost,
    "histogram": _histogram.structural_cost,
    "flash_attention": _attention.structural_cost,
    "rmsnorm": _rmsnorm.structural_cost,
}

CONTRACTS = {
    "gemm": (_gemm.ABSTRACT_CONTRACT, _gemm.NATIVE_CONTRACT),
    "reduction": (_reduction.ABSTRACT_CONTRACT, _reduction.SHUFFLE_CONTRACT,
                  _reduction.NATIVE_CONTRACT),
    "histogram": (_histogram.ABSTRACT_CONTRACT, _histogram.SHUFFLE_CONTRACT,
                  _histogram.NATIVE_CONTRACT),
    "flash_attention": (_attention.ABSTRACT_CONTRACT,
                        _attention.SHUFFLE_CONTRACT,
                        _attention.NATIVE_CONTRACT),
    "rmsnorm": (_rmsnorm.ABSTRACT_CONTRACT, _rmsnorm.SHUFFLE_CONTRACT,
                _rmsnorm.NATIVE_CONTRACT),
}
