"""Fused-epilogue lowerings — eliminating the inter-kernel HBM round trip.

The paper's §VII.C lesson generalized one level up: traffic to the
nearest memory that *could* have stayed on-chip is the quantity that
decides performance.  Between two kernels, that traffic is the activation
staged to HBM by the producer and immediately read back by the consumer —
one full ``hbm_bytes`` round trip per transformer sublayer that no
per-kernel optimization can remove.  These are the first registrations
where HBM traffic is the *treatment*, not the control:

- :func:`rmsnorm_matmul` — the norm is computed as a GEMM prologue: each
  row block is normalized in VMEM and consumed directly by the MXU
  contraction, so the normalized activation is **never materialized to
  HBM**.  Its ``structural_cost.hbm_bytes`` is the unfused
  ``rmsnorm + gemm`` sum minus exactly one activation round trip
  (``2 · rows · d · itemsize``: the write plus the read-back).
- :func:`add_rmsnorm` — the residual add is fused into the norm's load
  stage: the kernel reads the two addends directly and emits both the
  summed residual (the stream the next sublayer needs) and its norm.
  The *read-back* leg of the staging round trip disappears
  (``rows · d · itemsize``); the write survives because the residual
  stream owns the sum — the cost model says so honestly rather than
  claiming the full round trip.

Both ops carry the full Table V mode matrix.  The fused *program
structure* (two abstract ops realized by one kernel) is a lowering
decision available to every budget; within the kernel each mode spends
only its own cross-lane budget — the abstract variant still pays the
scratch-tree round-trips for the moment reduction and the moment
re-stage (the universal budget carries no fusion guarantee *inside* the
kernel either), while only ``native`` claims the target's
``fused_epilogue`` feature.  The ``library`` row is the **unfused jnp
pair** — simultaneously the numerical reference and the declared
fallback target when no fused lowering is legal (never a silent rewrite).

Tile shapes come from the shared GEMM resolver
(``repro.kernels.gemm.block_shape_for``, autotuner-aware) so the modeled
traffic and the executed tiling cannot drift apart, and the row plan of
``add_rmsnorm`` consults the tuning table like every other rowwise
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, align_up, register_op_space,
                        scratch_tree_bytes, tree_stages, tuned_plan,
                        validate_contract)
from repro.core.pipeline import CompilerParams
from repro.kernels import gemm as _gemm
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rmsnorm

LANES = TARGET.W
_MAX_BLOCK_ROWS = 64          # add_rmsnorm latency cap (mirrors rmsnorm)
register_op_space("add_rmsnorm", "rowwise", max_block_rows=_MAX_BLOCK_ROWS)
# rmsnorm_matmul's tile IS a GEMM tile: it shares the "gemm" tuning space
# (one table row tunes both), so no separate op space is registered.

# --------------------------------------------------------------------------
# Contracts: the fused ops spend the union of their constituents' budgets.
# --------------------------------------------------------------------------

_RM_ABSTRACT = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
        Primitive.REGISTER_OCCUPANCY,
    }))
_RM_SHUFFLE = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_RM_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_RM_NATIVE = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

_AR_ABSTRACT = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
_AR_SHUFFLE = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_AR_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_AR_NATIVE = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "dimension_semantics",
                               "multi_buffering"}))

for _c in (_RM_ABSTRACT, _RM_SHUFFLE, _RM_NATIVE,
           _AR_ABSTRACT, _AR_SHUFFLE, _AR_NATIVE):
    validate_contract(_c)


# --------------------------------------------------------------------------
# rmsnorm @ w_proj: the norm as a GEMM prologue
# --------------------------------------------------------------------------


def _rmsnorm_matmul_kernel(x_ref, w_ref, p_ref, o_ref, scratch_ref, *,
                           eps: float, mode: str, d_true: int):
    x = x_ref[...].astype(jnp.float32)                    # (bm, d)
    w = w_ref[...].astype(jnp.float32)                    # (1, d)
    # one shared source for the per-mode moment discipline (rmsnorm.py)
    y = _rmsnorm.normalize_block(x, w, scratch_ref, eps=eps, mode=mode,
                                 d_true=d_true)
    # the epilogue: the normalized block goes straight into the MXU
    # contraction from VMEM — it never exists in HBM.
    o_ref[...] = jax.lax.dot_general(
        y, p_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret"))
def rmsnorm_matmul(x: jax.Array, weight: jax.Array, w_proj: jax.Array, *,
                   eps: float = 1e-6, mode: str = "native",
                   interpret: bool = True) -> jax.Array:
    """``rmsnorm(x, weight) @ w_proj`` in one kernel.

    x: [..., D]; weight: [D]; w_proj: [D, N] -> [..., N] (x.dtype, f32
    accumulation).  Tiled over (row blocks × N blocks) with the shared
    GEMM tile resolver; the full feature row stays resident per block
    (the moment needs the whole row), so D is not tiled.
    """
    if mode == "library":
        y = _ref.rmsnorm(x, weight, eps)
        return jnp.einsum("...d,dn->...n", y, w_proj.astype(y.dtype))
    *lead, d = x.shape
    n = w_proj.shape[1]
    assert w_proj.shape[0] == d, (x.shape, w_proj.shape)
    rows = 1
    for s in lead:
        rows *= s
    x2d = x.reshape(rows, d)
    w2d = weight.reshape(1, d)
    p2d = w_proj

    d_padded = d
    if mode != "native":
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))
            p2d = jnp.pad(p2d, ((0, pad_d), (0, 0)))

    bm, bn, _ = _gemm.block_shape_for(mode, rows, n, d, x.dtype)
    bm = min(bm, align_up(rows, 128))
    bn = min(bn, align_up(n, 128))
    pad_m = (-rows) % bm
    pad_n = (-n) % bn
    if pad_m:
        x2d = jnp.pad(x2d, ((0, pad_m), (0, 0)))
    if pad_n:
        p2d = jnp.pad(p2d, ((0, 0), (0, pad_n)))
    mp, np_ = rows + pad_m, n + pad_n
    grid = (mp // bm, np_ // bn)

    params = None
    if mode == "native":
        params = CompilerParams(
            dimension_semantics=("parallel", "parallel"))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_matmul_kernel, eps=eps, mode=mode,
                          d_true=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_padded), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d_padded), lambda i, j: (0, 0)),
            pl.BlockSpec((d_padded, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        # only the abstract moment tree stages through scratch
        scratch_shapes=[pltpu.VMEM(
            (bm, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_rmsnorm_matmul_{mode.replace('+', '_')}",
    )(x2d, w2d, p2d)
    return out[:rows, :n].reshape(*lead, n)


def structural_cost_rmsnorm_matmul(rows: int, d: int, n: int, mode: str,
                                   dtype=jnp.float32) -> dict:
    """The unfused pair's traffic minus exactly one activation round trip.

    Composes the registered ``gemm`` and ``rmsnorm`` cost models (same
    shapes, same mode, same autotuned tiles), then removes the write and
    read-back of the normalized activation — the two legs of the
    inter-kernel staging this lowering eliminates.  ``library`` is the
    unfused pair itself: full sum, nothing saved.
    """
    itemsize = jnp.dtype(dtype).itemsize
    g = _gemm.structural_cost(m=rows, n=n, k=d, mode=mode, dtype=dtype)
    r = _rmsnorm.structural_cost(rows=rows, d=d, mode=mode, dtype=dtype)
    unfused = g["hbm_bytes"] + r["hbm_bytes"]
    saved = 0 if mode == "library" else 2 * rows * d * itemsize
    if mode == "library":
        bm = bn = 512
    else:
        # the kernel's own problem-size clamps, so block/steps/scratch
        # report the executed tiling (re-read counts are unaffected: a
        # clamp only fires when the tile already covers the dimension)
        bm, bn, _ = _gemm.block_shape_for(mode, rows, n, d, dtype)
        bm = min(bm, align_up(rows, 128))
        bn = min(bn, align_up(n, 128))
    steps = -(-rows // bm) * -(-n // bn)
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = steps * (scratch_tree_bytes(LANES, rows=bm)
                                 + 3 * bm * 4)
    else:
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "flops": g["flops"],
        "block": (bm, bn),
        "blocks": steps,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "fused_epilogue": mode != "library",
    }


# --------------------------------------------------------------------------
# (x + residual) -> rmsnorm: the add fused into the norm's load stage
# --------------------------------------------------------------------------


def _add_rmsnorm_kernel(x_ref, r_ref, w_ref, o_ref, s_ref, scratch_ref, *,
                        eps: float, mode: str, d_true: int):
    # the load stage IS the residual add: both addends arrive in VMEM and
    # the staged sum is never read back from HBM by the norm.
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    s_ref[...] = s.astype(s_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = _rmsnorm.normalize_block(
        s, w, scratch_ref, eps=eps, mode=mode,
        d_true=d_true).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret"))
def add_rmsnorm(x: jax.Array, residual: jax.Array, weight: jax.Array, *,
                eps: float = 1e-6, mode: str = "native",
                interpret: bool = True):
    """``(rmsnorm(x + residual, weight), x + residual)`` in one kernel.

    Returns the norm *and* the summed residual stream (both [..., D],
    x.dtype) — the residual→norm hot pair of every transformer sublayer.
    """
    assert x.shape == residual.shape, (x.shape, residual.shape)
    if mode == "library":
        s = x + residual
        return _ref.rmsnorm(s, weight, eps), s
    *lead, d = x.shape
    rows = 1
    for sdim in lead:
        rows *= sdim
    x2d = x.reshape(rows, d)
    r2d = residual.reshape(rows, d)
    w2d = weight.reshape(1, d)
    d_padded = d
    if mode != "native":
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            r2d = jnp.pad(r2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))

    itemsize = jnp.dtype(x.dtype).itemsize
    plan = tuned_plan("add_rmsnorm", rows, 2 * d_padded * itemsize,
                      mode=mode, max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("parallel",))
    block = plan.block_rows
    pad = plan.padded_rows - rows
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        r2d = jnp.pad(r2d, ((0, pad), (0, 0)))

    normed, summed = pl.pallas_call(
        functools.partial(_add_rmsnorm_kernel, eps=eps, mode=mode,
                          d_true=d),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((1, d_padded), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM(
            (block, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=plan.compiler_params,
        interpret=interpret,
        name=f"uisa_add_rmsnorm_{mode.replace('+', '_')}",
    )(x2d, r2d, w2d)
    normed = normed[:rows, :d].reshape(x.shape)
    summed = summed[:rows, :d].reshape(x.shape)
    return normed, summed


def structural_cost_add_rmsnorm(rows: int, d: int, mode: str,
                                dtype=jnp.float32) -> dict:
    """The read-back leg of the staging round trip, eliminated.

    Unfused pair = elementwise add (read x, read residual, write sum) +
    registered rmsnorm (read sum, read weight, write norm): five
    activation-sized HBM terms.  Fused = read x, read residual, write sum,
    write norm: four.  The surviving write is the residual stream's own
    output, so the honest saving is ``rows·d·itemsize`` — one leg, not
    the full round trip (cf. ``rmsnorm_matmul``, where the activation
    vanishes from HBM entirely).
    """
    itemsize = jnp.dtype(dtype).itemsize
    r = _rmsnorm.structural_cost(rows=rows, d=d, mode=mode, dtype=dtype)
    unfused = 3 * rows * d * itemsize + r["hbm_bytes"]
    saved = 0 if mode == "library" else rows * d * itemsize
    d_padded = d if mode == "native" else d + ((-d) % LANES)
    plan = tuned_plan("add_rmsnorm", rows, 2 * d_padded * itemsize,
                      mode=mode if mode != "library" else "native",
                      max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("parallel",))
    blocks = plan.grid[0]
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = blocks * (
            scratch_tree_bytes(LANES, rows=plan.block_rows)
            + 3 * plan.block_rows * 4)
    else:
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "blocks": blocks,
        "block_rows": plan.block_rows,
        "pipeline_occupancy": plan.occupancy,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "fused_epilogue": mode != "library",
    }


# --------------------------------------------------------------------------
# Library rows: the unfused jnp pairs (numerical reference AND the declared
# fallback target — requesting an illegal fused mode degrades to the pair
# with a warning + a recorded event, never silently).
# --------------------------------------------------------------------------


def _rmsnorm_matmul_library(x, weight, w_proj, *, eps: float = 1e-6,
                            interpret: bool = True):
    del interpret
    return rmsnorm_matmul(x, weight, w_proj, eps=eps, mode="library")


def _add_rmsnorm_library(x, residual, weight, *, eps: float = 1e-6,
                         interpret: bool = True):
    del interpret
    return add_rmsnorm(x, residual, weight, eps=eps, mode="library")


for _mode, _contract in (("abstract", _RM_ABSTRACT),
                         ("abstract+shuffle", _RM_SHUFFLE),
                         ("native", _RM_NATIVE)):
    REGISTRY.register(
        "rmsnorm_matmul", _mode,
        functools.partial(rmsnorm_matmul, mode=_mode), contract=_contract,
        cost=functools.partial(structural_cost_rmsnorm_matmul, mode=_mode))
REGISTRY.register(
    "rmsnorm_matmul", IsaMode.LIBRARY, _rmsnorm_matmul_library,
    cost=functools.partial(structural_cost_rmsnorm_matmul, mode="library"))

for _mode, _contract in (("abstract", _AR_ABSTRACT),
                         ("abstract+shuffle", _AR_SHUFFLE),
                         ("native", _AR_NATIVE)):
    REGISTRY.register(
        "add_rmsnorm", _mode,
        functools.partial(add_rmsnorm, mode=_mode), contract=_contract,
        cost=functools.partial(structural_cost_add_rmsnorm, mode=_mode))
REGISTRY.register(
    "add_rmsnorm", IsaMode.LIBRARY, _add_rmsnorm_library,
    cost=functools.partial(structural_cost_add_rmsnorm, mode="library"))

# Declared per-mode fallbacks (warned + recorded in fallback_events):
# the shuffle moment tree degrades to scratch round-trips on a no-shuffle
# dialect; the target-pinned native epilogue degrades to the unfused XLA
# pair (the library row) anywhere it is illegal.
for _op in ("rmsnorm_matmul", "add_rmsnorm"):
    REGISTRY.declare_fallback(
        _op, IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
        reason="no lane shuffle on this dialect; the moment reduction "
               "degrades to the scratch-tree lowering")
    REGISTRY.declare_fallback(
        _op, IsaMode.NATIVE, IsaMode.LIBRARY,
        reason="fused native epilogue is target-pinned; the unfused XLA "
               "pair is the declared escape")
