"""Fused-epilogue lowerings — eliminating the inter-kernel HBM round trip.

The paper's §VII.C lesson generalized one level up: traffic to the
nearest memory that *could* have stayed on-chip is the quantity that
decides performance.  Between two kernels, that traffic is the activation
staged to HBM by the producer and immediately read back by the consumer —
one full ``hbm_bytes`` round trip per transformer sublayer that no
per-kernel optimization can remove.  These are the first registrations
where HBM traffic is the *treatment*, not the control:

- :func:`rmsnorm_matmul` — the norm is computed as a GEMM prologue: each
  row block is normalized in VMEM and consumed directly by the MXU
  contraction, so the normalized activation is **never materialized to
  HBM**.  Its ``structural_cost.hbm_bytes`` is the unfused
  ``rmsnorm + gemm`` sum minus exactly one activation round trip
  (``2 · rows · d · itemsize``: the write plus the read-back).
- :func:`add_rmsnorm` — the residual add is fused into the norm's load
  stage: the kernel reads the two addends directly and emits both the
  summed residual (the stream the next sublayer needs) and its norm.
  The *read-back* leg of the staging round trip disappears
  (``rows · d · itemsize``); the write survives because the residual
  stream owns the sum — the cost model says so honestly rather than
  claiming the full round trip.
- :func:`flash_attention_matmul` — the post-attention ``wo`` projection
  consumed from the online-softmax accumulator in VMEM
  (kernels/attention.py's epilogue hook).  The flash grid is reordered so
  heads run on a *sequential* axis and every head's ``(acc / l) @ wo_h``
  contribution accumulates into one shared ``[bq, N]`` output block — the
  ``[B, S, H, D]`` attention output never exists in HBM (write + read-back
  = ``2 · B·S·H·D · itemsize``, the largest single round trip in a
  transformer sublayer).
- :func:`rmsnorm_swiglu` — ln2 → ``wi``/``wg`` as one fused call against
  the concatenated ``[wi|wg]`` weight, with the silu gate applied in the
  epilogue: the normalized activation feeds both projections from VMEM
  (same ``2 · rows · d · itemsize`` saving as :func:`rmsnorm_matmul`; the
  ``hi``/``hg`` products additionally never stage — claimed conservatively,
  the pinned delta stays exactly one activation round trip).

Both ops carry the full Table V mode matrix.  The fused *program
structure* (two abstract ops realized by one kernel) is a lowering
decision available to every budget; within the kernel each mode spends
only its own cross-lane budget — the abstract variant still pays the
scratch-tree round-trips for the moment reduction and the moment
re-stage (the universal budget carries no fusion guarantee *inside* the
kernel either), while only ``native`` claims the target's
``fused_epilogue`` feature.  The ``library`` row is the **unfused jnp
pair** — simultaneously the numerical reference and the declared
fallback target when no fused lowering is legal (never a silent rewrite).

Tile shapes come from the shared GEMM resolver
(``repro.kernels.gemm.block_shape_for``, autotuner-aware) so the modeled
traffic and the executed tiling cannot drift apart, and the row plan of
``add_rmsnorm`` consults the tuning table like every other rowwise
kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, align_up, register_op_space,
                        scratch_tree_bytes, tree_stages, tuned_plan,
                        validate_contract)
from repro.core.pipeline import CompilerParams
from repro.core.tuning import (attention_matmul_bucket, swiglu_bucket,
                               tuned_entry)
from repro.kernels import attention as _attention
from repro.kernels import gemm as _gemm
from repro.kernels import ref as _ref
from repro.kernels import rmsnorm as _rmsnorm

LANES = TARGET.W
_MAX_BLOCK_ROWS = 64          # add_rmsnorm latency cap (mirrors rmsnorm)
register_op_space("add_rmsnorm", "rowwise", max_block_rows=_MAX_BLOCK_ROWS)
# rmsnorm_matmul's tile IS a GEMM tile: it shares the "gemm" tuning space
# (one table row tunes both, so no separate op space); the two ops below
# have genuinely different working sets and get their own Eq. 1 grids.
register_op_space("rmsnorm_swiglu", "swiglu")
register_op_space("flash_attention_matmul", "attention_matmul")
# The quantized twins tune separately: their weight tiles are int8, so a
# given scratchpad budget admits larger (or deeper-buffered) tiles — the
# tuner must be allowed to find that.  rmsnorm_matmul_q8 rides gemm's
# space exactly like its f32 twin.
register_op_space("rmsnorm_swiglu_q8", "swiglu")
register_op_space("flash_attention_matmul_q8", "attention_matmul")

#: every fused multi-op lowering this module registers — the sweep target
#: for validate_contracts' cost-accounting gate and the property tests.
#: Extended with QUANT_OPS at the bottom of this module once the quantized
#: twins are registered.
FUSED_OPS = ("add_rmsnorm", "flash_attention_matmul", "rmsnorm_matmul",
             "rmsnorm_swiglu")

#: the int8 dialect variants (ISSUE 7): same fused program structure, but
#: the weight prologue loads int8 blocks + per-channel f32 scales and
#: dequantizes in VMEM — quantized weights never stage through HBM at f32
#: width.  ``REGISTRY.select`` retargets the f32 op onto its twin when the
#: policy carries ``precision="int8"``.
QUANT_OPS = ("flash_attention_matmul_q8", "rmsnorm_matmul_q8",
             "rmsnorm_swiglu_q8")


def quantize_weight(w: jax.Array):
    """Per-output-channel symmetric int8 quantization of a weight matrix.

    ``w``: [..., K, N] — the scale reduces over the contraction axis
    (axis -2), one f32 scale per output channel: [..., N].  The channel
    max maps to exactly ±127, so ``dequantize_weight`` round-trips the
    extreme value losslessly.
    """
    m = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
    scale = jnp.maximum(m / 127.0, 1e-8).astype(jnp.float32)
    q = jnp.clip(jnp.round(w.astype(jnp.float32)
                           / jnp.expand_dims(scale, -2)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_weight(q: jax.Array, scale: jax.Array,
                      dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`quantize_weight` (the library rows' prologue)."""
    return (q.astype(jnp.float32)
            * jnp.expand_dims(scale, -2)).astype(dtype)


def _weight_stream(m: int, n: int, k: int, mode: str, dtype,
                   plan_dialect: str | None):
    """The B-matrix leg of the composed GEMM's hbm stream.

    Mirrors ``gemm.structural_cost`` exactly (same resolver, same library
    constant, same re-read count) so a quantized cost can substitute its
    own weight stream into the composed sum without breaking the
    ``hbm == unfused - saved`` identity validate_contracts pins."""
    itemsize = jnp.dtype(dtype).itemsize
    if mode == "library":
        bm = 512
    else:
        bm, _, _ = _gemm.block_shape_for(mode, m, n, k, dtype, plan_dialect)
    rereads = max(1, -(-m // bm))
    return k * n * itemsize * rereads, rereads


def _q8_weight_stream(rereads: int, k: int, n: int) -> int:
    """int8 weight elements + the f32 per-channel scale row, re-fetched
    once per row-block sweep like the f32 weight tile they replace."""
    return (k * n * 1 + n * 4) * rereads

# --------------------------------------------------------------------------
# Contracts: the fused ops spend the union of their constituents' budgets.
# --------------------------------------------------------------------------

_RM_ABSTRACT = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
        Primitive.REGISTER_OCCUPANCY,
    }))
_RM_SHUFFLE = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_RM_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_RM_NATIVE = KernelContract(
    kernel="rmsnorm_matmul", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

_AR_ABSTRACT = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
_AR_SHUFFLE = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_AR_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_AR_NATIVE = KernelContract(
    kernel="add_rmsnorm", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "dimension_semantics",
                               "multi_buffering"}))

# flash_attention_matmul spends attention's budget (the epilogue is an MMA
# on data already resident); rmsnorm_swiglu spends rmsnorm_matmul's.
_FA_ABSTRACT = KernelContract(
    kernel="flash_attention_matmul", mode=IsaMode.ABSTRACT,
    primitives=_attention.ABSTRACT_CONTRACT.primitives)
_FA_SHUFFLE = KernelContract(
    kernel="flash_attention_matmul", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_FA_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_FA_NATIVE = KernelContract(
    kernel="flash_attention_matmul", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

_SW_ABSTRACT = KernelContract(
    kernel="rmsnorm_swiglu", mode=IsaMode.ABSTRACT,
    primitives=_RM_ABSTRACT.primitives)
_SW_SHUFFLE = KernelContract(
    kernel="rmsnorm_swiglu", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_SW_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_SW_NATIVE = KernelContract(
    kernel="rmsnorm_swiglu", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

# The quantized twins spend the identical primitive budgets: dequantize
# is an elementwise multiply on a block already resident in VMEM — no new
# cross-lane or native capability is consumed, only the *operand dtype*
# of the prologue load changes.  (Same contract discipline, new kernel
# names: contract.kernel must match the registered op.)
_RMQ_ABSTRACT = KernelContract(
    kernel="rmsnorm_matmul_q8", mode=IsaMode.ABSTRACT,
    primitives=_RM_ABSTRACT.primitives)
_RMQ_SHUFFLE = KernelContract(
    kernel="rmsnorm_matmul_q8", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_RM_SHUFFLE.primitives)
_RMQ_NATIVE = KernelContract(
    kernel="rmsnorm_matmul_q8", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=_RM_NATIVE.native_features)

_FAQ_ABSTRACT = KernelContract(
    kernel="flash_attention_matmul_q8", mode=IsaMode.ABSTRACT,
    primitives=_FA_ABSTRACT.primitives)
_FAQ_SHUFFLE = KernelContract(
    kernel="flash_attention_matmul_q8", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_FA_SHUFFLE.primitives)
_FAQ_NATIVE = KernelContract(
    kernel="flash_attention_matmul_q8", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=_FA_NATIVE.native_features)

_SWQ_ABSTRACT = KernelContract(
    kernel="rmsnorm_swiglu_q8", mode=IsaMode.ABSTRACT,
    primitives=_SW_ABSTRACT.primitives)
_SWQ_SHUFFLE = KernelContract(
    kernel="rmsnorm_swiglu_q8", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_SW_SHUFFLE.primitives)
_SWQ_NATIVE = KernelContract(
    kernel="rmsnorm_swiglu_q8", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=_SW_NATIVE.native_features)

for _c in (_RM_ABSTRACT, _RM_SHUFFLE, _RM_NATIVE,
           _AR_ABSTRACT, _AR_SHUFFLE, _AR_NATIVE,
           _FA_ABSTRACT, _FA_SHUFFLE, _FA_NATIVE,
           _SW_ABSTRACT, _SW_SHUFFLE, _SW_NATIVE,
           _RMQ_ABSTRACT, _RMQ_SHUFFLE, _RMQ_NATIVE,
           _FAQ_ABSTRACT, _FAQ_SHUFFLE, _FAQ_NATIVE,
           _SWQ_ABSTRACT, _SWQ_SHUFFLE, _SWQ_NATIVE):
    validate_contract(_c)


# --------------------------------------------------------------------------
# rmsnorm @ w_proj: the norm as a GEMM prologue
# --------------------------------------------------------------------------


def _rmsnorm_matmul_kernel(*refs, eps: float, mode: str, d_true: int,
                           quant: bool = False):
    if quant:
        x_ref, w_ref, p_ref, s_ref, o_ref, scratch_ref = refs
    else:
        x_ref, w_ref, p_ref, o_ref, scratch_ref = refs
        s_ref = None
    x = x_ref[...].astype(jnp.float32)                    # (bm, d)
    w = w_ref[...].astype(jnp.float32)                    # (1, d)
    # one shared source for the per-mode moment discipline (rmsnorm.py)
    y = _rmsnorm.normalize_block(x, w, scratch_ref, eps=eps, mode=mode,
                                 d_true=d_true)
    p = p_ref[...].astype(jnp.float32)                    # (d, bn)
    if s_ref is not None:
        # the quantized prologue: the weight block arrives int8 and its
        # (1, bn) per-channel scales rescale it HERE, in VMEM — the f32
        # weight never exists in HBM (ISSUE 7).
        p = p * s_ref[...]
    # the epilogue: the normalized block goes straight into the MXU
    # contraction from VMEM — it never exists in HBM.
    o_ref[...] = jax.lax.dot_general(
        y, p, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect"))
def rmsnorm_matmul(x: jax.Array, weight: jax.Array, w_proj: jax.Array, *,
                   w_scale: jax.Array | None = None, eps: float = 1e-6,
                   mode: str = "native", interpret: bool = True,
                   plan_dialect: str | None = None) -> jax.Array:
    """``rmsnorm(x, weight) @ w_proj`` in one kernel.

    x: [..., D]; weight: [D]; w_proj: [D, N] -> [..., N] (x.dtype, f32
    accumulation).  Tiled over (row blocks × N blocks) with the shared
    GEMM tile resolver; the full feature row stays resident per block
    (the moment needs the whole row), so D is not tiled.

    ``w_scale`` ([N] f32, with ``w_proj`` int8) selects the quantized
    prologue: the weight block is dequantized per-channel in VMEM
    (the ``rmsnorm_matmul_q8`` registry rows; the library row
    dequantizes up front and runs the unfused pair).
    """
    if mode == "library":
        if w_scale is not None:
            w_proj = dequantize_weight(w_proj, w_scale, x.dtype)
        y = _ref.rmsnorm(x, weight, eps)
        return jnp.einsum("...d,dn->...n", y, w_proj.astype(y.dtype))
    *lead, d = x.shape
    n = w_proj.shape[1]
    assert w_proj.shape[0] == d, (x.shape, w_proj.shape)
    rows = 1
    for s in lead:
        rows *= s
    x2d = x.reshape(rows, d)
    w2d = weight.reshape(1, d)
    p2d = w_proj
    s2d = None if w_scale is None else w_scale.reshape(1, n)

    d_padded = d
    if mode != "native":
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))
            p2d = jnp.pad(p2d, ((0, pad_d), (0, 0)))

    bm, bn, _ = _gemm.block_shape_for(mode, rows, n, d, x.dtype,
                                      plan_dialect)
    bm = min(bm, align_up(rows, 128))
    bn = min(bn, align_up(n, 128))
    pad_m = (-rows) % bm
    pad_n = (-n) % bn
    if pad_m:
        x2d = jnp.pad(x2d, ((0, pad_m), (0, 0)))
    if pad_n:
        p2d = jnp.pad(p2d, ((0, 0), (0, pad_n)))
        if s2d is not None:
            s2d = jnp.pad(s2d, ((0, 0), (0, pad_n)))
    mp, np_ = rows + pad_m, n + pad_n
    grid = (mp // bm, np_ // bn)

    params = None
    if mode == "native":
        params = CompilerParams(
            dimension_semantics=("parallel", "parallel"))

    in_specs = [
        pl.BlockSpec((bm, d_padded), lambda i, j: (i, 0)),
        pl.BlockSpec((1, d_padded), lambda i, j: (0, 0)),
        pl.BlockSpec((d_padded, bn), lambda i, j: (0, j)),
    ]
    operands = [x2d, w2d, p2d]
    if s2d is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        operands.append(s2d)
    kernel_name = ("uisa_rmsnorm_matmul_q8_" if s2d is not None
                   else "uisa_rmsnorm_matmul_") + mode.replace('+', '_')

    out = pl.pallas_call(
        functools.partial(_rmsnorm_matmul_kernel, eps=eps, mode=mode,
                          d_true=d, quant=s2d is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        # only the abstract moment tree stages through scratch
        scratch_shapes=[pltpu.VMEM(
            (bm, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=kernel_name,
    )(*operands)
    return out[:rows, :n].reshape(*lead, n)


def structural_cost_rmsnorm_matmul(rows: int, d: int, n: int, mode: str,
                                   dtype=jnp.float32,
                                   plan_dialect: str | None = None) -> dict:
    """The unfused pair's traffic minus exactly one activation round trip.

    Composes the registered ``gemm`` and ``rmsnorm`` cost models (same
    shapes, same mode, same autotuned tiles), then removes the write and
    read-back of the normalized activation — the two legs of the
    inter-kernel staging this lowering eliminates.  ``library`` is the
    unfused pair itself: full sum, nothing saved.
    """
    itemsize = jnp.dtype(dtype).itemsize
    g = _gemm.structural_cost(m=rows, n=n, k=d, mode=mode, dtype=dtype,
                              plan_dialect=plan_dialect)
    r = _rmsnorm.structural_cost(rows=rows, d=d, mode=mode, dtype=dtype,
                                 plan_dialect=plan_dialect)
    unfused = g["hbm_bytes"] + r["hbm_bytes"]
    saved = 0 if mode == "library" else 2 * rows * d * itemsize
    if mode == "library":
        bm = bn = 512
    else:
        # the kernel's own problem-size clamps, so block/steps/scratch
        # report the executed tiling (re-read counts are unaffected: a
        # clamp only fires when the tile already covers the dimension)
        bm, bn, _ = _gemm.block_shape_for(mode, rows, n, d, dtype,
                                          plan_dialect)
        bm = min(bm, align_up(rows, 128))
        bn = min(bn, align_up(n, 128))
    steps = -(-rows // bm) * -(-n // bn)
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = steps * (scratch_tree_bytes(LANES, rows=bm)
                                 + 3 * bm * 4)
    else:
        round_trips = 0
        scratch_bytes = 0
    ws, _ = _weight_stream(rows, n, d, mode, dtype, plan_dialect)
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "weight_stream_bytes": ws,
        "flops": g["flops"],
        "block": (bm, bn),
        "blocks": steps,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "fused_epilogue": mode != "library",
    }


def structural_cost_rmsnorm_matmul_q8(rows: int, d: int, n: int, mode: str,
                                      dtype=jnp.float32,
                                      plan_dialect: str | None = None
                                      ) -> dict:
    """The f32 cost with the weight stream swapped to int8 + scales.

    Same composed sum, same identities (``hbm == unfused - saved``, the
    saving unchanged — the fusion still removes exactly one activation
    round trip); only the B-matrix leg of the GEMM term shrinks from f32
    width to int8 + one f32 scale row per re-read.  The library row is
    the dequantize-then-unfused-pair reference and carries the same
    substitution (XLA fuses the dequant into the consumer's read)."""
    base = structural_cost_rmsnorm_matmul(rows, d, n, mode, dtype,
                                          plan_dialect)
    ws_f32, rereads = _weight_stream(rows, n, d, mode, dtype, plan_dialect)
    ws_q8 = _q8_weight_stream(rereads, d, n)
    delta = ws_f32 - ws_q8
    base.update(
        hbm_bytes=base["hbm_bytes"] - delta,
        hbm_bytes_unfused_pair=base["hbm_bytes_unfused_pair"] - delta,
        weight_stream_bytes=ws_q8,
        weight_stream_bytes_f32=ws_f32,
        weight_precision="int8",
    )
    return base


# --------------------------------------------------------------------------
# (x + residual) -> rmsnorm: the add fused into the norm's load stage
# --------------------------------------------------------------------------


def _add_rmsnorm_kernel(x_ref, r_ref, w_ref, o_ref, s_ref, scratch_ref, *,
                        eps: float, mode: str, d_true: int):
    # the load stage IS the residual add: both addends arrive in VMEM and
    # the staged sum is never read back from HBM by the norm.
    s = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    s_ref[...] = s.astype(s_ref.dtype)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = _rmsnorm.normalize_block(
        s, w, scratch_ref, eps=eps, mode=mode,
        d_true=d_true).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect"))
def add_rmsnorm(x: jax.Array, residual: jax.Array, weight: jax.Array, *,
                eps: float = 1e-6, mode: str = "native",
                interpret: bool = True, plan_dialect: str | None = None):
    """``(rmsnorm(x + residual, weight), x + residual)`` in one kernel.

    Returns the norm *and* the summed residual stream (both [..., D],
    x.dtype) — the residual→norm hot pair of every transformer sublayer.
    """
    assert x.shape == residual.shape, (x.shape, residual.shape)
    if mode == "library":
        s = x + residual
        return _ref.rmsnorm(s, weight, eps), s
    *lead, d = x.shape
    rows = 1
    for sdim in lead:
        rows *= sdim
    x2d = x.reshape(rows, d)
    r2d = residual.reshape(rows, d)
    w2d = weight.reshape(1, d)
    d_padded = d
    if mode != "native":
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            r2d = jnp.pad(r2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))

    itemsize = jnp.dtype(x.dtype).itemsize
    plan = tuned_plan("add_rmsnorm", rows, 2 * d_padded * itemsize,
                      mode=mode, dialect=plan_dialect,
                      max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("parallel",))
    block = plan.block_rows
    pad = plan.padded_rows - rows
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
        r2d = jnp.pad(r2d, ((0, pad), (0, 0)))

    normed, summed = pl.pallas_call(
        functools.partial(_add_rmsnorm_kernel, eps=eps, mode=mode,
                          d_true=d),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((1, d_padded), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
            jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        ],
        scratch_shapes=[pltpu.VMEM(
            (block, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=plan.compiler_params,
        interpret=interpret,
        name=f"uisa_add_rmsnorm_{mode.replace('+', '_')}",
    )(x2d, r2d, w2d)
    normed = normed[:rows, :d].reshape(x.shape)
    summed = summed[:rows, :d].reshape(x.shape)
    return normed, summed


def structural_cost_add_rmsnorm(rows: int, d: int, mode: str,
                                dtype=jnp.float32,
                                plan_dialect: str | None = None) -> dict:
    """The read-back leg of the staging round trip, eliminated.

    Unfused pair = elementwise add (read x, read residual, write sum) +
    registered rmsnorm (read sum, read weight, write norm): five
    activation-sized HBM terms.  Fused = read x, read residual, write sum,
    write norm: four.  The surviving write is the residual stream's own
    output, so the honest saving is ``rows·d·itemsize`` — one leg, not
    the full round trip (cf. ``rmsnorm_matmul``, where the activation
    vanishes from HBM entirely).
    """
    itemsize = jnp.dtype(dtype).itemsize
    r = _rmsnorm.structural_cost(rows=rows, d=d, mode=mode, dtype=dtype,
                                 plan_dialect=plan_dialect)
    unfused = 3 * rows * d * itemsize + r["hbm_bytes"]
    saved = 0 if mode == "library" else rows * d * itemsize
    d_padded = d if mode == "native" else d + ((-d) % LANES)
    plan = tuned_plan("add_rmsnorm", rows, 2 * d_padded * itemsize,
                      mode=mode if mode != "library" else "native",
                      dialect=plan_dialect,
                      max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("parallel",))
    blocks = plan.grid[0]
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = blocks * (
            scratch_tree_bytes(LANES, rows=plan.block_rows)
            + 3 * plan.block_rows * 4)
    else:
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "blocks": blocks,
        "block_rows": plan.block_rows,
        "pipeline_occupancy": plan.occupancy,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "fused_epilogue": mode != "library",
    }


# --------------------------------------------------------------------------
# flash_attention -> wo: the output projection consumed from the
# online-softmax accumulator (the epilogue hook in kernels/attention.py)
# --------------------------------------------------------------------------


def resolve_attention_matmul_blocks(mode: str, sq: int, skv: int, d: int,
                                    n: int, block_q=None, block_kv=None,
                                    plan_dialect: str | None = None,
                                    op: str = "flash_attention_matmul"):
    """Caller-pinned blocks win; then this op's own tuned entry (its
    working set includes the wo slice and the shared output block, so it
    tunes separately from bare flash); then the flash resolution.  Shared
    by the kernel and ``structural_cost`` — modeled == executed.
    ``plan_dialect`` names the table slice consulted; ``op`` names the
    table *row* — the quantized twin consults its own tuned slice."""
    if block_q is None or block_kv is None:
        entry = tuned_entry(op, mode,
                            attention_matmul_bucket(sq, skv, d, n),
                            dialect=plan_dialect)
        if entry and "block_q" in entry and "block_kv" in entry:
            tq, tkv = int(entry["block_q"]), int(entry["block_kv"])
        else:
            tq, tkv = _attention.resolve_blocks(
                mode, sq, skv, d, plan_dialect=plan_dialect)
        block_q = tq if block_q is None else block_q
        block_kv = tkv if block_kv is None else block_kv
    block_q = min(block_q, align_up(sq, 128))
    block_kv = min(block_kv, align_up(skv, 128))
    if mode != "native":
        # abstract/shuffle row reduces fold into 128-lane vregs
        block_kv = max(LANES, (block_kv // LANES) * LANES)
    return block_q, block_kv


def _flash_matmul_kernel(*refs, scale: float,
                         causal: bool, kv_offset: int, block_q: int,
                         block_kv: int, n_kv: int, n_heads: int,
                         kv_len: int | None, mode: str,
                         has_pos: bool = False, paged: bool = False,
                         quant_w: bool = False, quant_kv: bool = False):
    # Operand order (optional members gated by the static flags):
    #   [tbl,] q, k, [k_scale,] v, [v_scale,] w, [w_scale,] [pos]
    # paged: the block table is the scalar-prefetch operand (consumed
    # entirely by the kv index maps — the gather) and the per-slot
    # frontier rides in as the (1, 1) pos block.  quant_kv: the kv blocks
    # arrive int8 with (block_kv, 1) per-token scales; quant_w: the wo
    # slice arrives int8 with a (1, n) per-channel scale row.  All
    # dequantization happens in VMEM, on blocks already resident.
    refs = list(refs)
    if paged:
        refs.pop(0)                               # block table (index maps)
    q_ref = refs.pop(0)
    k_ref = refs.pop(0)
    k_scale_ref = refs.pop(0) if quant_kv else None
    v_ref = refs.pop(0)
    v_scale_ref = refs.pop(0) if quant_kv else None
    w_ref = refs.pop(0)
    ws_ref = refs.pop(0) if quant_w else None
    pos_ref = refs.pop(0) if (paged or has_pos) else None
    o_ref, m_ref, l_ref, acc_ref, red_ref, oacc_ref = refs
    hh = pl.program_id(2)

    def epilogue(out):
        # the hook: (acc / l) goes straight into the head's wo slice from
        # VMEM; heads run sequentially and accumulate into one shared f32
        # scratch (a single output-dtype cast at the last head — the same
        # accumulation discipline as the unfused einsum), so the
        # attention output never exists in HBM.
        w = w_ref[0].astype(jnp.float32)
        if ws_ref is not None:
            w = w * ws_ref[...]                   # (1, n) channel scales
        contrib = jax.lax.dot_general(
            out, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(hh == 0)
        def _first_head():
            oacc_ref[...] = contrib

        @pl.when(hh != 0)
        def _accumulate():
            oacc_ref[...] += contrib

        @pl.when(hh == n_heads - 1)
        def _store_block():
            o_ref[0] = oacc_ref[...].astype(o_ref.dtype)

    _attention._flash_kernel(
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, red_ref,
        scale=scale, causal=causal, kv_offset=kv_offset, block_q=block_q,
        block_kv=block_kv, n_kv=n_kv, mode=mode,
        skip=(mode == "native" and causal), kv_len=kv_len, q_axis=1,
        kv_axis=3, epilogue=epilogue, pos_ref=pos_ref, skip_dead=paged,
        k_scale_ref=k_scale_ref, v_scale_ref=v_scale_ref)


@functools.partial(jax.jit, static_argnames=(
    "causal", "mode", "interpret", "block_q", "block_kv", "kv_offset",
    "plan_dialect", "tuning_op"))
def flash_attention_matmul(q: jax.Array, k: jax.Array, v: jax.Array,
                           w_out: jax.Array, *, causal: bool = True,
                           kv_offset: int | None = None,
                           mode: str = "native", interpret: bool = True,
                           block_q: int | None = None,
                           block_kv: int | None = None,
                           pos: jax.Array | None = None,
                           block_tables: jax.Array | None = None,
                           w_scale: jax.Array | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None,
                           plan_dialect: str | None = None,
                           tuning_op: str = "flash_attention_matmul"
                           ) -> jax.Array:
    """``flash_attention(q, k, v)`` -> ``wo`` projection in one kernel.

    q: [B,H,Sq,D]; k/v: [B,Hkv,Skv,D]; w_out: [H·D, N] -> [B,Sq,N].
    The grid is ``(batch, q-block, head, kv-block)`` with the head axis
    *sequential*: each head finishes its online softmax, projects the
    accumulator through its wo slice, and adds into a shared f32 VMEM
    accumulator (cast to the output dtype once, at the last head) — the
    `[B,S,H,D]` activation the unfused pair stages to HBM is never
    materialized.

    ``pos`` is the decode shape of the same op: per-sequence [B] int32
    cache frontiers (keys at columns > pos[b] masked), replacing the
    static causal triangle — how the serve tick, whose batch mixes slot
    positions, runs this fusion against the KV cache.  ``plan_dialect``
    (static) pins the tuned-table slice the trace binds.

    ``block_tables`` is the *paged* decode shape: k/v become page pools
    ``[P, Hkv, page_size, D]`` and ``block_tables`` a [B, max_pages]
    int32 table mapping each slot's logical kv blocks to pool pages
    (entries past the slot's reservation hold the sentinel ``P``).  The
    table rides as a scalar-prefetch operand so the sequential kv grid
    walks table entries instead of a contiguous strip, and a ``pl.when``
    on the ``pos`` frontier skips dead blocks entirely — the kernel only
    ever visits live pages.  Requires ``pos``; ``causal`` is ignored.

    ``w_scale`` ([N] f32, with ``w_out`` int8) selects the quantized
    weight prologue — the head's wo slice is dequantized per-channel in
    VMEM.  ``k_scale``/``v_scale`` (paged shape only: per-token scale
    pools ``[P, Hkv, page_size, 1]``, with int8 kv pools) select the
    int8-KV gather: pages are dequantized in VMEM after the block-table
    gather.  These are the ``flash_attention_matmul_q8`` registry rows;
    ``tuning_op`` (static) names the tuned-table row consulted so the
    quantized twin runs its own staging plans.
    """
    if block_tables is not None:
        return _paged_attention_matmul(
            q, k, v, w_out, block_tables=block_tables, pos=pos, mode=mode,
            interpret=interpret, block_q=block_q, w_scale=w_scale,
            k_scale=k_scale, v_scale=v_scale, plan_dialect=plan_dialect,
            tuning_op=tuning_op)
    if k_scale is not None or v_scale is not None:
        raise ValueError("int8 kv scales are a paged-shape operand; the "
                         "dense decode path dequantizes its cache strip "
                         "up front (models/attention.py)")
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    assert w_out.shape[0] == h * d, (w_out.shape, h, d)
    n = w_out.shape[1]
    if mode == "library":
        if w_scale is not None:
            w_out = dequantize_weight(w_out, w_scale, q.dtype)
        if pos is None:
            o = _ref.attention(q, k, v, causal=causal)
        else:
            # the unfused decode pair: masked softmax over the cache
            # frontier (models/attention.py::decode_attention), then wo
            k_r = jnp.repeat(k, group, axis=1).astype(jnp.float32)
            v_r = jnp.repeat(v, group, axis=1).astype(jnp.float32)
            s = jnp.einsum("bhqd,bhsd->bhqs", q.astype(jnp.float32),
                           k_r) * (d ** -0.5)
            valid = jnp.arange(skv)[None] <= pos[:, None]    # [B,Skv]
            s = jnp.where(valid[:, None, None], s, -1e30)
            o = jnp.einsum("bhqs,bhsd->bhqd", jax.nn.softmax(s, axis=-1),
                           v_r).astype(q.dtype)
        o = o.transpose(0, 2, 1, 3).reshape(b, sq, h * d)
        return jnp.einsum("bsh,hn->bsn", o, w_out.astype(o.dtype))
    if kv_offset is None:
        kv_offset = skv - sq
    scale = 1.0 / (d ** 0.5)
    causal = causal and pos is None
    block_q, block_kv = resolve_attention_matmul_blocks(
        mode, sq, skv, d, n, block_q, block_kv, plan_dialect,
        op=tuning_op)
    q_p = _attention._pad_seq(q, block_q)
    k_p = _attention._pad_seq(k, block_kv)
    v_p = _attention._pad_seq(v, block_kv)
    sqp, skvp = q_p.shape[2], k_p.shape[2]
    n_p = align_up(n, 128)
    w3 = w_out.reshape(h, d, n)
    if n_p != n:
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, n_p - n)))
    grid = (b, sqp // block_q, h, skvp // block_kv)

    params = None
    if mode == "native":
        params = CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary", "arbitrary"))

    in_specs = [
        pl.BlockSpec((1, 1, block_q, d),
                     lambda bb, qi, hh, ki: (bb, hh, qi, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda bb, qi, hh, ki, g=group: (bb, hh // g, ki, 0)),
        pl.BlockSpec((1, 1, block_kv, d),
                     lambda bb, qi, hh, ki, g=group: (bb, hh // g, ki, 0)),
        pl.BlockSpec((1, d, n_p), lambda bb, qi, hh, ki: (hh, 0, 0)),
    ]
    operands = [q_p, k_p, v_p, w3]
    if w_scale is not None:
        s2d = w_scale.reshape(1, n).astype(jnp.float32)
        if n_p != n:
            s2d = jnp.pad(s2d, ((0, 0), (0, n_p - n)))
        in_specs.append(pl.BlockSpec((1, n_p),
                                     lambda bb, qi, hh, ki: (0, 0)))
        operands.append(s2d)
    if pos is not None:
        in_specs.append(pl.BlockSpec((1, 1),
                                     lambda bb, qi, hh, ki: (bb, 0)))
        operands.append(pos.reshape(b, 1).astype(jnp.int32))

    out = pl.pallas_call(
        functools.partial(
            _flash_matmul_kernel, scale=scale, causal=causal,
            kv_offset=kv_offset, block_q=block_q, block_kv=block_kv,
            n_kv=grid[3], n_heads=h, kv_len=skv, mode=mode,
            has_pos=pos is not None, quant_w=w_scale is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, n_p),
                               lambda bb, qi, hh, ki: (bb, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sqp, n_p), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # m
            pltpu.VMEM((block_q, 1), jnp.float32),      # l
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
            pltpu.VMEM((block_q, LANES) if mode == "abstract"
                       else (8, LANES), jnp.float32),
            pltpu.VMEM((block_q, n_p), jnp.float32),    # cross-head acc
        ],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_flash_attention_matmul_{mode.replace('+', '_')}",
    )(*operands)
    return out[:, :sq, :n]


def _paged_attention_matmul(q, k_pages, v_pages, w_out, *, block_tables,
                            pos, mode: str, interpret: bool,
                            block_q: int | None,
                            w_scale=None, k_scale=None, v_scale=None,
                            plan_dialect: str | None = None,
                            tuning_op: str = "flash_attention_matmul"):
    """The paged decode lowering of ``flash_attention_matmul``.

    The kv grid dimension indexes *table entries*: the block table is a
    scalar-prefetch operand, so each kv step's index map gathers page
    ``block_tables[b, ki]`` straight out of the pool — no contiguous
    strip is ever materialized.  ``block_kv`` IS ``page_size``.  Sentinel
    entries clamp onto a real page whose contents the ``pos`` mask hides,
    and the ``skip_dead`` predicate in the shared flash kernel skips
    every block past the frontier before it computes anything.

    ``k_scale``/``v_scale`` ([P, Hkv, page_size, 1] f32 per-token scale
    pools, with int8 ``k_pages``/``v_pages``) ride the *same* block-table
    index maps as the value pools, so the gather stays one scalar-prefetch
    plan and dequantization happens in VMEM on the gathered page.
    """
    if pos is None:
        raise ValueError("paged flash_attention_matmul requires the "
                         "per-slot pos frontier")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("int8 paged kv needs BOTH k_scale and v_scale")
    b, h, sq, d = q.shape
    num_pages, hkv, page_size, _ = k_pages.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    assert w_out.shape[0] == h * d, (w_out.shape, h, d)
    n = w_out.shape[1]
    maxp = block_tables.shape[1]
    skv = maxp * page_size
    tbl = jnp.minimum(block_tables, num_pages - 1).astype(jnp.int32)
    if mode == "library":
        # the unfused pair: gather the logical strip, masked softmax over
        # the frontier, then wo — the dense decode library row applied to
        # the gathered pages (models/attention.py::gather_paged_kv math).
        # int8 pools dequantize at gather time (host-side reference).
        if k_scale is not None:
            k_pages = k_pages.astype(jnp.float32) * k_scale
            v_pages = v_pages.astype(jnp.float32) * v_scale

        def strip(pages):
            s = pages[tbl]                     # [B, maxp, Hkv, ps, D]
            return s.transpose(0, 2, 1, 3, 4).reshape(b, hkv, skv, d)
        return flash_attention_matmul(
            q, strip(k_pages).astype(q.dtype),
            strip(v_pages).astype(q.dtype), w_out, causal=False,
            mode="library", interpret=interpret, pos=pos,
            w_scale=w_scale, plan_dialect=plan_dialect)
    if page_size % LANES != 0 and mode != "native":
        raise ValueError(
            f"paged decode under mode={mode!r} needs page_size to be a "
            f"multiple of {LANES} (the abstract row reduces fold into "
            f"{LANES}-lane vregs); got page_size={page_size}")
    scale = 1.0 / (d ** 0.5)
    bq, _ = resolve_attention_matmul_blocks(mode, sq, skv, d, n, block_q,
                                            page_size, plan_dialect,
                                            op=tuning_op)
    q_p = _attention._pad_seq(q, bq)
    sqp = q_p.shape[2]
    n_p = align_up(n, 128)
    w3 = w_out.reshape(h, d, n)
    if n_p != n:
        w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, n_p - n)))
    grid = (b, sqp // bq, h, maxp)

    params = None
    if mode == "native":
        params = CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary", "arbitrary"))

    page_spec = pl.BlockSpec((1, 1, page_size, d),
                             lambda bb, qi, hh, ki, tr, g=group:
                             (tr[bb, ki], hh // g, 0, 0))
    # per-token scale pools ride the SAME table-gather index map as the
    # value pools — one scalar-prefetch plan covers both widths
    scale_spec = pl.BlockSpec((1, 1, page_size, 1),
                              lambda bb, qi, hh, ki, tr, g=group:
                              (tr[bb, ki], hh // g, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, bq, d),
                     lambda bb, qi, hh, ki, tr: (bb, hh, qi, 0)),
        page_spec,
    ]
    operands = [q_p, k_pages]
    if k_scale is not None:
        in_specs.append(scale_spec)
        operands.append(k_scale.astype(jnp.float32))
    in_specs.append(page_spec)
    operands.append(v_pages)
    if v_scale is not None:
        in_specs.append(scale_spec)
        operands.append(v_scale.astype(jnp.float32))
    in_specs.append(pl.BlockSpec((1, d, n_p),
                                 lambda bb, qi, hh, ki, tr: (hh, 0, 0)))
    operands.append(w3)
    if w_scale is not None:
        s2d = w_scale.reshape(1, n).astype(jnp.float32)
        if n_p != n:
            s2d = jnp.pad(s2d, ((0, 0), (0, n_p - n)))
        in_specs.append(pl.BlockSpec((1, n_p),
                                     lambda bb, qi, hh, ki, tr: (0, 0)))
        operands.append(s2d)
    in_specs.append(pl.BlockSpec((1, 1),
                                 lambda bb, qi, hh, ki, tr: (bb, 0)))
    operands.append(pos.reshape(b, 1).astype(jnp.int32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, n_p),
                               lambda bb, qi, hh, ki, tr: (bb, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),           # m
            pltpu.VMEM((bq, 1), jnp.float32),           # l
            pltpu.VMEM((bq, d), jnp.float32),           # acc
            pltpu.VMEM((bq, LANES) if mode == "abstract"
                       else (8, LANES), jnp.float32),
            pltpu.VMEM((bq, n_p), jnp.float32),         # cross-head acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _flash_matmul_kernel, scale=scale, causal=False, kv_offset=0,
            block_q=bq, block_kv=page_size, n_kv=maxp, n_heads=h,
            kv_len=None, mode=mode, has_pos=True, paged=True,
            quant_w=w_scale is not None, quant_kv=k_scale is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sqp, n_p), q.dtype),
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_paged_attention_matmul_{mode.replace('+', '_')}",
    )(tbl, *operands)
    return out[:, :sq, :n]


def structural_cost_flash_attention_matmul(
        b: int, h: int, sq: int, skv: int, d: int, n: int, causal: bool,
        mode: str, block_q=None, block_kv=None, dtype=jnp.float32,
        plan_dialect: str | None = None, page_size: int | None = None,
        pages_occupied: int | None = None,
        op: str = "flash_attention_matmul") -> dict:
    """The unfused pair's traffic minus exactly one ``[B,S,H,D]`` trip.

    Composes the registered ``flash_attention`` and ``gemm`` cost models
    (``m = B·S``, ``k = H·D``) and removes the write plus read-back of the
    attention output (``2·B·S·H·D·itemsize``) — the two legs of the
    staging the epilogue hook eliminates.  The kernel-describing columns
    (visited blocks, scratch traffic) come from attention's visited-block
    model evaluated at *this* lowering's resolved tiling.

    The paged decode shape (``page_size`` set) swaps the kv traffic term:
    the kernel gathers *pages* through the block table and the
    ``skip_dead`` predicate never visits a block past the frontier, so
    its kv bytes scale with ``pages_occupied`` (live pages across the
    batch; default ``b · ceil(skv / page_size)``, the fully-occupied
    worst case used for static auto-selection) — **not** with the
    ``max_len`` capacity a dense strip would stream."""
    itemsize = jnp.dtype(dtype).itemsize
    if page_size is not None:
        return _structural_cost_paged(
            b=b, h=h, sq=sq, skv=skv, d=d, n=n, mode=mode, block_q=block_q,
            dtype=dtype, plan_dialect=plan_dialect, page_size=page_size,
            pages_occupied=pages_occupied, op=op)
    if mode == "library":
        bq, bkv = 256, 256
    else:
        bq, bkv = resolve_attention_matmul_blocks(mode, sq, skv, d, n,
                                                  block_q, block_kv,
                                                  plan_dialect, op=op)
    # ONE attention evaluation at this lowering's resolved tiling: its
    # hbm term is block-independent (so the pair sum is unaffected) and
    # its flops/visited/scratch columns then all describe the same grid.
    att = _attention.structural_cost(
        b=b, h=h, sq=sq, skv=skv, d=d, causal=causal, mode=mode,
        block_q=bq, block_kv=bkv, dtype=dtype, plan_dialect=plan_dialect)
    g = _gemm.structural_cost(m=b * sq, n=n, k=h * d, mode=mode,
                              dtype=dtype, plan_dialect=plan_dialect)
    unfused = att["hbm_bytes"] + g["hbm_bytes"]
    saved = 0 if mode == "library" else 2 * b * sq * h * d * itemsize
    ws, _ = _weight_stream(b * sq, n, h * d, mode, dtype, plan_dialect)
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "weight_stream_bytes": ws,
        "flops": att["flops"] + g["flops"],
        "block": (bq, bkv),
        "blocks_visited": att["blocks_visited"],
        "skip_fraction": att["skip_fraction"],
        "scratch_round_trips_per_block":
            att["scratch_round_trips_per_block"],
        "scratch_bytes_total": att["scratch_bytes_total"],
        "lane_shuffles_per_block": att["lane_shuffles_per_block"],
        "fused_epilogue": mode != "library",
    }


def _structural_cost_paged(*, b: int, h: int, sq: int, skv: int, d: int,
                           n: int, mode: str, block_q, dtype,
                           plan_dialect: str | None, page_size: int,
                           pages_occupied: int | None,
                           op: str = "flash_attention_matmul") -> dict:
    """Occupied-page accounting for the paged decode shape.

    ``skv`` is the logical capacity (``max_pages · page_size``); the kv
    stream term reads ``pages_occupied · page_size`` rows because the
    table gather only touches live pages and ``skip_dead`` predication
    skips the rest at the grid level.  Capacity (``skv``) appears in
    ``blocks_total`` only — growing ``max_len`` with fixed occupancy
    leaves ``hbm_bytes`` unchanged, which is the whole point of paging.
    """
    itemsize = jnp.dtype(dtype).itemsize
    maxp = -(-skv // page_size)
    total_pages = b * maxp
    if pages_occupied is None:
        pages_occupied = total_pages
    pages_occupied = min(pages_occupied, total_pages)
    if mode == "library":
        bq = 256
    else:
        bq, _ = resolve_attention_matmul_blocks(mode, sq, skv, d, n,
                                                block_q, page_size,
                                                plan_dialect, op=op)
    visited = h * pages_occupied        # every head walks live pages only
    reduces_per_block = 2               # row-max + row-sum
    if mode == "abstract":
        round_trips = reduces_per_block * tree_stages(LANES)
        scratch_bytes = (visited * reduces_per_block *
                         scratch_tree_bytes(LANES, rows=bq))
        shuffles = 0
    elif mode == "abstract+shuffle":
        round_trips, scratch_bytes = 0, 0
        shuffles = reduces_per_block * tree_stages(LANES)
    else:                               # native / library
        round_trips, scratch_bytes, shuffles = 0, 0, 0
    kv_stream = 2 * h * d * pages_occupied * page_size * itemsize
    att_hbm = h * d * 2 * b * sq * itemsize + kv_stream
    g = _gemm.structural_cost(m=b * sq, n=n, k=h * d, mode=mode,
                              dtype=dtype, plan_dialect=plan_dialect)
    unfused = att_hbm + g["hbm_bytes"]
    saved = 0 if mode == "library" else 2 * b * sq * h * d * itemsize
    ws, _ = _weight_stream(b * sq, n, h * d, mode, dtype, plan_dialect)
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "weight_stream_bytes": ws,
        "kv_stream_bytes": kv_stream,
        "flops": visited * 4 * bq * page_size * d + g["flops"],
        "block": (bq, page_size),
        "blocks_visited": visited,
        "blocks_total": h * total_pages,
        "skip_fraction": 1.0 - pages_occupied / total_pages,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": shuffles,
        "fused_epilogue": mode != "library",
        "page_size": page_size,
        "pages_occupied": pages_occupied,
    }


def structural_cost_flash_attention_matmul_q8(
        b: int, h: int, sq: int, skv: int, d: int, n: int, causal: bool,
        mode: str, block_q=None, block_kv=None, dtype=jnp.float32,
        plan_dialect: str | None = None, page_size: int | None = None,
        pages_occupied: int | None = None) -> dict:
    """The f32 model with the weight stream (and, on the paged shape, the
    kv stream) re-priced at int8 width: int8 values plus the f32 scale
    sideband (per-channel for wo, per-token for kv pages) replace each
    f32 stream, and the delta comes off both the fused bytes and the
    unfused pair — the saving is a *stream width* effect, orthogonal to
    what fusion saves."""
    base = structural_cost_flash_attention_matmul(
        b, h, sq, skv, d, n, causal, mode, block_q, block_kv, dtype,
        plan_dialect, page_size, pages_occupied,
        op="flash_attention_matmul_q8")
    ws_f32, rereads = _weight_stream(b * sq, n, h * d, mode, dtype,
                                     plan_dialect)
    ws_q8 = _q8_weight_stream(rereads, h * d, n)
    delta = ws_f32 - ws_q8
    if page_size is not None:
        # int8 page rows: 2·d value bytes + two f32 per-token scales
        kv_q8 = (h * base["pages_occupied"] * page_size * (2 * d + 8))
        delta += base["kv_stream_bytes"] - kv_q8
        base["kv_stream_bytes"] = kv_q8
        base["kv_precision"] = "int8"
    base.update(
        hbm_bytes=base["hbm_bytes"] - delta,
        hbm_bytes_unfused_pair=base["hbm_bytes_unfused_pair"] - delta,
        weight_stream_bytes=ws_q8,
        weight_stream_bytes_f32=ws_f32,
        weight_precision="int8",
    )
    return base


# --------------------------------------------------------------------------
# rmsnorm -> [wi|wg] swiglu: the norm as prologue, the silu gate as epilogue
# --------------------------------------------------------------------------


def resolve_swiglu_blocks(mode: str, rows: int, d: int, f: int,
                          dtype=jnp.float32,
                          plan_dialect: str | None = None,
                          op: str = "rmsnorm_swiglu"):
    """The (bm, bn) tile over ``rows × f``: this op's tuned entry first
    (its working set holds *two* weight tiles plus the hi/hg/out trio),
    then the shared GEMM heuristic.  Shared by kernel and cost;
    ``plan_dialect`` names the table slice consulted; ``op`` names the
    table row — the quantized twin tunes its own staging."""
    entry = tuned_entry(op, mode, swiglu_bucket(rows, d, f),
                        dialect=plan_dialect)
    if entry and "block" in entry:
        bm, bn = entry["block"]
        return int(bm), int(bn)
    bm, bn, _ = _gemm.block_shape_for(mode, rows, f, d, dtype, plan_dialect)
    return bm, bn


def _rmsnorm_swiglu_kernel(*refs, eps: float, mode: str, d_true: int,
                           quant: bool = False):
    # operands: x, w, wi, wg, [si, sg] — the scale rows ride only the
    # quantized rows and dequantize the int8 weight tiles in VMEM
    if quant:
        (x_ref, w_ref, wi_ref, wg_ref, si_ref, sg_ref, o_ref,
         scratch_ref) = refs
    else:
        x_ref, w_ref, wi_ref, wg_ref, o_ref, scratch_ref = refs
        si_ref = sg_ref = None
    x = x_ref[...].astype(jnp.float32)                    # (bm, d)
    w = w_ref[...].astype(jnp.float32)                    # (1, d)
    y = _rmsnorm.normalize_block(x, w, scratch_ref, eps=eps, mode=mode,
                                 d_true=d_true)
    # both halves of the concatenated [wi|wg] weight consume the
    # normalized block from VMEM; the silu gate runs in the epilogue on
    # products that never left the core.
    wi = wi_ref[...].astype(jnp.float32)
    wg = wg_ref[...].astype(jnp.float32)
    if si_ref is not None:
        wi = wi * si_ref[...]                             # (1, bn) scales
        wg = wg * sg_ref[...]
    hi = jax.lax.dot_general(
        y, wi, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    hg = jax.lax.dot_general(
        y, wg, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] = (jax.nn.silu(hg) * hi).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect", "tuning_op"))
def rmsnorm_swiglu(x: jax.Array, weight: jax.Array, w_cat: jax.Array, *,
                   eps: float = 1e-6, mode: str = "native",
                   interpret: bool = True,
                   w_scale: jax.Array | None = None,
                   plan_dialect: str | None = None,
                   tuning_op: str = "rmsnorm_swiglu") -> jax.Array:
    """``silu(y @ wg) * (y @ wi)`` with ``y = rmsnorm(x, weight)``, fused.

    x: [..., D]; weight: [D]; w_cat: [D, 2F] — the concatenated
    ``[wi|wg]`` weight (wi the first F columns, wg the last) -> [..., F].
    One call per sublayer: the residual is read and the moment computed
    once, the normalized activation and both projection products stay in
    VMEM.

    ``w_scale`` ([2F] f32, with ``w_cat`` int8) selects the quantized
    weight prologue: both int8 weight tiles dequantize per-channel in
    VMEM — the ``rmsnorm_swiglu_q8`` registry rows.  ``tuning_op``
    (static) names the tuned-table row consulted.
    """
    *lead, d = x.shape
    assert w_cat.shape[0] == d and w_cat.shape[1] % 2 == 0, \
        (x.shape, w_cat.shape)
    f = w_cat.shape[1] // 2
    if mode == "library":
        if w_scale is not None:
            w_cat = dequantize_weight(w_cat, w_scale, x.dtype)
        y = _ref.rmsnorm(x, weight, eps)
        hi = jnp.einsum("...d,df->...f", y, w_cat[:, :f].astype(y.dtype))
        hg = jnp.einsum("...d,df->...f", y, w_cat[:, f:].astype(y.dtype))
        return jax.nn.silu(hg) * hi
    rows = 1
    for s in lead:
        rows *= s
    x2d = x.reshape(rows, d)
    w2d = weight.reshape(1, d)
    wi2d, wg2d = w_cat[:, :f], w_cat[:, f:]
    si2d = sg2d = None
    if w_scale is not None:
        si2d = w_scale[:f].reshape(1, f).astype(jnp.float32)
        sg2d = w_scale[f:].reshape(1, f).astype(jnp.float32)

    d_padded = d
    if mode != "native":
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))
            wi2d = jnp.pad(wi2d, ((0, pad_d), (0, 0)))
            wg2d = jnp.pad(wg2d, ((0, pad_d), (0, 0)))

    bm, bn = resolve_swiglu_blocks(mode, rows, d, f, x.dtype, plan_dialect,
                                   op=tuning_op)
    bm = min(bm, align_up(rows, 128))
    bn = min(bn, align_up(f, 128))
    pad_m = (-rows) % bm
    pad_n = (-f) % bn
    if pad_m:
        x2d = jnp.pad(x2d, ((0, pad_m), (0, 0)))
    if pad_n:
        wi2d = jnp.pad(wi2d, ((0, 0), (0, pad_n)))
        wg2d = jnp.pad(wg2d, ((0, 0), (0, pad_n)))
        if si2d is not None:
            si2d = jnp.pad(si2d, ((0, 0), (0, pad_n)))
            sg2d = jnp.pad(sg2d, ((0, 0), (0, pad_n)))
    mp, fp = rows + pad_m, f + pad_n
    grid = (mp // bm, fp // bn)

    params = None
    if mode == "native":
        params = CompilerParams(
            dimension_semantics=("parallel", "parallel"))

    in_specs = [
        pl.BlockSpec((bm, d_padded), lambda i, j: (i, 0)),
        pl.BlockSpec((1, d_padded), lambda i, j: (0, 0)),
        pl.BlockSpec((d_padded, bn), lambda i, j: (0, j)),
        pl.BlockSpec((d_padded, bn), lambda i, j: (0, j)),
    ]
    operands = [x2d, w2d, wi2d, wg2d]
    if si2d is not None:
        in_specs += [pl.BlockSpec((1, bn), lambda i, j: (0, j)),
                     pl.BlockSpec((1, bn), lambda i, j: (0, j))]
        operands += [si2d, sg2d]
    kernel_name = ("uisa_rmsnorm_swiglu_q8_" if si2d is not None
                   else "uisa_rmsnorm_swiglu_") + mode.replace('+', '_')
    out = pl.pallas_call(
        functools.partial(_rmsnorm_swiglu_kernel, eps=eps, mode=mode,
                          d_true=d, quant=si2d is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, fp), x.dtype),
        scratch_shapes=[pltpu.VMEM(
            (bm, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=kernel_name,
    )(*operands)
    return out[:rows, :f].reshape(*lead, f)


def structural_cost_rmsnorm_swiglu(rows: int, d: int, f: int, mode: str,
                                   dtype=jnp.float32,
                                   plan_dialect: str | None = None,
                                   op: str = "rmsnorm_swiglu") -> dict:
    """The unfused pair's traffic minus exactly one activation round trip.

    The pair is ``rmsnorm`` + one GEMM against the concatenated
    ``[D, 2F]`` weight; the fused lowering removes the normalized
    activation's write + read-back (``2 · rows · d · itemsize``) —
    claimed conservatively: the hi/hg products the epilogue gate consumes
    also never stage, but only the norm round trip is pinned."""
    itemsize = jnp.dtype(dtype).itemsize
    g = _gemm.structural_cost(m=rows, n=2 * f, k=d, mode=mode, dtype=dtype,
                              plan_dialect=plan_dialect)
    r = _rmsnorm.structural_cost(rows=rows, d=d, mode=mode, dtype=dtype,
                                 plan_dialect=plan_dialect)
    unfused = g["hbm_bytes"] + r["hbm_bytes"]
    saved = 0 if mode == "library" else 2 * rows * d * itemsize
    if mode == "library":
        bm = bn = 512
    else:
        bm, bn = resolve_swiglu_blocks(mode, rows, d, f, dtype,
                                       plan_dialect, op=op)
        bm = min(bm, align_up(rows, 128))
        bn = min(bn, align_up(f, 128))
    steps = -(-rows // bm) * -(-f // bn)
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = steps * (scratch_tree_bytes(LANES, rows=bm)
                                 + 3 * bm * 4)
    else:
        round_trips = 0
        scratch_bytes = 0
    ws, _ = _weight_stream(rows, 2 * f, d, mode, dtype, plan_dialect)
    return {
        "hbm_bytes": unfused - saved,
        "hbm_bytes_unfused_pair": unfused,
        "hbm_bytes_saved": saved,
        "weight_stream_bytes": ws,
        "flops": g["flops"],
        "block": (bm, bn),
        "blocks": steps,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "fused_epilogue": mode != "library",
    }


def structural_cost_rmsnorm_swiglu_q8(rows: int, d: int, f: int, mode: str,
                                      dtype=jnp.float32,
                                      plan_dialect: str | None = None
                                      ) -> dict:
    """The f32 model with the ``[wi|wg]`` stream re-priced at int8 width
    (int8 tiles + one f32 per-channel scale row), off both the fused
    bytes and the unfused pair."""
    base = structural_cost_rmsnorm_swiglu(rows, d, f, mode, dtype,
                                          plan_dialect,
                                          op="rmsnorm_swiglu_q8")
    ws_f32, rereads = _weight_stream(rows, 2 * f, d, mode, dtype,
                                     plan_dialect)
    ws_q8 = _q8_weight_stream(rereads, d, 2 * f)
    delta = ws_f32 - ws_q8
    base.update(
        hbm_bytes=base["hbm_bytes"] - delta,
        hbm_bytes_unfused_pair=base["hbm_bytes_unfused_pair"] - delta,
        weight_stream_bytes=ws_q8,
        weight_stream_bytes_f32=ws_f32,
        weight_precision="int8",
    )
    return base


# --------------------------------------------------------------------------
# Library rows: the unfused jnp pairs (numerical reference AND the declared
# fallback target — requesting an illegal fused mode degrades to the pair
# with a warning + a recorded event, never silently).
# --------------------------------------------------------------------------


def _rmsnorm_matmul_library(x, weight, w_proj, *, eps: float = 1e-6,
                            interpret: bool = True,
                            plan_dialect: str | None = None):
    del interpret, plan_dialect
    return rmsnorm_matmul(x, weight, w_proj, eps=eps, mode="library")


def _add_rmsnorm_library(x, residual, weight, *, eps: float = 1e-6,
                         interpret: bool = True,
                         plan_dialect: str | None = None):
    del interpret, plan_dialect
    return add_rmsnorm(x, residual, weight, eps=eps, mode="library")


def _flash_attention_matmul_library(q, k, v, w_out, *, causal: bool = True,
                                    kv_offset=None, interpret: bool = True,
                                    block_q=None, block_kv=None, pos=None,
                                    block_tables=None,
                                    plan_dialect: str | None = None):
    # library: XLA decides every staging parameter
    del kv_offset, interpret, block_q, block_kv, plan_dialect
    return flash_attention_matmul(q, k, v, w_out, causal=causal,
                                  mode="library", pos=pos,
                                  block_tables=block_tables)


def _rmsnorm_swiglu_library(x, weight, w_cat, *, eps: float = 1e-6,
                            interpret: bool = True,
                            plan_dialect: str | None = None):
    del interpret, plan_dialect
    return rmsnorm_swiglu(x, weight, w_cat, eps=eps, mode="library")


# --------------------------------------------------------------------------
# Quantized twins: the SAME fused bodies behind int8 weight prologues.
# Each accepts pre-quantized operands (int8 + per-channel f32 scale, the
# checkpoint's stored form) or, with ``w_scale=None``, f32 weights it
# quantizes on the fly — so ``REGISTRY.select`` under an int8 precision
# policy can retarget a call site that still holds f32 operands.
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect"))
def rmsnorm_matmul_q8(x: jax.Array, weight: jax.Array, w_proj: jax.Array,
                      *, eps: float = 1e-6, mode: str = "native",
                      interpret: bool = True,
                      w_scale: jax.Array | None = None,
                      plan_dialect: str | None = None) -> jax.Array:
    if w_scale is None:
        w_proj, w_scale = quantize_weight(w_proj)
    return rmsnorm_matmul(x, weight, w_proj, eps=eps, mode=mode,
                          interpret=interpret, w_scale=w_scale,
                          plan_dialect=plan_dialect)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect"))
def rmsnorm_swiglu_q8(x: jax.Array, weight: jax.Array, w_cat: jax.Array,
                      *, eps: float = 1e-6, mode: str = "native",
                      interpret: bool = True,
                      w_scale: jax.Array | None = None,
                      plan_dialect: str | None = None) -> jax.Array:
    if w_scale is None:
        w_cat, w_scale = quantize_weight(w_cat)
    return rmsnorm_swiglu(x, weight, w_cat, eps=eps, mode=mode,
                          interpret=interpret, w_scale=w_scale,
                          plan_dialect=plan_dialect,
                          tuning_op="rmsnorm_swiglu_q8")


@functools.partial(jax.jit, static_argnames=(
    "causal", "mode", "interpret", "block_q", "block_kv", "kv_offset",
    "plan_dialect"))
def flash_attention_matmul_q8(q: jax.Array, k: jax.Array, v: jax.Array,
                              w_out: jax.Array, *, causal: bool = True,
                              kv_offset: int | None = None,
                              mode: str = "native", interpret: bool = True,
                              block_q: int | None = None,
                              block_kv: int | None = None,
                              pos: jax.Array | None = None,
                              block_tables: jax.Array | None = None,
                              w_scale: jax.Array | None = None,
                              k_scale: jax.Array | None = None,
                              v_scale: jax.Array | None = None,
                              plan_dialect: str | None = None
                              ) -> jax.Array:
    if w_scale is None:
        w_out, w_scale = quantize_weight(w_out)
    return flash_attention_matmul(
        q, k, v, w_out, causal=causal, kv_offset=kv_offset, mode=mode,
        interpret=interpret, block_q=block_q, block_kv=block_kv, pos=pos,
        block_tables=block_tables, w_scale=w_scale, k_scale=k_scale,
        v_scale=v_scale, plan_dialect=plan_dialect,
        tuning_op="flash_attention_matmul_q8")


def _rmsnorm_matmul_q8_library(x, weight, w_proj, *, eps: float = 1e-6,
                               interpret: bool = True, w_scale=None,
                               plan_dialect: str | None = None):
    del interpret, plan_dialect
    return rmsnorm_matmul_q8(x, weight, w_proj, eps=eps, mode="library",
                             w_scale=w_scale)


def _rmsnorm_swiglu_q8_library(x, weight, w_cat, *, eps: float = 1e-6,
                               interpret: bool = True, w_scale=None,
                               plan_dialect: str | None = None):
    del interpret, plan_dialect
    return rmsnorm_swiglu_q8(x, weight, w_cat, eps=eps, mode="library",
                             w_scale=w_scale)


def _flash_attention_matmul_q8_library(q, k, v, w_out, *,
                                       causal: bool = True, kv_offset=None,
                                       interpret: bool = True, block_q=None,
                                       block_kv=None, pos=None,
                                       block_tables=None, w_scale=None,
                                       k_scale=None, v_scale=None,
                                       plan_dialect: str | None = None):
    del kv_offset, interpret, block_q, block_kv, plan_dialect
    return flash_attention_matmul_q8(
        q, k, v, w_out, causal=causal, mode="library", pos=pos,
        block_tables=block_tables, w_scale=w_scale, k_scale=k_scale,
        v_scale=v_scale)


for _mode, _contract in (("abstract", _RM_ABSTRACT),
                         ("abstract+shuffle", _RM_SHUFFLE),
                         ("native", _RM_NATIVE)):
    REGISTRY.register(
        "rmsnorm_matmul", _mode,
        functools.partial(rmsnorm_matmul, mode=_mode), contract=_contract,
        cost=functools.partial(structural_cost_rmsnorm_matmul, mode=_mode))
REGISTRY.register(
    "rmsnorm_matmul", IsaMode.LIBRARY, _rmsnorm_matmul_library,
    cost=functools.partial(structural_cost_rmsnorm_matmul, mode="library"))

for _mode, _contract in (("abstract", _AR_ABSTRACT),
                         ("abstract+shuffle", _AR_SHUFFLE),
                         ("native", _AR_NATIVE)):
    REGISTRY.register(
        "add_rmsnorm", _mode,
        functools.partial(add_rmsnorm, mode=_mode), contract=_contract,
        cost=functools.partial(structural_cost_add_rmsnorm, mode=_mode))
REGISTRY.register(
    "add_rmsnorm", IsaMode.LIBRARY, _add_rmsnorm_library,
    cost=functools.partial(structural_cost_add_rmsnorm, mode="library"))

for _mode, _contract in (("abstract", _FA_ABSTRACT),
                         ("abstract+shuffle", _FA_SHUFFLE),
                         ("native", _FA_NATIVE)):
    REGISTRY.register(
        "flash_attention_matmul", _mode,
        functools.partial(flash_attention_matmul, mode=_mode),
        contract=_contract,
        cost=functools.partial(structural_cost_flash_attention_matmul,
                               mode=_mode))
REGISTRY.register(
    "flash_attention_matmul", IsaMode.LIBRARY,
    _flash_attention_matmul_library,
    cost=functools.partial(structural_cost_flash_attention_matmul,
                           mode="library"))

for _mode, _contract in (("abstract", _SW_ABSTRACT),
                         ("abstract+shuffle", _SW_SHUFFLE),
                         ("native", _SW_NATIVE)):
    REGISTRY.register(
        "rmsnorm_swiglu", _mode,
        functools.partial(rmsnorm_swiglu, mode=_mode), contract=_contract,
        cost=functools.partial(structural_cost_rmsnorm_swiglu, mode=_mode))
REGISTRY.register(
    "rmsnorm_swiglu", IsaMode.LIBRARY, _rmsnorm_swiglu_library,
    cost=functools.partial(structural_cost_rmsnorm_swiglu, mode="library"))

# Declared per-mode fallbacks (warned + recorded in fallback_events):
# the shuffle moment tree degrades to scratch round-trips on a no-shuffle
# dialect; the target-pinned native epilogue degrades to the unfused XLA
# pair (the library row) anywhere it is illegal.
for _op in FUSED_OPS:
    REGISTRY.declare_fallback(
        _op, IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
        reason="no lane shuffle on this dialect; the cross-lane reduction "
               "degrades to the scratch-tree lowering")
    REGISTRY.declare_fallback(
        _op, IsaMode.NATIVE, IsaMode.LIBRARY,
        reason="fused native epilogue is target-pinned; the unfused XLA "
               "pair is the declared escape")

# Quantized rows: same mode grid, q8 contracts, q8 cost models.  Their
# cost dicts re-price the weight (and paged-kv) streams at int8 width,
# so auto-selection sees the traffic cut before anything runs.
for _mode, _contract in (("abstract", _RMQ_ABSTRACT),
                         ("abstract+shuffle", _RMQ_SHUFFLE),
                         ("native", _RMQ_NATIVE)):
    REGISTRY.register(
        "rmsnorm_matmul_q8", _mode,
        functools.partial(rmsnorm_matmul_q8, mode=_mode),
        contract=_contract,
        cost=functools.partial(structural_cost_rmsnorm_matmul_q8,
                               mode=_mode))
REGISTRY.register(
    "rmsnorm_matmul_q8", IsaMode.LIBRARY, _rmsnorm_matmul_q8_library,
    cost=functools.partial(structural_cost_rmsnorm_matmul_q8,
                           mode="library"))

for _mode, _contract in (("abstract", _FAQ_ABSTRACT),
                         ("abstract+shuffle", _FAQ_SHUFFLE),
                         ("native", _FAQ_NATIVE)):
    REGISTRY.register(
        "flash_attention_matmul_q8", _mode,
        functools.partial(flash_attention_matmul_q8, mode=_mode),
        contract=_contract,
        cost=functools.partial(structural_cost_flash_attention_matmul_q8,
                               mode=_mode))
REGISTRY.register(
    "flash_attention_matmul_q8", IsaMode.LIBRARY,
    _flash_attention_matmul_q8_library,
    cost=functools.partial(structural_cost_flash_attention_matmul_q8,
                           mode="library"))

for _mode, _contract in (("abstract", _SWQ_ABSTRACT),
                         ("abstract+shuffle", _SWQ_SHUFFLE),
                         ("native", _SWQ_NATIVE)):
    REGISTRY.register(
        "rmsnorm_swiglu_q8", _mode,
        functools.partial(rmsnorm_swiglu_q8, mode=_mode),
        contract=_contract,
        cost=functools.partial(structural_cost_rmsnorm_swiglu_q8,
                               mode=_mode))
REGISTRY.register(
    "rmsnorm_swiglu_q8", IsaMode.LIBRARY, _rmsnorm_swiglu_q8_library,
    cost=functools.partial(structural_cost_rmsnorm_swiglu_q8,
                           mode="library"))

for _op in QUANT_OPS:
    REGISTRY.declare_fallback(
        _op, IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
        reason="no lane shuffle on this dialect; the cross-lane reduction "
               "degrades to the scratch-tree lowering")
    REGISTRY.declare_fallback(
        _op, IsaMode.NATIVE, IsaMode.LIBRARY,
        reason="fused native epilogue is target-pinned; the unfused XLA "
               "pair (dequantize, then the pair) is the declared escape")

# the precision axis: ExecutionPolicy(precision="int8") retargets the f32
# op names at select() time — call sites never spell the q8 names.
for _base in ("rmsnorm_matmul", "rmsnorm_swiglu", "flash_attention_matmul"):
    REGISTRY.register_precision_variant(_base, "int8", _base + "_q8")

FUSED_OPS = FUSED_OPS + QUANT_OPS

# the fused chunked SSD scan (ISSUE 8) registers itself on import; pulling
# it in here keeps FUSED_OPS authoritative for every consumer regardless
# of import order (kernels/ssd.py depends only on repro.core — no cycle).
from repro.kernels import ssd as _ssd  # noqa: E402,F401

FUSED_OPS = FUSED_OPS + ("ssd_scan", "ssd_decode")
