"""Tensor-parallel twin lowerings — the mesh-aware rows of the registry.

ISSUE 10 extends the execution-policy cost model below the chip edge.
Each fused projection op gets a ``_tp`` twin registered as its own op:
same program structure (GSPMD owns the actual sharding — the twin rows
change the *cost model*, not the kernel), but the structural cost prices
the sharded execution:

- the weight stream is divided across the tensor-parallel axis (each
  device re-reads only its ``1/T`` slice of the projection weight), and
- a :class:`repro.core.dialect.CollectiveCost` term is added — the
  all-gather (column-parallel) or all-reduce (row-parallel) the sharded
  projection pays, converted to HBM-equivalent bytes through the
  dialect's interconnect profile so it competes in :func:`cost_key`
  directly against the saved weight traffic.

``REGISTRY.register_collective_variant`` wires each pair; under
``mode="auto"`` with a model axis in the ambient mesh
(:func:`repro.core.registry.use_mesh_axes` or an active ``jax.Mesh``),
the twin's variants join the base op's candidate set and win exactly
when ``saved weight bytes > collective HBM-equivalent bytes`` — small
meshes with decode-shaped GEMMs pick TP-fused, large meshes (more hops,
thinner shards) fall back to replicated.  Partitioning choices:

- ``gemm_tp`` / ``rmsnorm_matmul_tp`` / ``rmsnorm_swiglu_tp``:
  column-parallel — the ``[K, N]`` weight shards over ``N``, each device
  produces an output column slice, one **all-gather** of the output.
- ``flash_attention_matmul_tp``: row-parallel — heads (and the ``wo``
  rows they feed) shard over the axis, each device holds a partial
  ``[rows, N]`` sum, one **all-reduce** of the output.
"""
from __future__ import annotations

import dataclasses
import functools

import jax.numpy as jnp

from repro.core import IsaMode, REGISTRY
from repro.core.dialect import TARGET, collective_cost, get_dialect
from repro.core.registry import tp_axis_size
from repro.kernels import fused as _fused
from repro.kernels import gemm as _gemm

#: the tensor-parallel twins this module registers (base name + "_tp")
TP_OPS = ("gemm_tp", "rmsnorm_matmul_tp", "rmsnorm_swiglu_tp",
          "flash_attention_matmul_tp")


def _resolve_tp(tp) -> int:
    """Explicit ``tp=`` wins; None reads the ambient mesh's model axis."""
    if tp is None:
        tp = tp_axis_size()
    return max(1, int(tp))


def _apply_tp(cost: dict, *, kind: str, payload_bytes: int, tp: int,
              ws_full: int, ws_shard: int,
              plan_dialect: str | None) -> dict:
    """Re-price a base cost dict for the sharded execution.

    The weight-stream delta comes off ``hbm_bytes`` *and*
    ``hbm_bytes_unfused_pair`` (both sides of the pair would shard the
    same weight), preserving the ``hbm == pair - saved`` identity that
    validate_contracts pins for the fused ops; the collective term lands
    in the ``collective_*`` columns that :func:`cost_key` folds into the
    bandwidth rank."""
    delta = max(0, ws_full - ws_shard)
    cost["hbm_bytes"] = cost["hbm_bytes"] - delta
    if "hbm_bytes_unfused_pair" in cost:
        cost["hbm_bytes_unfused_pair"] -= delta
    if "weight_stream_bytes" in cost:
        cost["weight_stream_bytes"] = ws_shard
    dialect = TARGET if plan_dialect is None else get_dialect(plan_dialect)
    cost.update(collective_cost(kind, payload_bytes, tp,
                                dialect).cost_keys())
    cost["tp_axis"] = tp
    return cost


def structural_cost_gemm_tp(m: int, n: int, k: int, mode: str,
                            dtype=jnp.float32,
                            plan_dialect: str | None = None,
                            tp: int | None = None) -> dict:
    """Column-parallel GEMM: ``[K, N]`` shards over N, all-gather of C."""
    tp = _resolve_tp(tp)
    cost = dict(_gemm.structural_cost(m=m, n=n, k=k, mode=mode,
                                      dtype=dtype,
                                      plan_dialect=plan_dialect))
    itemsize = jnp.dtype(dtype).itemsize
    ws_full, rereads = _fused._weight_stream(m, n, k, mode, dtype,
                                             plan_dialect)
    ws_shard = k * -(-n // tp) * itemsize * rereads
    return _apply_tp(cost, kind="all_gather",
                     payload_bytes=m * n * itemsize, tp=tp,
                     ws_full=ws_full, ws_shard=ws_shard,
                     plan_dialect=plan_dialect)


def structural_cost_rmsnorm_matmul_tp(rows: int, d: int, n: int, mode: str,
                                      dtype=jnp.float32,
                                      plan_dialect: str | None = None,
                                      tp: int | None = None) -> dict:
    """Column-parallel fused norm+projection: all-gather of [rows, N]."""
    tp = _resolve_tp(tp)
    cost = dict(_fused.structural_cost_rmsnorm_matmul(
        rows, d, n, mode, dtype=dtype, plan_dialect=plan_dialect))
    itemsize = jnp.dtype(dtype).itemsize
    _, rereads = _fused._weight_stream(rows, n, d, mode, dtype,
                                       plan_dialect)
    ws_shard = d * -(-n // tp) * itemsize * rereads
    return _apply_tp(cost, kind="all_gather",
                     payload_bytes=rows * n * itemsize, tp=tp,
                     ws_full=cost["weight_stream_bytes"],
                     ws_shard=ws_shard, plan_dialect=plan_dialect)


def structural_cost_rmsnorm_swiglu_tp(rows: int, d: int, f: int, mode: str,
                                      dtype=jnp.float32,
                                      plan_dialect: str | None = None,
                                      tp: int | None = None) -> dict:
    """Column-parallel fused norm+SwiGLU: the ``[D, 2F]`` concat shards
    over F (each device keeps matched wi/wg column slices, so the gate
    stays local); all-gather of the gated ``[rows, F]`` output."""
    tp = _resolve_tp(tp)
    cost = dict(_fused.structural_cost_rmsnorm_swiglu(
        rows, d, f, mode, dtype=dtype, plan_dialect=plan_dialect))
    itemsize = jnp.dtype(dtype).itemsize
    _, rereads = _fused._weight_stream(rows, 2 * f, d, mode, dtype,
                                       plan_dialect)
    ws_shard = d * -(-(2 * f) // tp) * itemsize * rereads
    return _apply_tp(cost, kind="all_gather",
                     payload_bytes=rows * f * itemsize, tp=tp,
                     ws_full=cost["weight_stream_bytes"],
                     ws_shard=ws_shard, plan_dialect=plan_dialect)


def structural_cost_flash_attention_matmul_tp(
        b: int, h: int, sq: int, skv: int, d: int, n: int, causal: bool,
        mode: str, block_q=None, block_kv=None, dtype=jnp.float32,
        plan_dialect: str | None = None, page_size: int | None = None,
        pages_occupied: int | None = None,
        tp: int | None = None) -> dict:
    """Row-parallel fused attention+projection: heads (and the ``wo``
    rows they feed) shard over the axis, all-reduce of the partial
    ``[B·Sq, N]`` outputs.  Only the weight-stream shard is claimed (the
    per-device kv stream also shrinks with heads, but that saving is not
    pinned — same conservatism as the fused ops' ``hbm_bytes_saved``)."""
    tp = _resolve_tp(tp)
    cost = dict(_fused.structural_cost_flash_attention_matmul(
        b, h, sq, skv, d, n, causal, mode, block_q=block_q,
        block_kv=block_kv, dtype=dtype, plan_dialect=plan_dialect,
        page_size=page_size, pages_occupied=pages_occupied))
    itemsize = jnp.dtype(dtype).itemsize
    _, rereads = _fused._weight_stream(b * sq, n, h * d, mode, dtype,
                                       plan_dialect)
    ws_shard = -(-(h * d) // tp) * n * itemsize * rereads
    return _apply_tp(cost, kind="all_reduce",
                     payload_bytes=b * sq * n * itemsize, tp=tp,
                     ws_full=cost["weight_stream_bytes"],
                     ws_shard=ws_shard, plan_dialect=plan_dialect)


TP_COSTS = {
    "gemm_tp": structural_cost_gemm_tp,
    "rmsnorm_matmul_tp": structural_cost_rmsnorm_matmul_tp,
    "rmsnorm_swiglu_tp": structural_cost_rmsnorm_swiglu_tp,
    "flash_attention_matmul_tp": structural_cost_flash_attention_matmul_tp,
}

# --------------------------------------------------------------------------
# Registration: each twin reuses the base lowering's impl (in this repo's
# interpret/modeled setting GSPMD does the physical distribution — see the
# subprocess mesh test) under a contract re-keyed to the twin name, with
# the TP cost model above.  Fallback declarations mirror the base op's.
# --------------------------------------------------------------------------

for _twin, _cost_fn in TP_COSTS.items():
    _base = _twin[:-len("_tp")]
    for _mode_s in REGISTRY.modes(_base):
        _mode = IsaMode(_mode_s)
        _low = REGISTRY.variant(_base, _mode)
        _contract = (None if _mode is IsaMode.LIBRARY else
                     dataclasses.replace(_low.contract, kernel=_twin))
        REGISTRY.register(_twin, _mode, _low.impl, contract=_contract,
                          cost=functools.partial(_cost_fn, mode=_mode_s))
    for _missing in IsaMode:
        _fb = REGISTRY.fallback_for(_base, _missing)
        if _fb is not None:
            REGISTRY.declare_fallback(_twin, _fb.missing, _fb.to,
                                      _fb.reason)
    REGISTRY.register_collective_variant(_base, _twin)
