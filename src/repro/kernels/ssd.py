"""Fused chunked SSD scan — the whole Mamba2 chunk algorithm in ONE grid.

`models/ssd.py::ssd_scan` is the repo's one hot spot that mixes the two
shapes the paper's invariant-primitive analysis distinguishes: the
intra-chunk quadratic form is GEMM-shaped (MXU), the inter-chunk state
recurrence is reduction-shaped.  The jnp chunk path (the library row
here) leaves every one of its contractions — `C·Bᵀ`, the decay-weighted
`w·x`, the carried-state contribution `C·h`, and the state update's
`Bᵀ·(wS·x)` — as a separate surface dot whose operands and results stage
through HBM (`scripts/audit_chunked_fusion.py --target ssd`).

This kernel runs the whole scan in one `pl.pallas_call`: grid
``(batch, head, chunk)`` with the chunk axis **sequential** and the
``[N, P]`` per-head state carried in f32 VMEM scratch across it — the
same sequential-axis-accumulator pattern `flash_attention_matmul` uses
for heads and `_paged_attention_matmul` uses for pages.  The
`[B,nc,Q,G,Hg,·]` intermediates (scores, decay weights, per-chunk state
contributions, the carried state itself) never touch HBM; the structural
cost pins that saving against the unfused six-dot sum.

The §VII.C mode distinction lives in the within-chunk decay prefix scan
(`ldec = cumsum(dt·A)`), the scan's one genuinely cross-lane stage:

- ``abstract``        — Hillis–Steele, every doubling stage staged
                        through a VMEM scratch row (store + shifted
                        reload; program order plays the barrier).
- ``abstract+shuffle``— the same stages as lane rotations
                        (`pltpu.roll`), zero scratch traffic.
- ``native``          — the target's fused `cumsum` lowering.
- ``library``         — the jnp chunk path (`ssd_scan_reference`), which
                        is also the registered fallback for ``native``
                        on foreign dialects.

``ssd_decode`` (ISSUE 9) is the decode-side twin: ONE Pallas kernel
batching the one-token recurrence (``h ← exp(dt·A)·h + dt·B⊗x``,
``y = C·h``) across the whole serve batch, grid ``(batch-tile, head)``
with each program's ``[N, P]`` state slice resident in VMEM for the
tick.  The jnp einsum trio (the library row, ``ssd_decode_reference``)
round-trips the ``[B,G,Hg,N,P]``-sized update tensor through HBM per
layer per token; the fused kernel's stream is the operand/result IO
alone.  The §VII.C mode split lives in the cross-lane ``C·h``
contraction over N: abstract stages a scratch-tree reduce, shuffle runs
the lane rotate tree, native issues one MXU dot.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY)
from repro.core.pipeline import CompilerParams
from repro.core.shuffle import (LANES, lane_shuffle_up, lane_tree_reduce,
                                scratch_tree_reduce)
from repro.core.tuning import (active_dialect, register_op_space,
                               ssd_bucket, ssd_candidates,
                               ssd_decode_bucket, ssd_decode_candidates,
                               tuned_entry)

__all__ = ["fused_ssd_scan", "ssd_scan_reference", "resolve_chunk",
           "structural_cost_ssd_scan", "fused_ssd_decode",
           "ssd_decode_reference", "resolve_decode_block",
           "structural_cost_ssd_decode"]


# ---------------------------------------------------------------------------
# Library reference: the jnp chunk path (moved from models/ssd.py so the
# registry's library row and the model wrapper share one implementation).
# ---------------------------------------------------------------------------


def ssd_scan_reference(x, dt, A, B_mat, C_mat, chunk: int,
                       initial_state: Optional[jax.Array] = None,
                       state_hook=None):
    """Chunked SSD, jnp end to end (the unfused six-dot program).

    x:     [B, L, H, P]   (H heads of dim P)
    dt:    [B, L, H]      (positive step sizes)
    A:     [H]            (negative)
    B_mat: [B, L, G, N]
    C_mat: [B, L, G, N]
    Returns y [B, L, H, P] and final state [B, G, Hg, N, P] (Hg = H // G).

    ``state_hook`` (optional) is applied to the carried state inside the
    scan body — models/ssd.py threads its sharding constraint through it
    so the [B,G,Hg,N,P] carry stays placed under a mesh.
    """
    b, l, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    hg = h // g
    chunk = min(chunk, l)
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)) + ((0, 0),))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lp = x.shape[1]
    nc = lp // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, g, hg, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, g, hg)
    Bf = B_mat.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    Cf = C_mat.astype(jnp.float32).reshape(b, nc, chunk, g, n)
    dA = dtf * A.reshape(g, hg)                       # [B,nc,Q,G,Hg] (<=0)
    ldec = jnp.cumsum(dA, axis=2)                     # inclusive within chunk

    if initial_state is None:
        h0 = jnp.zeros((b, g, hg, n, p), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    causal = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])

    def body(state, inp):
        xq, dtq, ldq, Bq, Cq = inp                    # leading axis: nc
        # ---- intra-chunk (quadratic / 'attention' form) ----
        gts = jnp.einsum("bqgn,bsgn->bgqs", Cq, Bq)   # [B,G,Qt,Qs]
        diff = ldq[:, :, None] - ldq[:, None]         # [B,Qt,Qs,G,Hg]
        decay = jnp.exp(jnp.where(causal[None, :, :, None, None],
                                  diff, -jnp.inf))
        w = decay * jnp.moveaxis(gts, 1, 3)[..., None] \
            * dtq[:, None]                            # [B,Qt,Qs,G,Hg]
        y = jnp.einsum("bqsgh,bsghp->bqghp", w, xq)
        # ---- contribution of carried state ----
        y += jnp.einsum("bqgn,bghnp->bqghp", Cq, state) \
            * jnp.exp(ldq)[..., None]
        # ---- state update ----
        total = ldq[:, -1]                            # [B,G,Hg]
        wS = dtq * jnp.exp(total[:, None] - ldq)      # [B,Q,G,Hg]
        s_c = jnp.einsum("bsgn,bsgh,bsghp->bghnp", Bq, wS, xq)
        state = jnp.exp(total)[..., None, None] * state + s_c
        if state_hook is not None:
            state = state_hook(state)
        return state, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xf, dtf, ldec, Bf, Cf))
    final_state, ys = jax.lax.scan(body, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, lp, h, p)[:, :l]
    return y.astype(x.dtype), final_state


def ssd_decode_reference(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence, jnp end to end (the unfused einsum trio).

    state: [B,G,Hg,N,P] (any float dtype; carried in f32)
    x_t:   [B,H,P]; dt_t: [B,H]; A: [H]; B_t/C_t: [B,G,N].
    Returns ``(new_state f32 [B,G,Hg,N,P], y [B,H,P] in x_t's dtype)`` —
    the registry's library row for ``ssd_decode`` and the math
    ``models/ssd.py::ssd_decode_step`` delegates to.
    """
    b, g, hg, n, p = state.shape
    xf = x_t.astype(jnp.float32).reshape(b, g, hg, p)
    dtf = dt_t.astype(jnp.float32).reshape(b, g, hg)
    da = jnp.exp(dtf * A.astype(jnp.float32).reshape(g, hg))  # [B,G,Hg]
    upd = jnp.einsum("bgn,bgh,bghp->bghnp", B_t.astype(jnp.float32),
                     dtf, xf)
    state = da[..., None, None] * state.astype(jnp.float32) + upd
    y = jnp.einsum("bgn,bghnp->bghp", C_t.astype(jnp.float32), state)
    return state, y.reshape(b, g * hg, p).astype(x_t.dtype)


# ---------------------------------------------------------------------------
# Chunk resolution: explicit wins, then the tuned table, then the ranked
# candidate grid's structural winner (one source of truth with autotune).
# ---------------------------------------------------------------------------


def resolve_chunk(mode: str, seq: int, p: int, n: int,
                  chunk: Optional[int] = None,
                  plan_dialect: Optional[str] = None,
                  op: str = "ssd_scan") -> int:
    """The effective chunk length: never longer than the sequence."""
    if chunk is not None:
        return max(1, min(int(chunk), seq))
    entry = tuned_entry(op, mode, ssd_bucket(seq, p, n), plan_dialect)
    if entry and "chunk" in entry:
        return max(1, min(int(entry["chunk"]), seq))
    cands = ssd_candidates(seq, p, n, active_dialect(plan_dialect))
    return max(1, min(int(cands[0]["chunk"]), seq))


def resolve_decode_block(mode: str, b: int, p: int, n: int,
                         block_b: Optional[int] = None,
                         plan_dialect: Optional[str] = None,
                         op: str = "ssd_decode") -> int:
    """The effective decode batch tile: never wider than the batch."""
    if block_b is not None:
        return max(1, min(int(block_b), b))
    entry = tuned_entry(op, mode, ssd_decode_bucket(b, p, n), plan_dialect)
    if entry and "block_b" in entry:
        return max(1, min(int(entry["block_b"]), b))
    cands = ssd_decode_candidates(b, p, n, active_dialect(plan_dialect))
    return max(1, min(int(cands[0]["block_b"]), b))


# ---------------------------------------------------------------------------
# The kernel
# ---------------------------------------------------------------------------


def _prefix_sum(v, scratch_ref, q: int, mode: str):
    """Inclusive prefix sum over the (1, q) lane row — the scan's one
    cross-lane stage, realized per §VII.C budget.

    abstract: each Hillis–Steele doubling stage stores the partial to a
    VMEM scratch row and reloads it shifted (program order plays the
    workgroup barrier) — ceil(log2(q)) round trips.  abstract+shuffle:
    the same stages as lane rotations, zero scratch traffic.  native:
    the target's fused cumsum.
    """
    if mode == "native":
        return jnp.cumsum(v, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (1, q), 1)
    off = 1
    if mode == "abstract+shuffle":
        while off < q:
            shifted = lane_shuffle_up(v, off, axis=-1)
            v = v + jnp.where(idx >= off, shifted, 0.0)
            off *= 2
        return v
    # abstract: the shuffle-free realization — stage through scratch
    while off < q:
        scratch_ref[...] = v                          # store | barrier
        shifted = jnp.concatenate(
            [jnp.zeros((1, off), jnp.float32),
             scratch_ref[:, :q - off]], axis=1)       # shifted reload
        v = v + shifted
        off *= 2
    return v


def _ssd_scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                     y_ref, hf_ref, state_ref, pscan_ref, *,
                     q: int, n_chunks: int, mode: str):
    """One (batch, head, chunk) step; state carried in VMEM across cc."""
    cc = pl.program_id(2)

    xq = x_ref[0, 0].astype(jnp.float32)              # [Q, P]
    dtq = dt_ref[0].astype(jnp.float32)               # [1, Q]
    a = a_ref[0, 0].astype(jnp.float32)               # scalar (negative)
    Bq = b_ref[0, 0].astype(jnp.float32)              # [Q, N]
    Cq = c_ref[0, 0].astype(jnp.float32)              # [Q, N]

    ld = _prefix_sum(dtq * a, pscan_ref, q, mode)     # [1, Q] inclusive
    ld_col = ld.reshape(q, 1)

    @pl.when(cc == 0)
    def _seed_state():
        state_ref[...] = h0_ref[0, 0].astype(jnp.float32)

    state = state_ref[...]                            # [N, P] pre-update

    # ---- intra-chunk quadratic form (MXU) ----
    gts = jax.lax.dot_general(Cq, Bq, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [Q,Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(jnp.where(qi >= si, ld_col - ld, -jnp.inf))
    w = decay * gts * dtq                             # w[t,s] ∝ dt[s]
    y = jax.lax.dot_general(w, xq, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # [Q,P]
    # ---- carried-state contribution ----
    y = y + jax.lax.dot_general(Cq, state, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32) \
        * jnp.exp(ld_col)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # ---- inter-chunk state update (the recurrence) ----
    total = ld[0, q - 1]
    wS = dtq * jnp.exp(total - ld)                    # [1, Q]
    s_c = jax.lax.dot_general(Bq * wS.reshape(q, 1), xq,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [N,P]
    state_ref[...] = jnp.exp(total) * state + s_c

    @pl.when(cc == n_chunks - 1)
    def _emit_state():
        hf_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=(
    "chunk", "mode", "interpret", "plan_dialect", "tuning_op"))
def fused_ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
                   B_mat: jax.Array, C_mat: jax.Array,
                   initial_state: Optional[jax.Array] = None, *,
                   chunk: Optional[int] = None, mode: str = "native",
                   interpret: bool = True,
                   plan_dialect: Optional[str] = None,
                   tuning_op: str = "ssd_scan"):
    """The whole chunked SSD scan as one Pallas kernel.

    Same signature contract as :func:`ssd_scan_reference`: returns the
    identical ``(y [B,L,H,P], final_state f32 [B,G,Hg,N,P])`` pair, so
    the final state seeds the decode recurrence unchanged.  ``chunk``
    ``None`` defers to the tuned table (then the candidate grid) via
    :func:`resolve_chunk`; explicit values pin.  ``initial_state`` rides
    in as a kernel input — hybrid prefill-with-state seeds the VMEM
    carry at the first chunk step.
    """
    b, l, h, p = x.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    hg = h // g
    q = resolve_chunk(mode, l, p, n, chunk, plan_dialect, op=tuning_op)
    if mode == "library":
        return ssd_scan_reference(x, dt, A, B_mat, C_mat, q,
                                  initial_state=initial_state)
    if initial_state is None:
        h0 = jnp.zeros((b, g, hg, n, p), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    # head-major layouts: every grid program owns one (batch, head) lane
    xh = jnp.moveaxis(x, 1, 2)                        # [B, H, L, P]
    dth = jnp.moveaxis(dt, 1, 2)                      # [B, H, L]
    Bh = jnp.moveaxis(B_mat, 1, 2)                    # [B, G, L, N]
    Ch = jnp.moveaxis(C_mat, 1, 2)
    pad = (-l) % q
    if pad:
        # zero dt kills every padded position's contribution (w, wS ∝ dt)
        xh = jnp.pad(xh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dth = jnp.pad(dth, ((0, 0), (0, 0), (0, pad)))
        Bh = jnp.pad(Bh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, 0), (0, pad), (0, 0)))
    lp = l + pad
    nc = lp // q
    a2 = A.astype(jnp.float32).reshape(h, 1)
    h0h = h0.reshape(b, h, n, p)

    grid = (b, h, nc)                                 # chunk axis last
    params = None
    if mode == "native":
        params = CompilerParams(dimension_semantics=(
            "parallel", "parallel", "arbitrary"))

    y, hf = pl.pallas_call(
        functools.partial(_ssd_scan_kernel, q=q, n_chunks=nc, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, q), lambda bb, hh, cc: (bb, hh, cc)),
            pl.BlockSpec((1, 1), lambda bb, hh, cc: (hh, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bb, hh, cc, g_=hg: (bb, hh // g_, cc, 0)),
            pl.BlockSpec((1, 1, q, n),
                         lambda bb, hh, cc, g_=hg: (bb, hh // g_, cc, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, lp, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, p), jnp.float32),          # carried state
            pltpu.VMEM((1, q) if mode == "abstract" else (1, 8),
                       jnp.float32),                  # prefix-scan stage
        ],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_ssd_scan_{mode.replace('+', '_')}",
    )(xh, dth, a2, Bh, Ch, h0h)
    return (jnp.moveaxis(y, 1, 2)[:, :l],
            hf.reshape(b, g, hg, n, p))


def _ssd_scan_library(x, dt, A, B_mat, C_mat, initial_state=None, *,
                      chunk=None, interpret=None, plan_dialect=None,
                      tuning_op: str = "ssd_scan"):
    """jnp chunk-path reference (the unfused six-dot row of Table V).

    ``tuning_op`` threads through to :func:`resolve_chunk` exactly like
    ``fused_ssd_scan``'s static argname does (ISSUE 9 bug fix: the call
    used to drop ``op=``, so with a second ssd op space in the table a
    library fallback would resolve its chunk from the wrong slice).
    """
    del interpret
    q = resolve_chunk("library", x.shape[1], x.shape[3], B_mat.shape[3],
                      chunk, plan_dialect, op=tuning_op)
    return ssd_scan_reference(x, dt, A, B_mat, C_mat, q,
                              initial_state=initial_state)


# ---------------------------------------------------------------------------
# The decode kernel: one-token recurrence batched across the serve batch
# ---------------------------------------------------------------------------


def _ssd_decode_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref,
                       y_ref, hf_ref, red_ref, *, bb: int, n: int, p: int,
                       mode: str):
    """One (batch-tile, head) program: ``bb`` slots' [N,P] states updated
    in VMEM, then the cross-lane ``C·h`` contraction per §VII.C budget."""
    x = x_ref[:, 0].astype(jnp.float32)               # [bb, P]
    dt = dt_ref[...].astype(jnp.float32)              # [bb, 1]
    a = a_ref[0, 0].astype(jnp.float32)               # scalar (negative)
    Bv = b_ref[:, 0].astype(jnp.float32)              # [bb, N]
    Cv = c_ref[:, 0].astype(jnp.float32)              # [bb, N]
    h0 = h0_ref[:, 0].astype(jnp.float32)             # [bb, N, P]

    # the recurrence: decay + rank-1 update, all register/VMEM arithmetic
    da = jnp.exp(dt * a)                              # [bb, 1]
    state = da[..., None] * h0 \
        + (dt * Bv)[..., None] * x[:, None, :]        # [bb, N, P]
    hf_ref[:, 0] = state

    # cross-lane stage: y[p] = sum_n C[n] * state[n, p], per slot
    if mode == "native":
        y = jax.lax.dot_general(Cv, state, (((1,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
    else:
        u = Cv[..., None] * state                     # [bb, N, P]
        rows = []
        for i in range(bb):                           # static unroll
            if mode == "abstract+shuffle":
                # N in lanes: log2(N) rotate tree, zero scratch traffic
                red = lane_tree_reduce(u[i].T, axis=-1)
                rows.append(red[:, :1].T)             # [1, P]
            else:
                # abstract: halving stages through the VMEM scratch ref,
                # program order playing the workgroup barrier
                rows.append(scratch_tree_reduce(u[i], red_ref, axis=0))
        y = jnp.concatenate(rows, axis=0)             # [bb, P]
    y_ref[:, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_b", "mode", "interpret", "plan_dialect", "tuning_op"))
def fused_ssd_decode(state: jax.Array, x_t: jax.Array, dt_t: jax.Array,
                     A: jax.Array, B_t: jax.Array, C_t: jax.Array, *,
                     block_b: Optional[int] = None, mode: str = "native",
                     interpret: bool = True,
                     plan_dialect: Optional[str] = None,
                     tuning_op: str = "ssd_decode"):
    """The batched one-token SSD recurrence as one Pallas kernel.

    Same signature contract as :func:`ssd_decode_reference`: returns the
    identical ``(new_state f32 [B,G,Hg,N,P], y [B,H,P])`` pair, so the
    serve tick's cache carry is unchanged.  Grid ``(batch-tile, head)``
    with each program's ``[bb, N, P]`` state slice resident in VMEM for
    the tick — the jnp path's ``[B,G,Hg,N,P]`` update tensor never
    stages through HBM.  ``block_b`` ``None`` defers to the tuned table
    (then the candidate grid) via :func:`resolve_decode_block`; explicit
    values pin.  N must be a power of two for the non-native tree
    reduces (every registered mamba2 state width is).
    """
    b, g, hg, n, p = state.shape
    h = g * hg
    bb = resolve_decode_block(mode, b, p, n, block_b, plan_dialect,
                              op=tuning_op)
    if mode == "library":
        return ssd_decode_reference(state, x_t, dt_t, A, B_t, C_t)

    h0h = state.astype(jnp.float32).reshape(b, h, n, p)
    a2 = A.astype(jnp.float32).reshape(h, 1)
    pad = (-b) % bb
    if pad:
        # zero dt/x/B kill the padded slots' update (their state rows are
        # zeros and sliced off before return)
        h0h = jnp.pad(h0h, ((0, pad),) + ((0, 0),) * 3)
        x_t = jnp.pad(x_t, ((0, pad), (0, 0), (0, 0)))
        dt_t = jnp.pad(dt_t, ((0, pad), (0, 0)))
        B_t = jnp.pad(B_t, ((0, pad), (0, 0), (0, 0)))
        C_t = jnp.pad(C_t, ((0, pad), (0, 0), (0, 0)))
    bp = b + pad

    grid = (bp // bb, h)
    params = None
    if mode == "native":
        params = CompilerParams(dimension_semantics=(
            "parallel", "parallel"))

    y, hf = pl.pallas_call(
        functools.partial(_ssd_decode_kernel, bb=bb, n=n, p=p, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 1, p), lambda bi, hh: (bi, hh, 0)),
            pl.BlockSpec((bb, 1), lambda bi, hh: (bi, hh)),
            pl.BlockSpec((1, 1), lambda bi, hh: (hh, 0)),
            pl.BlockSpec((bb, 1, n),
                         lambda bi, hh, g_=hg: (bi, hh // g_, 0)),
            pl.BlockSpec((bb, 1, n),
                         lambda bi, hh, g_=hg: (bi, hh // g_, 0)),
            pl.BlockSpec((bb, 1, n, p), lambda bi, hh: (bi, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 1, p), lambda bi, hh: (bi, hh, 0)),
            pl.BlockSpec((bb, 1, n, p), lambda bi, hh: (bi, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, h, p), x_t.dtype),
            jax.ShapeDtypeStruct((bp, h, n, p), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, p) if mode == "abstract" else (1, 8),
                       jnp.float32),                  # tree-reduce stage
        ],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_ssd_decode_{mode.replace('+', '_')}",
    )(x_t, dt_t, a2, B_t, C_t, h0h)
    return hf[:b].reshape(b, g, hg, n, p), y[:b]


def _ssd_decode_library(state, x_t, dt_t, A, B_t, C_t, *, block_b=None,
                        interpret=None, plan_dialect=None):
    """jnp einsum-trio reference — the per-layer state round trip row."""
    del block_b, interpret, plan_dialect
    return ssd_decode_reference(state, x_t, dt_t, A, B_t, C_t)


# ---------------------------------------------------------------------------
# Structural cost: fused stream vs the unfused six-dot boundary traffic
# ---------------------------------------------------------------------------


def _scan_stages(q: int) -> int:
    """Hillis–Steele doubling stages of a ``q``-wide inclusive scan."""
    return int(math.ceil(math.log2(q))) if q > 1 else 0


def _scan_scratch_bytes(q: int, itemsize: int = 4) -> int:
    """Scratch traffic of one abstract prefix scan: stage ``k`` stores
    the full ``q`` row and reloads ``q - 2^k`` shifted lanes."""
    return sum((q + (q - (1 << k))) * itemsize
               for k in range(_scan_stages(q)))


def structural_cost_ssd_scan(b: int, seq: int, h: int, p: int, g: int,
                             n: int, mode: str,
                             chunk: Optional[int] = None,
                             dtype=jnp.float32,
                             plan_dialect: Optional[str] = None) -> dict:
    """Fused stream traffic vs the unfused chunk path's six-dot sum.

    ``hbm_bytes_unfused_pair`` is what the jnp chunk program stages: the
    operand/result stream **plus** every per-chunk intermediate the six
    separate contractions round-trip through HBM — the `[B,nc,G,Q,Q]`
    scores, the `[B,nc,Q,Q,G,Hg]` decay weights, the `[B,nc,Q,G,Hg]`
    decay rows (ldec, wS), the `[B,nc,Q,G,Hg,P]` carried-state
    contribution, the per-chunk `[G,Hg,N,P]` state updates, and the
    carried state itself between chunks.  The fused kernel keeps all of
    them in VMEM (the state in scratch across the sequential chunk
    axis), so its ``hbm_bytes`` is the operand/result stream alone —
    the identity ``hbm_bytes == hbm_bytes_unfused_pair -
    hbm_bytes_saved`` is validated by scripts/validate_contracts.py.

    The scratch columns account only the §VII.C cross-lane mechanism
    (the decay prefix scan), exactly like attention's cost model: the
    VMEM-resident state is pipelining, not barrier traffic, and keeping
    it out of the columns keeps the declared fallbacks never-cheaper.
    """
    q = resolve_chunk(mode, seq, p, n, chunk, plan_dialect)
    nc = -(-seq // q)
    lp = nc * q
    hg = max(1, h // g)
    itemsize = jnp.dtype(dtype).itemsize
    f32 = 4
    # fused operand/result stream (read x/dt/B/C/A/h0 once, write y + hf)
    io = (b * lp * h * p * itemsize                   # x read
          + b * lp * h * itemsize                     # dt read
          + 2 * b * lp * g * n * itemsize             # B + C reads
          + h * f32                                   # A
          + b * h * n * p * f32                       # h0 read
          + b * lp * h * p * itemsize                 # y write
          + b * h * n * p * f32)                      # final state write
    # per-chunk intermediates the unfused six-dot program materializes
    inter = (b * nc * g * q * q * f32                 # gts scores
             + b * nc * q * q * g * hg * f32          # decay weights w
             + 2 * b * nc * q * g * hg * f32          # ldec + wS rows
             + b * nc * q * g * hg * p * f32          # C·h contribution
             + b * nc * g * hg * n * p * f32          # s_c per chunk
             + b * nc * g * hg * n * p * f32)         # carried state trip
    pair = io + 2 * inter                             # write + read back
    saved = 0 if mode == "library" else 2 * inter
    flops = b * h * nc * (2 * q * q * n               # C·Bᵀ
                          + 2 * q * q * p             # w·x
                          + 2 * q * n * p             # C·h
                          + 2 * q * n * p)            # Bᵀ·(wS·x)
    stages = _scan_stages(q)
    if mode == "abstract":
        round_trips = stages
        scratch_bytes = b * h * nc * _scan_scratch_bytes(q)
        shuffles = 0
    elif mode == "abstract+shuffle":
        round_trips = 0
        scratch_bytes = 0
        shuffles = stages
    else:                                             # native / library
        round_trips = 0
        scratch_bytes = 0
        shuffles = 0
    return {
        "hbm_bytes": pair - saved,
        "hbm_bytes_unfused_pair": pair,
        "hbm_bytes_saved": saved,
        "flops": flops,
        "chunk": q,
        "n_chunks": nc,
        "blocks_visited": b * h * nc,
        "state_bytes_resident": n * p * f32,          # the VMEM carry
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": shuffles,
        "fused_epilogue": mode != "library",
    }


def structural_cost_ssd_decode(b: int, h: int, p: int, g: int, n: int,
                               mode: str,
                               block_b: Optional[int] = None,
                               dtype=jnp.float32,
                               plan_dialect: Optional[str] = None) -> dict:
    """Fused decode-tick traffic vs the unfused einsum trio's round trip.

    ``hbm_bytes_unfused_pair`` is what the jnp recurrence stages per
    layer per token: the operand/result stream (the state itself must
    round-trip HBM once per tick either way — it lives in the decode
    cache between ticks) **plus** the intermediates the separate einsums
    materialize — the ``[B,G,Hg,N,P]`` update tensor ``dt·B⊗x`` (a full
    state-sized HBM round trip, the §VII.C tail-latency tax ISSUE 9
    removes) and the ``[B,G,Hg]`` decay row.  The fused kernel keeps
    both in VMEM, so its ``hbm_bytes`` is the operand/result stream
    alone; the identity ``hbm_bytes == hbm_bytes_unfused_pair -
    hbm_bytes_saved`` is validated by scripts/validate_contracts.py.

    The scratch columns account only the cross-lane ``C·h`` contraction
    (the §VII.C mechanism): a log2(N) tree per slot, staged through VMEM
    in abstract mode and through lane rotations in shuffle mode.
    """
    bb = resolve_decode_block(mode, b, p, n, block_b, plan_dialect)
    itemsize = jnp.dtype(dtype).itemsize
    f32 = 4
    # fused operand/result stream (read x/dt/A/B/C + the cached state,
    # write y + the updated state — the cache round trip both paths pay)
    io = (b * h * p * itemsize                        # x_t read
          + b * h * itemsize                          # dt read
          + h * f32                                   # A
          + 2 * b * g * n * itemsize                  # B_t + C_t reads
          + b * h * n * p * f32                       # state read (cache)
          + b * h * n * p * f32                       # state write (cache)
          + b * h * p * itemsize)                     # y write
    # intermediates the unfused einsum trio materializes per layer/token
    inter = (b * h * n * p * f32                      # dt·B⊗x update tensor
             + b * h * f32)                           # exp(dt·A) decay row
    pair = io + 2 * inter                             # write + read back
    saved = 0 if mode == "library" else 2 * inter
    flops = b * h * (2 * n * p                        # decay scale + add
                     + 2 * n * p                      # rank-1 update
                     + 2 * n * p)                     # C·h contraction
    stages = _scan_stages(n)
    blocks = -(-b // bb) * h
    if mode == "abstract":
        round_trips = bb * stages
        # per tree: stage k reads two (n >> k, P) slices and writes one
        per_tree = p * sum(3 * (n >> k) * f32
                           for k in range(1, stages + 1))
        scratch_bytes = blocks * bb * per_tree
        shuffles = 0
    elif mode == "abstract+shuffle":
        round_trips = 0
        scratch_bytes = 0
        shuffles = bb * stages
    else:                                             # native / library
        round_trips = 0
        scratch_bytes = 0
        shuffles = 0
    return {
        "hbm_bytes": pair - saved,
        "hbm_bytes_unfused_pair": pair,
        "hbm_bytes_saved": saved,
        "flops": flops,
        "block_b": bb,
        "blocks_visited": blocks,
        "state_bytes_resident": bb * n * p * f32,     # the VMEM residency
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": shuffles,
        "fused_epilogue": mode != "library",
    }


# ---------------------------------------------------------------------------
# Contracts + registration (the full IsaMode matrix, six dialects)
# ---------------------------------------------------------------------------

_SSD_ABSTRACT = KernelContract(
    kernel="ssd_scan", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MASKED_DIVERGENCE,
        Primitive.MANAGED_SCRATCHPAD, Primitive.WORKGROUP_BARRIER,
        Primitive.HIERARCHICAL_MEMORY, Primitive.IDENTITY_REGISTERS,
        Primitive.ASYNC_MEMORY, Primitive.REGISTER_OCCUPANCY,
    }))
_SSD_SHUFFLE = KernelContract(
    kernel="ssd_scan", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_SSD_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_SSD_NATIVE = KernelContract(
    kernel="ssd_scan", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

register_op_space("ssd_scan", "ssd")

for _mode, _contract in (("abstract", _SSD_ABSTRACT),
                         ("abstract+shuffle", _SSD_SHUFFLE),
                         ("native", _SSD_NATIVE)):
    REGISTRY.register("ssd_scan", _mode,
                      functools.partial(fused_ssd_scan, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost_ssd_scan,
                                             mode=_mode))
REGISTRY.register("ssd_scan", IsaMode.LIBRARY, _ssd_scan_library,
                  cost=functools.partial(structural_cost_ssd_scan,
                                         mode="library"))
REGISTRY.declare_fallback(
    "ssd_scan", IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
    reason="no lane shuffle: decay prefix scan stages through the VMEM "
           "scratch tree instead (§VII.C)")
REGISTRY.declare_fallback(
    "ssd_scan", IsaMode.NATIVE, IsaMode.LIBRARY,
    reason="fused native chunk scan is target-pinned; the declared escape "
           "is the unfused jnp chunk path")

_SSDD_ABSTRACT = KernelContract(
    kernel="ssd_decode", mode=IsaMode.ABSTRACT,
    primitives=_SSD_ABSTRACT.primitives)
_SSDD_SHUFFLE = KernelContract(
    kernel="ssd_decode", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=_SSD_ABSTRACT.primitives | {Primitive.LANE_SHUFFLE})
_SSDD_NATIVE = KernelContract(
    kernel="ssd_decode", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "mxu_aligned_tiles",
                               "dimension_semantics", "multi_buffering"}))

register_op_space("ssd_decode", "ssd_decode")

for _mode, _contract in (("abstract", _SSDD_ABSTRACT),
                         ("abstract+shuffle", _SSDD_SHUFFLE),
                         ("native", _SSDD_NATIVE)):
    REGISTRY.register("ssd_decode", _mode,
                      functools.partial(fused_ssd_decode, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost_ssd_decode,
                                             mode=_mode))
REGISTRY.register("ssd_decode", IsaMode.LIBRARY, _ssd_decode_library,
                  cost=functools.partial(structural_cost_ssd_decode,
                                         mode="library"))
REGISTRY.declare_fallback(
    "ssd_decode", IsaMode.ABSTRACT_SHUFFLE, IsaMode.ABSTRACT,
    reason="no lane shuffle: the C·h contraction reduces through the VMEM "
           "scratch tree instead (§VII.C)")
REGISTRY.declare_fallback(
    "ssd_decode", IsaMode.NATIVE, IsaMode.LIBRARY,
    reason="batched native decode recurrence is target-pinned; the declared "
           "escape is the unfused jnp einsum trio")
