"""Fused RMSNorm — fused-epilogue example + a §VII.C cross-lane hot loop.

The moment computation (mean of squares over the feature axis) is a
rowwise cross-lane reduction, so the kernel carries the full Table V mode
matrix through the shared primitive layer:

- ``abstract``: the row is folded to one 128-lane vreg by register
  accumulation, then tree-reduced through *scratchpad round-trips*
  (``scratch_tree_reduce``) — log2(W)=7 store/reload stages with program
  order as the barrier.  A second scratch round-trip hands the moment to
  the normalize pass (no fusion guarantee in the universal budget).
- ``abstract+shuffle``: the same fold, then the in-register rotate tree
  (``row_reduce_shuffle``) — zero scratch traffic, single residency.
- ``native``: target-native reduce (jnp.mean) + fused epilogue + pipeline
  annotations.

The feature axis is zero-padded to a lane multiple for the non-native
variants (zeros contribute nothing to the second moment; the divisor uses
the true width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive, REGISTRY,
                        TARGET, register_op_space, row_reduce_shuffle,
                        fold_rows, scratch_tree_bytes, scratch_tree_reduce,
                        tree_stages, tuned_plan, validate_contract)

LANES = TARGET.W
_MAX_BLOCK_ROWS = 64
register_op_space("rmsnorm", "rowwise", max_block_rows=_MAX_BLOCK_ROWS)

ABSTRACT_CONTRACT = KernelContract(
    kernel="rmsnorm", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
SHUFFLE_CONTRACT = KernelContract(
    kernel="rmsnorm", mode=IsaMode.ABSTRACT_SHUFFLE,
    primitives=ABSTRACT_CONTRACT.primitives | {Primitive.LANE_SHUFFLE})
NATIVE_CONTRACT = KernelContract(
    kernel="rmsnorm", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "dimension_semantics",
                               "multi_buffering"}))
for _c in (ABSTRACT_CONTRACT, SHUFFLE_CONTRACT, NATIVE_CONTRACT):
    validate_contract(_c)


def _plan(rows: int, d_padded: int, itemsize: int, mode: str,
          plan_dialect: str | None = None):
    return tuned_plan("rmsnorm", rows, d_padded * itemsize, mode=mode,
                      dialect=plan_dialect,
                      max_block_rows=_MAX_BLOCK_ROWS,
                      semantics=("parallel",))


def normalize_block(x, w, scratch_ref, *, eps: float, mode: str,
                    d_true: int):
    """One row block's normalization, cross-lane stage budget-selected.

    The single source of the per-mode moment discipline, shared with the
    fused lowerings (kernels/fused.py):

    - ``native``: single residency, target-native cross-lane reduce;
    - ``abstract+shuffle``: rotate tree in registers — zero scratch
      round-trips (§VII.C);
    - ``abstract``: fold to one vreg (register ops), then the
      shuffle-free scratch tree (7 barrier-ordered round-trips), plus a
      second round-trip re-staging the moment — the universal budget
      gives no fusion guarantee before the normalize pass.
    """
    if mode == "native":
        var = jnp.mean(x * x, axis=-1, keepdims=True)
    elif mode == "abstract+shuffle":
        var = row_reduce_shuffle(x * x) / d_true          # (rows, 1)
    elif mode == "abstract":
        acc = fold_rows(x * x)                            # (rows, LANES)
        sumsq = scratch_tree_reduce(acc, scratch_ref)     # (rows, 1)
        scratch_ref[:, :1] = sumsq / d_true               # moment re-stage
        var = scratch_ref[:, :1]                          # reload
    else:
        raise ValueError(mode)
    return x * jax.lax.rsqrt(var + eps) * w


def _rmsnorm_kernel(x_ref, w_ref, o_ref, scratch_ref, *, eps: float,
                    mode: str, d_true: int):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    w = w_ref[...].astype(jnp.float32)                    # (1, d)
    o_ref[...] = normalize_block(x, w, scratch_ref, eps=eps, mode=mode,
                                 d_true=d_true).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret",
                                             "plan_dialect"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            mode: str = "native", interpret: bool = True,
            plan_dialect: str | None = None) -> jax.Array:
    """RMSNorm over the last axis; x: [..., D], weight: [D].

    ``plan_dialect`` (static) pins which dialect's tuned staging plan the
    trace binds; None degrades to the ambient policy's dialect."""
    if mode == "library":
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        return ((xf * jax.lax.rsqrt(var + eps)) *
                weight.astype(jnp.float32)).astype(x.dtype)
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    x2d = x.reshape(rows, d)
    w2d = weight.reshape(1, d)
    d_padded = d
    if mode != "native":
        # Non-native cross-lane stages fold the row into 128-lane vregs.
        pad_d = (-d) % LANES
        if pad_d:
            d_padded = d + pad_d
            x2d = jnp.pad(x2d, ((0, 0), (0, pad_d)))
            w2d = jnp.pad(w2d, ((0, 0), (0, pad_d)))

    plan = _plan(rows, d_padded, jnp.dtype(x.dtype).itemsize, mode,
                 plan_dialect)
    block = plan.block_rows
    pad = plan.padded_rows - rows
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, mode=mode, d_true=d),
        grid=plan.grid,
        in_specs=[
            pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
            pl.BlockSpec((1, d_padded), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d_padded), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        # only the abstract tree stages through scratch
        scratch_shapes=[pltpu.VMEM(
            (block, LANES) if mode == "abstract" else (8, LANES),
            jnp.float32)],
        compiler_params=plan.compiler_params,
        interpret=interpret,
        name=f"uisa_rmsnorm_{mode.replace('+', '_')}",
    )(x2d, w2d)
    return out[:rows, :d].reshape(x.shape)


def structural_cost(rows: int, d: int, mode: str, dtype=jnp.float32,
                    plan_dialect: str | None = None) -> dict:
    """Scratch-traffic delta of the moment reduction — §VII.C generalized.

    HBM traffic is mode-invariant (read x + w, write out); the cross-lane
    moment stage is where the budgets diverge: 7 scratch round-trips
    (abstract) vs 7 in-register shuffles (abstract+shuffle) vs a native
    fused reduce.
    """
    itemsize = jnp.dtype(dtype).itemsize
    d_padded = d if mode == "native" else d + ((-d) % LANES)
    plan = _plan(rows, d_padded, itemsize,
                 mode if mode != "library" else "native", plan_dialect)
    blocks = plan.grid[0]
    if mode == "abstract":
        round_trips = tree_stages(LANES) + 1   # tree + moment re-stage
        scratch_bytes = blocks * (
            scratch_tree_bytes(LANES, rows=plan.block_rows)
            + 3 * plan.block_rows * 4)         # moment store+2 reloads
    else:
        round_trips = 0
        scratch_bytes = 0
    return {
        "hbm_bytes": rows * d * itemsize * 2 + d * itemsize,
        "scratch_round_trips_per_block": round_trips,
        "scratch_bytes_total": scratch_bytes,
        "lane_shuffles_per_block": tree_stages(LANES)
        if mode == "abstract+shuffle" else 0,
        "blocks": blocks,
        "block_rows": plan.block_rows,
        "pipeline_occupancy": plan.occupancy,
        "fused_epilogue": mode in ("native", "library"),
    }


# Registry: the library variant is the jnp path model norms used to call
# directly — registering it here puts those call sites under Table V
# dispatch instead of bypassing the kernel layer (ISSUE 2 satellite).
for _mode, _contract in (("abstract", ABSTRACT_CONTRACT),
                         ("abstract+shuffle", SHUFFLE_CONTRACT),
                         ("native", NATIVE_CONTRACT),
                         ("library", None)):
    REGISTRY.register("rmsnorm", _mode,
                      functools.partial(rmsnorm, mode=_mode),
                      contract=_contract,
                      cost=functools.partial(structural_cost, mode=_mode))
