"""Fused RMSNorm — the fused-epilogue example kernel.

Exercises the remaining native feature (``fused_epilogue``): the native
variant computes moment + normalization + weight application in one VMEM
residency; the abstract variant makes two explicit passes through the
scratchpad with a barrier between them (moment pass, then normalize pass),
mirroring how a universal-primitives kernel without fusion guarantees
would be written.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import (IsaMode, KernelContract, Primitive,
                        validate_contract)

_BLOCK_ROWS = 64

ABSTRACT_CONTRACT = KernelContract(
    kernel="rmsnorm", mode=IsaMode.ABSTRACT,
    primitives=frozenset({
        Primitive.LOCKSTEP_GROUP, Primitive.MANAGED_SCRATCHPAD,
        Primitive.WORKGROUP_BARRIER, Primitive.HIERARCHICAL_MEMORY,
        Primitive.IDENTITY_REGISTERS, Primitive.ASYNC_MEMORY,
    }))
NATIVE_CONTRACT = KernelContract(
    kernel="rmsnorm", mode=IsaMode.NATIVE,
    primitives=frozenset(Primitive),
    native_features=frozenset({"fused_epilogue", "dimension_semantics",
                               "multi_buffering"}))
validate_contract(ABSTRACT_CONTRACT)
validate_contract(NATIVE_CONTRACT)


def _rmsnorm_kernel(x_ref, w_ref, o_ref, scratch_ref, *, eps: float,
                    mode: str):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    w = w_ref[...].astype(jnp.float32)                    # (1, d)
    if mode == "native":
        # Fused: single residency.
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)
    else:
        # Abstract: pass 1 writes moments to scratch; barrier; pass 2
        # reloads them and normalizes.  Same arithmetic, one extra
        # scratchpad round-trip per block.
        scratch_ref[...] = jnp.mean(x * x, axis=-1, keepdims=True)
        var = scratch_ref[...]                            # round-trip
        o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "eps", "interpret"))
def rmsnorm(x: jax.Array, weight: jax.Array, *, eps: float = 1e-6,
            mode: str = "native", interpret: bool = True) -> jax.Array:
    """RMSNorm over the last axis; x: [..., D], weight: [D]."""
    if mode == "library":
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        return ((xf * jax.lax.rsqrt(var + eps)) *
                weight.astype(jnp.float32)).astype(x.dtype)
    if mode == "abstract+shuffle":
        mode = "abstract"
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    x2d = x.reshape(rows, d)
    block = min(_BLOCK_ROWS, rows)
    pad = (-rows) % block
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)))
    grid = (x2d.shape[0] // block,)

    params = None
    if mode == "native":
        params = pltpu.CompilerParams(dimension_semantics=("parallel",))

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((block, 1), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
        name=f"uisa_rmsnorm_{mode}",
    )(x2d, weight.reshape(1, d))
    return out[:rows].reshape(x.shape)
