"""Encoder-decoder transformer (whisper-base backbone, arXiv:2212.04356).

Per the assignment the conv/audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings [B, num_frames, d_model] (the output
the two conv1d layers would produce).  Everything downstream — sinusoidal
encoder, learned-position decoder with causal self-attn + cross-attn —
is real and scanned for compile-time economy.

Decode path: self-attn KV cache grows with generated tokens; cross-attn
K/V over the encoder memory are computed once at prefill and static
thereafter (the standard whisper serving layout).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import common, mlp, transformer
from repro.models.attention import chunked_attention, decode_attention, update_cache
from repro.models.config import ModelConfig, ParallelConfig
from repro.parallel.sharding import ShardCtx, shard


def _init_cross_attn(key, cfg: ModelConfig, dtype):
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": common.dense_init(ks[0], (d, h * hd), 0, dtype),
        "wk": common.dense_init(ks[1], (d, hkv * hd), 0, dtype),
        "wv": common.dense_init(ks[2], (d, hkv * hd), 0, dtype),
        "wo": common.dense_init(ks[3], (h * hd, d), 0, dtype),
    }
    specs = {"wq": ("embed", "q_heads"), "wk": ("embed", "kv_heads"),
             "wv": ("embed", "kv_heads"), "wo": ("q_heads", "embed")}
    return params, specs


def init_dec_block(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 6)
    self_attn, self_specs = transformer.init_attn(ks[0], cfg, dtype)
    cross_attn, cross_specs = _init_cross_attn(ks[1], cfg, dtype)
    mlp_p, mlp_specs = mlp.init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.act,
                                    dtype)
    params = {"self_attn": self_attn, "cross_attn": cross_attn,
              "mlp": mlp_p,
              "ln1": common.init_norm(ks[3], cfg.d_model, cfg.norm, dtype),
              "ln2": common.init_norm(ks[4], cfg.d_model, cfg.norm, dtype),
              "ln3": common.init_norm(ks[5], cfg.d_model, cfg.norm, dtype)}
    specs = {"self_attn": self_specs, "cross_attn": cross_specs,
             "mlp": mlp_specs,
             "ln1": common.norm_specs(cfg.norm),
             "ln2": common.norm_specs(cfg.norm),
             "ln3": common.norm_specs(cfg.norm)}
    return params, specs


def _cross_kv(params, memory, cfg: ModelConfig, ctx):
    """Project encoder memory to cross-attn K/V: [B,Hkv,F,hd]."""
    b, f, _ = memory.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = jnp.einsum("bfd,dh->bfh", memory, params["wk"].astype(memory.dtype))
    v = jnp.einsum("bfd,dh->bfh", memory, params["wv"].astype(memory.dtype))
    k = k.reshape(b, f, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, f, hkv, hd).transpose(0, 2, 1, 3)
    k = shard(k, ("act_batch", "act_kv_heads", "act_frames",
                  "act_head_dim"), ctx)
    v = shard(v, ("act_batch", "act_kv_heads", "act_frames",
                  "act_head_dim"), ctx)
    return k, v


def _cross_attend(params, x, k, v, cfg: ModelConfig, par: ParallelConfig,
                  ctx):
    """x: [B,S,D] queries against fixed memory K/V [B,Hkv,F,hd]."""
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype))
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    o = chunked_attention(q, k, v, causal=False,
                          chunk_q=par.attn_chunk_q,
                          chunk_kv=par.attn_chunk_kv, ctx=ctx)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", o, params["wo"].astype(x.dtype))


def dec_block_seq(params, x, memory_kv, cfg, par, positions, ctx,
                  return_kv: bool = False, policy=None):
    h = common.apply_norm(x, params["ln1"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    if return_kv:
        a, kv = transformer.attn_seq(params["self_attn"], h, cfg, par,
                                     positions, ctx, return_kv=True,
                                     policy=policy)
    else:
        a = transformer.attn_seq(params["self_attn"], h, cfg, par,
                                 positions, ctx, policy=policy)
        kv = None
    x = x + a
    h = common.apply_norm(x, params["ln2"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    x = x + _cross_attend(params["cross_attn"], h, *memory_kv, cfg, par, ctx)
    h = common.apply_norm(x, params["ln3"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    x = x + mlp.apply_mlp(params["mlp"], h, cfg.act, ctx)
    x = shard(x, ("act_batch", "act_seq", "act_embed"), ctx)
    return (x, kv) if return_kv else x


def dec_block_decode(params, x_t, memory_kv, cfg, kv_cache, pos, ctx,
                     policy=None):
    h = common.apply_norm(x_t, params["ln1"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    a, kv_cache = transformer.attn_decode(params["self_attn"], h, cfg,
                                          kv_cache, pos, ctx, policy=policy)
    x_t = x_t + a
    h = common.apply_norm(x_t, params["ln2"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    b = x_t.shape[0]
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", h,
                   params["cross_attn"]["wq"].astype(h.dtype))
    q = q.reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
    mk, mv = memory_kv
    f = mk.shape[2]
    o = decode_attention(q, mk, mv, jnp.full((b,), f - 1, jnp.int32),
                         ctx=ctx)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    x_t = x_t + jnp.einsum("bsh,hd->bsd", o,
                           params["cross_attn"]["wo"].astype(x_t.dtype))
    h = common.apply_norm(x_t, params["ln3"], cfg.norm, cfg.norm_eps,
                          policy=policy)
    x_t = x_t + mlp.apply_mlp(params["mlp"], h, cfg.act, ctx)
    return x_t, kv_cache


class EncDecLM:
    """Whisper-family: scanned encoder + scanned decoder, stub frontend."""

    def __init__(self, cfg: ModelConfig, par: ParallelConfig,
                 ctx: Optional[ShardCtx] = None, policy=None):
        assert cfg.encdec is not None
        self.cfg, self.par, self.ctx = cfg, par, ctx
        self.policy = policy or par.execution_policy()

    def with_policy(self, policy) -> "EncDecLM":
        return type(self)(self.cfg, self.par, self.ctx, policy=policy)

    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    # ---- params ----

    def init_params(self, rng):
        cfg = self.cfg
        dtype = self._dtype()
        ks = jax.random.split(rng, 6)
        enc_keys = jax.random.split(ks[0], cfg.encdec.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.num_layers)
        enc_blocks = jax.vmap(
            lambda k: transformer.init_block(k, cfg, dtype)[0])(enc_keys)
        dec_blocks = jax.vmap(
            lambda k: init_dec_block(k, cfg, dtype)[0])(dec_keys)
        return {
            "embed": common.embed_init(ks[2],
                                       (cfg.vocab_size, cfg.d_model)),
            "pos_embed": common.embed_init(ks[3],
                                           (cfg.max_seq_len, cfg.d_model)),
            "enc_blocks": enc_blocks,
            "dec_blocks": dec_blocks,
            "enc_norm": common.init_norm(ks[4], cfg.d_model, cfg.norm,
                                         dtype),
            "final_norm": common.init_norm(ks[5], cfg.d_model, cfg.norm,
                                           dtype),
        }

    def param_specs(self):
        cfg = self.cfg
        lift = lambda t: jax.tree.map(lambda ax: (None,) + ax, t,
                                      is_leaf=lambda x: isinstance(x, tuple))
        _, enc_specs = transformer.init_block(jax.random.PRNGKey(0), cfg,
                                              jnp.float32)
        _, dec_specs = init_dec_block(jax.random.PRNGKey(0), cfg,
                                      jnp.float32)
        return {"embed": ("vocab", "embed"),
                "pos_embed": (None, "embed"),
                "enc_blocks": lift(enc_specs),
                "dec_blocks": lift(dec_specs),
                "enc_norm": common.norm_specs(cfg.norm),
                "final_norm": common.norm_specs(cfg.norm)}

    # ---- encoder ----

    def encode(self, params, frames):
        """frames: [B,F,D] stub frontend output -> encoder memory [B,F,D]."""
        cfg, par, ctx = self.cfg, self.par, self.ctx
        x = frames.astype(self._dtype())
        x = x + common.sinusoidal_positions(x.shape[1], cfg.d_model
                                            ).astype(x.dtype)[None]
        x = shard(x, ("act_batch", "act_frames", "act_embed"), ctx)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))

        def body(h, layer_params):
            # non-causal self-attention (encoder)
            hn = common.apply_norm(h, layer_params["ln1"], cfg.norm,
                                   cfg.norm_eps, policy=self.policy)
            a = transformer.attn_seq(layer_params["attn"], hn, cfg, par,
                                     positions, ctx, causal=False,
                                     policy=self.policy)
            h = h + a
            hn = common.apply_norm(h, layer_params["ln2"], cfg.norm,
                                   cfg.norm_eps, policy=self.policy)
            h = h + mlp.apply_mlp(layer_params["mlp"], hn, cfg.act, ctx)
            return h, None

        if par.remat == "full":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return common.apply_norm(x, params["enc_norm"], cfg.norm,
                                 cfg.norm_eps, policy=self.policy)

    # ---- decoder ----

    def _embed_tokens(self, params, tokens, pos_offset=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype())
        if pos_offset is None:
            pe = params["pos_embed"][:x.shape[1]]
            x = x + pe.astype(x.dtype)[None]
        else:
            pe = jnp.take(params["pos_embed"], pos_offset, axis=0)
            x = x + pe.astype(x.dtype)[:, None, :]
        return shard(x, ("act_batch", "act_seq_unsharded", "act_embed"),
                     self.ctx)

    def _head(self, params, x):
        cfg = self.cfg
        x = common.apply_norm(x, params["final_norm"], cfg.norm,
                              cfg.norm_eps, policy=self.policy)
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(x.dtype))  # tied head
        return shard(logits.astype(jnp.float32),
                     ("act_batch", "act_seq_unsharded", "act_vocab"),
                     self.ctx)

    def _scan_decoder(self, params, x, memory, positions,
                      return_kv: bool = False):
        cfg, par, ctx = self.cfg, self.par, self.ctx

        def body(h, layer_params):
            mem_kv = _cross_kv(layer_params["cross_attn"], memory, cfg, ctx)
            if return_kv:
                h, kv = dec_block_seq(layer_params, h, mem_kv, cfg, par,
                                      positions, ctx, return_kv=True,
                                      policy=self.policy)
                return h, kv
            h = dec_block_seq(layer_params, h, mem_kv, cfg, par, positions,
                              ctx, policy=self.policy)
            return h, None

        if par.remat == "full":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, params["dec_blocks"])

    # ---- public API ----

    def loss_fn(self, params, batch):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))
        x, _ = self._scan_decoder(params, x, memory, positions)
        logits = self._head(params, x)
        loss = common.cross_entropy(logits, batch["labels"], self.ctx)
        return loss, {"ce_loss": loss}

    def prefill(self, params, batch):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, kvs = self._scan_decoder(params, x, memory, positions,
                                    return_kv=True)
        logits = self._head(params, x[:, -1:, :])
        cache = {"k": kvs[0], "v": kvs[1], "memory": memory,
                 "pos": jnp.full((b,), s, jnp.int32)}
        return logits[:, 0], cache

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (cfg.num_layers, batch_size, hkv, cache_len, hd)
        return {
            "k": jnp.zeros(shape, self._dtype()),
            "v": jnp.zeros(shape, self._dtype()),
            "memory": jnp.zeros((batch_size, cfg.encdec.num_frames,
                                 cfg.d_model), self._dtype()),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def cache_specs(self):
        kv = (None, "act_cache_batch", "act_kv_heads", "act_kv_seq",
              "act_head_dim")
        return {"k": kv, "v": kv,
                "memory": ("act_batch", "act_frames", "act_embed"),
                "pos": (None,)}

    def decode_step(self, params, tokens, cache):
        cfg, ctx = self.cfg, self.ctx
        pos = cache["pos"]
        x = self._embed_tokens(params, tokens[:, None], pos_offset=pos)
        memory = cache["memory"]

        def body(h, layer):
            layer_params, kv = layer
            mem_kv = _cross_kv(layer_params["cross_attn"], memory, cfg, ctx)
            h, new_kv = dec_block_decode(layer_params, h, mem_kv, cfg, kv,
                                         pos, ctx, policy=self.policy)
            return h, new_kv

        x, new_kvs = jax.lax.scan(
            body, x, (params["dec_blocks"], (cache["k"], cache["v"])))
        logits = self._head(params, x)[:, 0]
        new_cache = {"k": new_kvs[0], "v": new_kvs[1], "memory": memory,
                     "pos": pos + 1}
        return logits, new_cache
