"""Shared model machinery: logical-axis sharding, init, norms, rotary.

Sharding follows the MaxText convention of *logical* axis names resolved
through a rules table (repro/parallel/sharding.py).  Layers call
``shard(x, ("act_batch", "act_seq", "act_embed"))``; with no mesh active
this is the identity, so the same model code runs in unit tests, the
multi-pod dry-run, and on real hardware.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import (ExecutionPolicy, LIBRARY_POLICY,
                                 resolve_policy)
from repro.parallel.sharding import ShardCtx, shard

# --------------------------------------------------------------------------
# Initialization
# --------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -2.0, 2.0, shape)
            ).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Parameter-layout accessors (ISSUE 5).  A parameter group that fuses may
# be stored either per-matrix (the legacy layout: "wq"/"wk"/"wv",
# "wi"/"wg") or concatenated (the fusion-legal layout planned by
# models.config.ParamLayout: one "wqkv"/"wig" tensor).  Every consumer
# reads through these two accessors, so model code is layout-agnostic:
# fused kernels take the whole tensor (free when persisted, a per-call
# concat tax on legacy params — exactly the tax the planner removes at
# decode), unfused math takes views/slices (zero-copy on either layout).
# --------------------------------------------------------------------------


def concat_param(params, cat_key: str, part_keys: Sequence[str]):
    """The whole concatenated tensor for a fused lowering.

    The persisted tensor when the layout planner placed one; otherwise a
    per-call last-axis concat of the legacy matrices (the pre-ISSUE-5
    behavior, kept so fusing policies still run on legacy checkpoints)."""
    if cat_key in params:
        return params[cat_key]
    return jnp.concatenate([params[k] for k in part_keys], axis=-1)


def split_param(params, cat_key: str, part_keys: Sequence[str],
                widths: Sequence[int]):
    """Per-matrix views for unfused math, on either stored layout.

    ``widths`` are the last-axis widths of the parts (needed only to
    slice the concatenated tensor; ignored on the legacy layout)."""
    if cat_key in params:
        w = params[cat_key]
        parts, off = [], 0
        for width in widths:
            parts.append(w[..., off:off + width])
            off += width
        return tuple(parts)
    return tuple(params[k] for k in part_keys)


def stored_concat(params, cat_key: str) -> bool:
    """Whether this parameter group is persisted in the concatenated
    layout — the decode-tick fusion gate: with the tensor at rest the
    fused call has zero weight-traffic overhead; on the legacy layout the
    per-call concat is a net loss at decode rows and the gate stays
    shut (the PR 4 behavior)."""
    return cat_key in params


# --------------------------------------------------------------------------
# Weight quantization (ISSUE 7).  The kernel layer owns the scheme
# (per-output-channel symmetric int8, kernels/fused.py); these re-exports
# plus `quantize_params` are the model-layer surface: scales ride the
# params tree as `<key>_scale` siblings of the (int8) weight leaves —
# the SAME persisted concats the layout planner owns, so the quantized
# decode tick still takes zero-copy views of tensors at rest.
# --------------------------------------------------------------------------


def quantize_weight(w):
    from repro.kernels.fused import quantize_weight as _qw
    return _qw(w)


def dequantize_weight(q, scale, dtype=jnp.float32):
    from repro.kernels.fused import dequantize_weight as _dw
    return _dw(q, scale, dtype)


#: the hot-pair weight leaves the precision policy quantizes, by block
#: subgroup: the layout planner's persisted concats plus attention's wo —
#: exactly the operands the three quantized fused lowerings consume.
QUANT_GROUPS = (("attn", ("wqkv", "wo")), ("mlp", ("wig",)))


def _quantize_group(sub, keys):
    sub = dict(sub)
    for key in keys:
        if key not in sub or sub[key].dtype == jnp.int8:
            continue
        q, s = quantize_weight(sub[key])
        sub[key] = q
        sub[key + "_scale"] = s
    return sub


def quantize_params(params):
    """Quantize the hot-pair weight leaves of a TransformerLM params tree
    (functionally): every ``blocks/attn/{wqkv,wo}`` and ``blocks/mlp/wig``
    leaf (plus a MoE shared expert's ``wig``) becomes int8 with an f32
    ``<key>_scale`` sibling.  Per-channel scales reduce over the input
    axis (``-2``), so stacked ``[L, d, n]`` leaves get ``[L, n]`` scales
    — per-layer scales in one vectorized pass.  Leaves already int8 are
    left alone.  Embeddings, norms, the lm head, and legacy per-matrix
    layouts stay f32: the quantized decode tick requires the persisted
    concats anyway — the same gate the fusion planner enforces."""
    blocks = dict(params["blocks"])
    for group, keys in QUANT_GROUPS:
        if group in blocks:
            blocks[group] = _quantize_group(blocks[group], keys)
    if "moe" in blocks and "shared" in blocks["moe"]:
        moe_p = dict(blocks["moe"])
        moe_p["shared"] = _quantize_group(moe_p["shared"], ("wig",))
        blocks["moe"] = moe_p
    return dict(params, blocks=blocks)


# --------------------------------------------------------------------------
# Norms / activations.  RMSNorm routes through the lowering registry
# (core/registry.py): the pure-jnp path is the registered `library`
# variant, so model norms no longer bypass the kernel layer — an
# ExecutionPolicy of abstract/abstract+shuffle/native/auto selects the
# corresponding Pallas lowering at every norm hot spot.
# --------------------------------------------------------------------------


def rmsnorm(x, weight, eps: float = 1e-6,
            policy: Optional[ExecutionPolicy] = None):
    from repro.kernels import ops as kernel_ops
    # explicit > ambient (use_policy) > the seed-equivalent XLA library
    # lowering (what model norms always were)
    return kernel_ops.rmsnorm(
        x, weight, eps=eps,
        policy=resolve_policy(policy=policy, default=LIBRARY_POLICY))


def rmsnorm_matmul(x, weight, w_proj, eps: float = 1e-6,
                   policy: Optional[ExecutionPolicy] = None,
                   w_scale=None):
    """The norm→projection hot pair: ``rmsnorm(x, weight) @ w_proj``.

    Policy-gated: when the resolved policy fuses (``fuse=True``, or
    ``mode="auto"`` by default), the pair lowers through the fused
    ``rmsnorm_matmul`` registry op and the normalized activation never
    makes the HBM round trip; otherwise the unfused sequence runs, which
    is bit-identical to the historical norm-then-einsum call sites.

    ``w_scale`` rides along when ``w_proj`` is an int8 leaf: fusing
    policies hand it to the quantized lowering (dequantize-in-VMEM);
    unfused policies dequantize up front — same math, staged at f32."""
    from repro.kernels import ops as kernel_ops
    pol = resolve_policy(policy=policy, default=LIBRARY_POLICY)
    if pol.fuses():
        # kernel-routed hot spot: dispatch under the policy's kernel view
        # (like the flash-attention path), so fuse_epilogues=True under
        # the default library-norm policy selects the fused Pallas
        # lowering instead of the library row (the unfused pair).
        return kernel_ops.fused_rmsnorm_matmul(x, weight, w_proj, eps=eps,
                                               policy=pol.kernel(),
                                               w_scale=w_scale)
    y = rmsnorm(x, weight, eps, policy=pol)
    if w_scale is not None:
        w_proj = dequantize_weight(w_proj, w_scale, y.dtype)
    return jnp.einsum("...d,dn->...n", y, w_proj.astype(y.dtype))


def rmsnorm_swiglu(x, weight, w_cat, eps: float = 1e-6,
                   policy: Optional[ExecutionPolicy] = None,
                   w_scale=None):
    """The norm→swiglu hot pair: ``silu(y @ wg) * (y @ wi)`` for
    ``y = rmsnorm(x, weight)``, ``w_cat`` the concatenated ``[wi|wg]``.

    Same gate as :func:`rmsnorm_matmul`: fused policies consume the
    normalized activation (and both projection products) from VMEM;
    unfused policies keep the historical norm-then-two-einsums sequence,
    bit-identical to the pre-fusion call sites.  ``w_scale`` (the int8
    concat's per-channel scales) follows the same split as the weights:
    fused lowerings dequantize blocks in VMEM, unfused math up front."""
    from repro.kernels import ops as kernel_ops
    pol = resolve_policy(policy=policy, default=LIBRARY_POLICY)
    if pol.fuses():
        return kernel_ops.fused_rmsnorm_swiglu(x, weight, w_cat, eps=eps,
                                               policy=pol.kernel(),
                                               w_scale=w_scale)
    y = rmsnorm(x, weight, eps, policy=pol)
    if w_scale is not None:
        w_cat = dequantize_weight(w_cat, w_scale, y.dtype)
    f = w_cat.shape[1] // 2
    hi = jnp.einsum("...d,df->...f", y, w_cat[:, :f].astype(y.dtype))
    hg = jnp.einsum("...d,df->...f", y, w_cat[:, f:].astype(y.dtype))
    return jax.nn.silu(hg) * hi


def add_rmsnorm(x, delta, weight, eps: float = 1e-6,
                policy: Optional[ExecutionPolicy] = None):
    """The residual→norm hot pair: ``(rmsnorm(x + delta), x + delta)``.

    Same gate as :func:`rmsnorm_matmul`: fused policies read both addends
    in the norm kernel's load stage (the staged sum is never read back
    from HBM); unfused policies keep the historical add-then-norm."""
    from repro.kernels import ops as kernel_ops
    pol = resolve_policy(policy=policy, default=LIBRARY_POLICY)
    if pol.fuses():
        return kernel_ops.fused_add_rmsnorm(x, delta, weight, eps=eps,
                                            policy=pol.kernel())
    s = x + delta
    return rmsnorm(s, weight, eps, policy=pol), s


def layernorm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


def apply_norm(x, params, kind: str, eps: float,
               policy: Optional[ExecutionPolicy] = None):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"], eps, policy=policy)
    return layernorm(x, params["scale"], params["bias"], eps)


def init_norm(key, d: int, kind: str, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_specs(kind: str):
    if kind == "rmsnorm":
        return {"scale": ("norm",)}
    return {"scale": ("norm",), "bias": ("norm",)}


def activation(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                     / head_dim)


def apply_rope(x, positions, theta: float):
    """x: [..., S, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------


def cross_entropy(logits, labels, ctx: Optional[ShardCtx] = None):
    """Token-mean cross entropy; logits [B,S,V] (vocab possibly sharded)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
