"""Feed-forward layers: dense (swiglu / gelu) and Mixture-of-Experts.

MoE uses GShard-style capacity-based routing with one-hot dispatch/combine
einsums (baseline; simple, SPMD-friendly, paper-faithful in spirit — it is
the 'abstract' formulation of dispatch).  The §Perf hillclimb for the MoE
cell replaces it with sort-based grouped dispatch (see EXPERIMENTS.md).
Experts are sharded on the ``model`` axis (EP).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.config import (LEGACY_LAYOUT, ModelConfig, MoEConfig,
                                 ParamLayout)
from repro.parallel.sharding import ShardCtx, shard


# --------------------------------------------------------------------------
# Dense MLP
# --------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, act: str, dtype,
             layout: ParamLayout = LEGACY_LAYOUT):
    ks = jax.random.split(key, 3)
    params = {"wo": common.dense_init(ks[1], (d_ff, d), 0, dtype)}
    specs = {"wo": ("mlp", "embed")}
    wi = common.dense_init(ks[0], (d, d_ff), 0, dtype)
    if act == "silu":                       # swiglu gate
        wg = common.dense_init(ks[2], (d, d_ff), 0, dtype)
        if layout.mlp_swiglu:
            # the fusion-legal layout: [wi|wg] persisted as one tensor,
            # consumed whole by the fused norm→swiglu lowering and as
            # views by the unfused einsums (models/common.py accessors)
            params["wig"] = jnp.concatenate([wi, wg], axis=1)
            specs["wig"] = ("embed", "mlp")
        else:
            params.update(wi=wi, wg=wg)
            specs.update(wi=("embed", "mlp"), wg=("embed", "mlp"))
    else:
        params["wi"] = wi
        specs["wi"] = ("embed", "mlp")
    return params, specs


def _wi_wg(params):
    """The (wi, wg) views on either stored layout."""
    if "wig" in params:
        f = params["wig"].shape[-1] // 2
        return common.split_param(params, "wig", ("wi", "wg"), (f, f))
    return params["wi"], params["wg"]


def apply_mlp(params, x, act: str, ctx: Optional[ShardCtx],
              policy=None, norm_scale=None, eps: float = 1e-6):
    """Position-wise MLP.  With ``norm_scale`` set, ``x`` is the *raw*
    residual stream and the pre-MLP rmsnorm rides into the projections —
    for swiglu as one fused call against the concatenated ``[wi|wg]``
    weight with the silu gate applied in the epilogue (kernels/fused.py),
    mirroring PR 3's q/k/v ``norm_scale`` threading.  Either parameter
    layout works on either path: the fused call takes the persisted
    ``wig`` when the layout planner placed one (a per-call concat
    otherwise), the unfused einsums take views."""
    if norm_scale is not None:
        if act == "silu":
            w_cat = common.concat_param(params, "wig", ("wi", "wg"))
            h = common.rmsnorm_swiglu(x, norm_scale, w_cat, eps,
                                      policy=policy,
                                      w_scale=params.get("wig_scale"))
        else:
            # no gate pair to fuse into: the norm rides into the single
            # wi projection as a GEMM prologue instead
            h = common.rmsnorm_matmul(x, norm_scale, params["wi"], eps,
                                      policy=policy)
            h = common.activation(h, act)
    else:
        if act == "silu":
            if "wig_scale" in params:
                # int8 concat on the unfused path: dequantize once, then
                # take the usual views (only the persisted concat is ever
                # quantized — see common.quantize_params)
                params = dict(params, wig=common.dequantize_weight(
                    params["wig"], params["wig_scale"], x.dtype))
            wi, wg = _wi_wg(params)
            h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
            gate = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
            h = jax.nn.silu(gate) * h
        else:
            h = jnp.einsum("bsd,df->bsf", x,
                           params["wi"].astype(x.dtype))
            h = common.activation(h, act)
    h = shard(h, ("act_batch", "act_seq_unsharded", "act_mlp"), ctx)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------


def init_moe(key, d: int, d_ff: int, moe: MoEConfig, act: str, dtype,
             layout: ParamLayout = LEGACY_LAYOUT):
    ks = jax.random.split(key, 5)
    e = moe.num_experts
    params = {
        "router": common.dense_init(ks[0], (d, e), 0, jnp.float32),
        "wi": common.dense_init(ks[1], (e, d, d_ff), 1, dtype),
        "wg": common.dense_init(ks[2], (e, d, d_ff), 1, dtype),
        "wo": common.dense_init(ks[3], (e, d_ff, d), 1, dtype),
    }
    specs = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "expert_mlp"),
        "wg": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    if moe.shared_experts:
        # the shared expert is a dense MLP and rides the layout plan; the
        # routed expert stacks stay per-matrix — the grouped dispatch
        # einsums consume wi/wg separately and never fuse
        shared, sspecs = init_mlp(ks[4], d, d_ff * moe.shared_experts,
                                  act, dtype, layout)
        params["shared"] = shared
        specs["shared"] = sspecs
    return params, specs


def _capacity(group_size: int, moe: MoEConfig) -> int:
    cap = int(group_size * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(8, ((cap + 7) // 8) * 8)  # sublane-aligned


def route(logits, moe: MoEConfig):
    """Top-k routing with capacity truncation.

    logits: [G, S, E] -> dispatch one-hot [G, S, E, C] and combine weights
    [G, S, E, C].  Position within an expert's capacity buffer = cumsum of
    prior assignments (deterministic, in-order truncation).
    """
    g, s, e = logits.shape
    c = _capacity(s, moe)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_ix = jax.lax.top_k(gates, moe.top_k)        # [G,S,K]
    if moe.top_k > 1:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # expert one-hot per routing slot: [G,S,K,E]
    onehot = jax.nn.one_hot(top_ix, e, dtype=jnp.float32)
    # position of each (token, slot) in its expert's buffer
    flat = onehot.reshape(g, s * moe.top_k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                  # [G,S*K,E]
    pos = pos.reshape(g, s, moe.top_k, e)
    within = jnp.sum(pos * onehot, axis=-1)                # [G,S,K]
    keep = within < c
    w = top_w * keep

    cap_onehot = jax.nn.one_hot(within.astype(jnp.int32), c,
                                dtype=jnp.float32)         # [G,S,K,C]
    dispatch = jnp.einsum("gske,gskc->gsec", onehot * keep[..., None],
                          cap_onehot)
    combine = jnp.einsum("gske,gskc->gsec", onehot * w[..., None],
                         cap_onehot)
    aux = _load_balance_loss(gates, onehot)
    return dispatch, combine, aux


def _load_balance_loss(gates, onehot):
    """Switch-style auxiliary load-balancing loss."""
    me = jnp.mean(gates, axis=(0, 1))                      # [E]
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))    # [E]
    return jnp.sum(me * ce) * gates.shape[-1]


def apply_moe(params, x, moe: MoEConfig, act: str,
              ctx: Optional[ShardCtx], policy=None, norm_scale=None,
              eps: float = 1e-6) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (y, aux_loss).

    With ``norm_scale`` set, ``x`` is the raw residual: the router and
    expert dispatch need the normalized stream explicitly (it feeds the
    routing einsum), so it is computed here through the registry norm,
    while the shared-expert path threads ``norm_scale`` down to
    :func:`apply_mlp` and fuses its own ln2→[wi|wg] pair against the raw
    stream."""
    x_raw = x
    if norm_scale is not None:
        x = common.rmsnorm(x, norm_scale, eps, policy=policy)
    b, s, d = x.shape
    tokens = b * s
    gsz = min(moe.group_size, tokens)
    flat = x.reshape(tokens, d)
    pad = (-tokens) % gsz
    if pad:                      # zero-pad to a whole number of groups
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    n_groups = flat.shape[0] // gsz
    xg = flat.reshape(n_groups, gsz, d)
    xg = shard(xg, ("act_group", "act_seq_unsharded", "act_embed"), ctx)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        params["router"])
    dispatch, combine, aux = route(logits, moe)

    # dispatch: [G,S,E,C] @ [G,S,D] -> [G,E,C,D]
    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    expert_in = shard(expert_in, ("act_group", "act_experts",
                                  "act_capacity", "act_embed"), ctx)
    h = jnp.einsum("gecd,edf->gecf", expert_in,
                   params["wi"].astype(x.dtype))
    gate = jnp.einsum("gecd,edf->gecf", expert_in,
                      params["wg"].astype(x.dtype))
    h = jax.nn.silu(gate) * h
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(x.dtype))
    out = shard(out, ("act_group", "act_experts", "act_capacity",
                      "act_embed"), ctx)
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), out)
    y = y.reshape(-1, d)[:tokens].reshape(b, s, d)
    if moe.shared_experts:
        if norm_scale is not None:
            y = y + apply_mlp(params["shared"], x_raw, act, ctx,
                              policy=policy, norm_scale=norm_scale,
                              eps=eps)
        else:
            y = y + apply_mlp(params["shared"], x, act, ctx)
    return y, aux
