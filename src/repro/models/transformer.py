"""Decoder-only transformer LM (dense / MoE / VLM backbone).

Covers: llama4-scout (MoE top-1), granite-moe (MoE top-8), mistral-nemo,
granite-8b, qwen3 (qk_norm), mistral-large, and the llava backbone (text
decoder over a stub patch-embedding prefix).

Layers are scanned (stacked params) so the lowered HLO is one block's
program — essential for 512-device dry-run compile times.  Remat policy
and sharding constraints follow ParallelConfig.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import ExecutionPolicy
from repro.models import common, mlp
from repro.models.attention import (chunked_attention, decode_attention,
                                    dequantize_kv, paged_decode_attention,
                                    quantize_kv, update_cache,
                                    update_cache_int8, update_paged_cache,
                                    update_paged_cache_int8)
from repro.models.config import (LEGACY_LAYOUT, ModelConfig, ParallelConfig,
                                 ParamLayout)
from repro.parallel.sharding import ShardCtx, shard


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# Per-block params
# --------------------------------------------------------------------------


def _qkv_widths(cfg: ModelConfig):
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return (h * hd, hkv * hd, hkv * hd)


def init_attn(key, cfg: ModelConfig, dtype,
              layout: ParamLayout = LEGACY_LAYOUT):
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    # the three projections draw from the same keys on either layout, so
    # the two layouts of one seed are the same weights (migration and the
    # fusion-equivalence tests rely on it)
    wq = common.dense_init(ks[0], (d, h * hd), 0, dtype)
    wk = common.dense_init(ks[1], (d, hkv * hd), 0, dtype)
    wv = common.dense_init(ks[2], (d, hkv * hd), 0, dtype)
    params = {"wo": common.dense_init(ks[3], (h * hd, d), 0, dtype)}
    specs = {"wo": ("q_heads", "embed")}
    if layout.attn_qkv:
        params["wqkv"] = jnp.concatenate([wq, wk, wv], axis=1)
        specs["wqkv"] = ("embed", "qkv_heads")
    else:
        params.update(wq=wq, wk=wk, wv=wv)
        specs.update(wq=("embed", "q_heads"), wk=("embed", "kv_heads"),
                     wv=("embed", "kv_heads"))
    if cfg.qk_norm:
        params["q_norm"] = jnp.ones((hd,), dtype)
        params["k_norm"] = jnp.ones((hd,), dtype)
        specs["q_norm"] = ("head_dim",)
        specs["k_norm"] = ("head_dim",)
    return params, specs


def init_block(key, cfg: ModelConfig, dtype,
               layout: ParamLayout = LEGACY_LAYOUT):
    ks = jax.random.split(key, 4)
    attn, attn_specs = init_attn(ks[0], cfg, dtype, layout)
    params = {"attn": attn,
              "ln1": common.init_norm(ks[2], cfg.d_model, cfg.norm, dtype),
              "ln2": common.init_norm(ks[3], cfg.d_model, cfg.norm, dtype)}
    specs = {"attn": attn_specs,
             "ln1": common.norm_specs(cfg.norm),
             "ln2": common.norm_specs(cfg.norm)}
    if cfg.moe is not None:
        params["moe"], specs["moe"] = mlp.init_moe(
            ks[1], cfg.d_model, cfg.d_ff, cfg.moe, cfg.act, dtype, layout)
    else:
        params["mlp"], specs["mlp"] = mlp.init_mlp(
            ks[1], cfg.d_model, cfg.d_ff, cfg.act, dtype, layout)
    return params, specs


# --------------------------------------------------------------------------
# Attention sublayer
# --------------------------------------------------------------------------


def _project_qkv(params, x, cfg: ModelConfig, positions, ctx,
                 constrain_kv: bool = True, policy=None, norm_scale=None):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if norm_scale is not None:
        # The pre-attention norm rides into the projection as a fused
        # GEMM prologue (x is the *raw* residual here): the normalized
        # activation is consumed from VMEM, never staged to HBM.  One
        # call against the concatenated [wq|wk|wv] — the persisted tensor
        # when the layout planner placed one, a per-call concat on legacy
        # params — so the residual is read and the moment computed once
        # per sublayer, not thrice.
        w_qkv = common.concat_param(params, "wqkv", ("wq", "wk", "wv"))
        qkv = common.rmsnorm_matmul(x, norm_scale, w_qkv,
                                    cfg.norm_eps, policy=policy,
                                    w_scale=params.get("wqkv_scale"))
        q, k, v = jnp.split(qkv, [h * hd, (h + hkv) * hd], axis=-1)
    else:
        if "wqkv_scale" in params:
            # int8 concat on the unfused path: dequantize once, then take
            # the usual per-matrix views (only the persisted concat is
            # ever quantized — see common.quantize_params)
            params = dict(params, wqkv=common.dequantize_weight(
                params["wqkv"], params["wqkv_scale"], x.dtype))
        wq, wk, wv = common.split_param(params, "wqkv", ("wq", "wk", "wv"),
                                        _qkv_widths(cfg))
        q = jnp.einsum("bsd,dh->bsh", x, wq.astype(x.dtype))
        k = jnp.einsum("bsd,dh->bsh", x, wk.astype(x.dtype))
        v = jnp.einsum("bsd,dh->bsh", x, wv.astype(x.dtype))
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = common.rmsnorm(q, params["q_norm"], cfg.norm_eps, policy=policy)
        k = common.rmsnorm(k, params["k_norm"], cfg.norm_eps, policy=policy)
    if cfg.pos_emb == "rope":
        q = common.apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = common.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    q = shard(q, ("act_batch", "act_heads", "act_seq_unsharded",
                  "act_head_dim"), ctx)
    if constrain_kv:
        # Baseline layout.  When num_kv_heads < model-axis size this
        # forces a [shard,replica] representation that every consumer
        # re-gathers — the constrain_kv_pre_repeat=False §Perf lever
        # skips it and lets propagation keep K/V in the producer layout.
        k = shard(k, ("act_batch", "act_kv_heads", "act_seq_unsharded",
                      "act_head_dim"), ctx)
        v = shard(v, ("act_batch", "act_kv_heads", "act_seq_unsharded",
                      "act_head_dim"), ctx)
    return q, k, v


def _wo_weight(params, dtype):
    """The output projection at math width: dequantized when the
    precision policy stored it int8 (unfused paths only — fused lowerings
    take the int8 leaf + scale and dequantize blocks in VMEM)."""
    if "wo_scale" in params:
        return common.dequantize_weight(params["wo"], params["wo_scale"],
                                        dtype)
    return params["wo"].astype(dtype)


def _repeat_kv(k, v, group: int, ctx):
    """Materialize GQA groups so the attention compute is uniformly
    head-sharded.

    With num_kv_heads < mesh 'model' size, a [B,Hkv,S,D] operand forces
    GSPMD into [shard,replica] <-> [full-shard] transitions *inside* the
    attention chunk scans — one involuntary all-gather per chunk step per
    layer (~10 TB/chip/step at qwen3 scale; see EXPERIMENTS.md §Perf).
    Repeating KV to H heads costs only the repeated chunk in VMEM-scale
    activation memory but makes every attention tensor share one clean
    16-way head sharding.  The *cache* keeps the un-repeated [B,Hkv,S,D]
    layout — this is a compute-layout choice, not a memory-layout one.
    """
    if group == 1:
        return k, v
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    k = shard(k, ("act_batch", "act_heads", "act_seq_unsharded",
                  "act_head_dim"), ctx)
    v = shard(v, ("act_batch", "act_heads", "act_seq_unsharded",
                  "act_head_dim"), ctx)
    return k, v


def attn_seq(params, x, cfg: ModelConfig, par: ParallelConfig,
             positions, ctx, causal: bool = True,
             return_kv: bool = False, policy=None, norm_scale=None):
    """Full-sequence attention (train / prefill).

    With ``norm_scale`` set, ``x`` is the raw residual and the
    pre-attention rmsnorm fuses into the q/k/v projections."""
    b, s, d = x.shape
    policy = policy or par.execution_policy()
    q, k, v = _project_qkv(params, x, cfg, positions, ctx,
                           constrain_kv=par.constrain_kv_pre_repeat,
                           policy=policy, norm_scale=norm_scale)
    k_rep, v_rep = _repeat_kv(k, v, cfg.num_heads // cfg.num_kv_heads, ctx)
    if par.use_pallas_attn:
        # TPU execution path: the framework's own flash kernel.  The
        # variant comes from the threaded policy's kernel view — the
        # registry, not this call site, decides the lowering.
        from repro.kernels import ops as kernel_ops
        if policy.fuses():
            # Fused epilogue: the wo projection consumes the online-
            # softmax accumulator in VMEM (kernels/fused.py) — the
            # [B,S,H,D] attention output never round-trips through HBM.
            out = kernel_ops.fused_flash_attention_matmul(
                q, k_rep, v_rep, params["wo"], causal=causal,
                block_q=min(par.attn_chunk_q, 256),
                block_kv=min(par.attn_chunk_kv, 256),
                policy=policy.kernel(),
                w_scale=params.get("wo_scale"))
        else:
            o = kernel_ops.flash_attention(
                q, k_rep, v_rep, causal=causal,
                block_q=min(par.attn_chunk_q, 256),
                block_kv=min(par.attn_chunk_kv, 256),
                policy=policy.kernel())
            o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
            out = jnp.einsum("bsh,hd->bsd", o, _wo_weight(params, x.dtype))
    else:
        o = chunked_attention(
            q, k_rep, v_rep, causal=causal, kv_offset=0,
            chunk_q=par.attn_chunk_q, chunk_kv=par.attn_chunk_kv,
            exact_causal=par.causal_folding, ctx=ctx)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
        out = jnp.einsum("bsh,hd->bsd", o, _wo_weight(params, x.dtype))
    if par.rs_outputs:
        # Constrain the row-parallel partial sum to the seq-sharded
        # residual layout so the TP combine compiles to reduce-scatter.
        out = shard(out, ("act_batch", "act_seq", "act_embed"), ctx)
    if return_kv:
        return out, (k, v)
    return out


def attn_decode(params, x_t, cfg: ModelConfig, kv_cache, pos, ctx,
                int8: bool = False, policy=None, norm_scale=None,
                fuse_wo: bool = False, block_tables=None):
    """One-token attention. x_t: [B,1,D]; kv_cache: (K,V) [B,Hkv,S,hd]
    (bf16) or (Kq,Ks,Vq,Vs) (int8 + scales).

    With ``norm_scale`` set, ``x_t`` is the raw residual and the
    pre-attention rmsnorm fuses into the q/k/v projections — decode-legal
    only because the caller verified the concatenated ``wqkv`` is
    *persisted* (zero weight-traffic overhead; see block_decode's gate).
    ``fuse_wo`` routes the cache attention + wo projection through the
    decode shape of ``flash_attention_matmul`` (per-slot ``pos``
    frontiers mask the cache), eliminating the `[B,1,H,D]` attention
    output round trip per layer per tick.

    ``block_tables`` switches the cache to its *paged* form: kv_cache is
    (k_pages, v_pages) ``[P, Hkv, page_size, hd]`` pools and the table
    maps each slot's logical kv blocks to pool pages.  The one-token
    write scatters through the table (sentinel entries drop), and the
    fused path hands the table to the paged decode shape of
    ``flash_attention_matmul`` so the kernel only visits live pages."""
    b = x_t.shape[0]
    positions = pos[:, None]                       # [B,1]
    q, k_new, v_new = _project_qkv(params, x_t, cfg, positions, ctx,
                                   policy=policy, norm_scale=norm_scale)
    if block_tables is not None:
        if int8:
            # int8 paged cache: quantize-on-write through the same table
            # scatter, per-page scales riding parallel [P,Hkv,ps,1] pools.
            k_pages, k_sc, v_pages, v_sc = kv_cache
            k_pages, k_sc = update_paged_cache_int8(k_pages, k_sc, k_new,
                                                    block_tables, pos)
            v_pages, v_sc = update_paged_cache_int8(v_pages, v_sc, v_new,
                                                    block_tables, pos)
            new_cache = (k_pages, k_sc, v_pages, v_sc)
        else:
            k_pages, v_pages = kv_cache
            k_pages = update_paged_cache(k_pages, k_new, block_tables, pos)
            v_pages = update_paged_cache(v_pages, v_new, block_tables, pos)
            k_sc = v_sc = None
            new_cache = (k_pages, v_pages)
        if fuse_wo:
            from repro.kernels import ops as kernel_ops
            out = kernel_ops.fused_flash_attention_matmul(
                q, k_pages, v_pages, params["wo"], pos=pos,
                block_tables=block_tables,
                policy=policy.kernel() if policy is not None else None,
                w_scale=params.get("wo_scale"), k_scale=k_sc, v_scale=v_sc)
            return out, new_cache
        if int8:
            # unfused reference path: dequantize the gathered-from pools
            # up front (the fused kernel instead dequantizes per page, in
            # VMEM, only for live table entries)
            k_pages = dequantize_kv(k_pages, k_sc, x_t.dtype)
            v_pages = dequantize_kv(v_pages, v_sc, x_t.dtype)
        o = paged_decode_attention(q, k_pages, v_pages, block_tables, pos,
                                   ctx=ctx)
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
        out = jnp.einsum("bsh,hd->bsd", o, _wo_weight(params, x_t.dtype))
        return out, new_cache
    if int8:
        k_q, k_s, v_q, v_s = kv_cache
        k_q, k_s = update_cache_int8(k_q, k_s, k_new, pos)
        v_q, v_s = update_cache_int8(v_q, v_s, v_new, pos)
        k_cache = dequantize_kv(k_q, k_s, x_t.dtype)
        v_cache = dequantize_kv(v_q, v_s, x_t.dtype)
        new_cache = (k_q, k_s, v_q, v_s)
    else:
        k_cache, v_cache = kv_cache
        k_cache = update_cache(k_cache, k_new, pos)
        v_cache = update_cache(v_cache, v_new, pos)
        new_cache = (k_cache, v_cache)
    if fuse_wo:
        from repro.kernels import ops as kernel_ops
        out = kernel_ops.fused_flash_attention_matmul(
            q, k_cache, v_cache, params["wo"], pos=pos,
            policy=policy.kernel() if policy is not None else None,
            w_scale=params.get("wo_scale"))
        return out, new_cache
    o = decode_attention(q, k_cache, v_cache, pos, ctx=ctx)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", o, _wo_weight(params, x_t.dtype))
    return out, new_cache


# --------------------------------------------------------------------------
# Block
# --------------------------------------------------------------------------


def block_seq(params, x, cfg: ModelConfig, par: ParallelConfig, positions,
              ctx, return_kv: bool = False, policy=None):
    policy = policy or par.execution_policy()
    # Fused-epilogue routing (policy-gated): the ln1→projection pair fuses
    # into the q/k/v GEMMs and the residual→ln2 pair into one kernel —
    # the two per-sublayer activation round trips the unfused sequence
    # stages through HBM (see kernels/fused.py).
    fuse = policy.fuses() and cfg.norm == "rmsnorm"
    if fuse:
        h, norm_scale = x, params["ln1"]["scale"]
    else:
        h = common.apply_norm(x, params["ln1"], cfg.norm, cfg.norm_eps,
                              policy=policy)
        norm_scale = None
    if return_kv:
        a, kv = attn_seq(params["attn"], h, cfg, par, positions, ctx,
                         return_kv=True, policy=policy,
                         norm_scale=norm_scale)
    else:
        a = attn_seq(params["attn"], h, cfg, par, positions, ctx,
                     policy=policy, norm_scale=norm_scale)
        kv = None
    # ln2 routing: when a fusable wi/wg pair sits downstream (dense silu
    # MLP, or a MoE with shared experts), the fused path keeps the
    # residual RAW — ln2 rides into the wi/wg projections as a fused
    # prologue (rmsnorm_swiglu saves the full norm round trip, strictly
    # more than add_rmsnorm's read-back leg).  Otherwise (gelu MLPs, MoE
    # without shared experts — the router path needs the norm explicitly
    # and there is no dense pair to absorb it) the PR 3 residual→norm
    # fusion stays.
    swiglu_fuse = (fuse and cfg.act == "silu"
                   and (cfg.moe is None or bool(cfg.moe.shared_experts)))
    if swiglu_fuse:
        x = x + a
        h, mlp_scale = x, params["ln2"]["scale"]
    elif fuse:
        h, x = common.add_rmsnorm(x, a, params["ln2"]["scale"],
                                  cfg.norm_eps, policy=policy)
        mlp_scale = None
    else:
        x = x + a
        h = common.apply_norm(x, params["ln2"], cfg.norm, cfg.norm_eps,
                              policy=policy)
        mlp_scale = None
    if cfg.moe is not None:
        m, aux = mlp.apply_moe(params["moe"], h, cfg.moe, cfg.act, ctx,
                               policy=policy, norm_scale=mlp_scale,
                               eps=cfg.norm_eps)
    else:
        m, aux = mlp.apply_mlp(params["mlp"], h, cfg.act, ctx,
                               policy=policy, norm_scale=mlp_scale,
                               eps=cfg.norm_eps), 0.0
    if par.rs_outputs:
        m = shard(m, ("act_batch", "act_seq", "act_embed"), ctx)
    x = x + m
    x = shard(x, ("act_batch", "act_seq", "act_embed"), ctx)
    return (x, aux, kv) if return_kv else (x, aux)


def block_decode(params, x_t, cfg: ModelConfig, kv_cache, pos, ctx,
                 int8: bool = False, policy=None, fuse_wo: bool = False,
                 block_tables=None):
    fuse = (policy is not None and policy.fuses()
            and cfg.norm == "rmsnorm")
    # Decode fusion gates (ISSUE 5): the qkv / ln2→[wi|wg] prologues fuse
    # exactly when the concatenated tensor is *persisted* (the ParamLayout
    # planner's init-time choice) — then the fused call reads the same
    # weight bytes the unfused sequence would and the activation round
    # trip is a pure saving.  On legacy per-matrix params the per-call
    # concat materializes a weight-sized tensor to save a token-sized
    # round trip (rows = B) — a net traffic loss — so the gates stay shut,
    # which is exactly the PR 4 behavior.  The activation-sized
    # residual→norm fusion has no weight term and is layout-independent.
    qkv_fuse = fuse and common.stored_concat(params["attn"], "wqkv")
    if qkv_fuse:
        h, ln1_scale = x_t, params["ln1"]["scale"]
    else:
        h = common.apply_norm(x_t, params["ln1"], cfg.norm, cfg.norm_eps,
                              policy=policy)
        ln1_scale = None
    a, kv_cache = attn_decode(params["attn"], h, cfg, kv_cache, pos, ctx,
                              int8=int8, policy=policy,
                              norm_scale=ln1_scale, fuse_wo=fuse_wo,
                              block_tables=block_tables)
    if cfg.moe is None:
        mlp_params = params["mlp"]
    elif cfg.moe.shared_experts:
        mlp_params = params["moe"]["shared"]
    else:
        mlp_params = {}                  # router-only MoE: no fusable pair
    swiglu_fuse = (fuse and cfg.act == "silu"
                   and common.stored_concat(mlp_params, "wig"))
    if swiglu_fuse:
        x_t = x_t + a
        h, mlp_scale = x_t, params["ln2"]["scale"]
    elif fuse:
        h, x_t = common.add_rmsnorm(x_t, a, params["ln2"]["scale"],
                                    cfg.norm_eps, policy=policy)
        mlp_scale = None
    else:
        x_t = x_t + a
        h = common.apply_norm(x_t, params["ln2"], cfg.norm, cfg.norm_eps,
                              policy=policy)
        mlp_scale = None
    if cfg.moe is not None:
        m, _ = mlp.apply_moe(params["moe"], h, cfg.moe, cfg.act, ctx,
                             policy=policy, norm_scale=mlp_scale,
                             eps=cfg.norm_eps)
    else:
        m = mlp.apply_mlp(params["mlp"], h, cfg.act, ctx, policy=policy,
                          norm_scale=mlp_scale, eps=cfg.norm_eps)
    return x_t + m, kv_cache


# --------------------------------------------------------------------------
# Model
# --------------------------------------------------------------------------


class TransformerLM:
    """Functional decoder-only LM with scanned layers."""

    def __init__(self, cfg: ModelConfig, par: ParallelConfig,
                 ctx: Optional[ShardCtx] = None,
                 policy: Optional[ExecutionPolicy] = None):
        self.cfg = cfg
        self.par = par
        self.ctx = ctx
        # the lowering policy every hot spot below threads (resolved ONCE)
        self.policy = policy or par.execution_policy()
        # the parameter layout the policy earns (resolved ONCE, at init —
        # the fusion-legality decision made where it is free, at rest).
        # Consumers stay layout-agnostic via the common.py accessors, so
        # params initialized under either plan still run under either
        # policy; only *this* model's init_params/param_specs emit the
        # planned layout.
        self.param_layout = ParamLayout.plan(cfg, self.policy)
        self.aux_weight = 0.01 if cfg.moe is not None else 0.0

    def with_policy(self, policy: ExecutionPolicy) -> "TransformerLM":
        return type(self)(self.cfg, self.par, self.ctx, policy=policy)

    # ---- params ----

    def init_params(self, rng):
        cfg = self.cfg
        dtype = _dtype(cfg)
        k_embed, k_blocks, k_out, k_norm = jax.random.split(rng, 4)
        block_keys = jax.random.split(k_blocks, cfg.num_layers)
        layout = self.param_layout
        blocks = jax.vmap(
            lambda k: init_block(k, cfg, dtype, layout)[0])(block_keys)
        params = {
            "embed": common.embed_init(k_embed,
                                       (cfg.vocab_size, cfg.d_model)),
            "blocks": blocks,
            "final_norm": common.init_norm(k_norm, cfg.d_model, cfg.norm,
                                           dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(
                k_out, (cfg.d_model, cfg.vocab_size), 0, dtype)
        return params

    def param_specs(self):
        cfg = self.cfg
        _, block_specs = init_block(jax.random.PRNGKey(0), cfg, jnp.float32,
                                    self.param_layout)
        # scanned leading 'layers' axis is unsharded
        block_specs = jax.tree.map(lambda ax: (None,) + ax, block_specs,
                                   is_leaf=lambda x: isinstance(x, tuple))
        specs = {
            "embed": ("vocab", "embed"),
            "blocks": block_specs,
            "final_norm": common.norm_specs(cfg.norm),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = ("embed", "vocab")
        return specs

    # ---- embedding / head ----

    def _embed(self, params, tokens, batch):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
        if cfg.family == "vlm" and "patch_embeds" in batch:
            patches = batch["patch_embeds"].astype(_dtype(cfg))
            x = jnp.concatenate([patches, x], axis=1)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        return shard(x, ("act_batch", "act_seq_unsharded", "act_embed"),
                     self.ctx)

    def _head(self, params, x):
        cfg = self.cfg
        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        if cfg.norm == "rmsnorm":
            # the final norm→lm_head pair: fused (policy-gated) or the
            # historical norm-then-einsum, decided in one place
            logits = common.rmsnorm_matmul(
                x, params["final_norm"]["scale"], w, cfg.norm_eps,
                policy=self.policy)
        else:
            x = common.apply_norm(x, params["final_norm"], cfg.norm,
                                  cfg.norm_eps, policy=self.policy)
            logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        return shard(logits.astype(jnp.float32),
                     ("act_batch", "act_seq_unsharded", "act_vocab"),
                     self.ctx)

    # ---- layer stack ----

    def _scan_blocks(self, params, x, positions, return_kv=False):
        cfg, par, ctx = self.cfg, self.par, self.ctx
        policy = self.policy

        def body(carry, layer_params):
            h, aux = carry
            if return_kv:
                h, a, kv = block_seq(layer_params, h, cfg, par, positions,
                                     ctx, return_kv=True, policy=policy)
                return (h, aux + a), kv
            h, a = block_seq(layer_params, h, cfg, par, positions, ctx,
                             policy=policy)
            return (h, aux + a), None

        if par.remat == "full":
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        elif par.remat == "dots":
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        (x, aux), kvs = jax.lax.scan(body, (x, 0.0), params["blocks"])
        return x, aux, kvs

    # ---- public API ----

    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        x = self._embed(params, tokens, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))
        x, aux, _ = self._scan_blocks(params, x, positions)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = x[:, batch["patch_embeds"].shape[1]:]
        logits = self._head(params, x)
        loss = common.cross_entropy(logits, labels, self.ctx)
        total = loss + self.aux_weight * aux / max(cfg.num_layers, 1)
        return total, {"ce_loss": loss, "aux_loss": aux}

    def prefill(self, params, batch):
        """Full forward building a decode cache; returns last-pos logits."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))
        x, _, kvs = self._scan_blocks(params, x, positions, return_kv=True)
        logits = self._head(params, x[:, -1:, :])
        pos = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
        if self.par.kv_cache_int8:
            k_q, k_s = quantize_kv(kvs[0])
            v_q, v_s = quantize_kv(kvs[1])
            cache = {"k": k_q, "k_scale": k_s, "v": v_q, "v_scale": v_s,
                     "pos": pos}
        else:
            cache = {"k": kvs[0], "v": kvs[1], "pos": pos}
        return logits[:, 0], cache

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (cfg.num_layers, batch_size, hkv, cache_len, hd)
        if self.par.kv_cache_int8:
            sshape = shape[:-1] + (1,)
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.full(sshape, 1e-8, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.full(sshape, 1e-8, jnp.float32),
                "pos": jnp.zeros((batch_size,), jnp.int32),
            }
        return {
            "k": jnp.zeros(shape, _dtype(cfg)),
            "v": jnp.zeros(shape, _dtype(cfg)),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def init_paged_cache(self, batch_size: int, num_pages: int,
                         page_size: int, max_pages_per_slot: int):
        """The paged form of :meth:`init_cache`: fixed-size KV pages plus
        per-slot block tables (capacity by pages, not slots).

        Pools are ``[L, P, Hkv, page_size, hd]`` — the page index axis is
        shared across layers, so one table serves the whole scan.  Tables
        init to the sentinel ``num_pages`` (out of range): a write
        through a sentinel entry drops and a gather clamps onto a page
        the ``pos`` mask hides, which is what makes reaped slots inert
        inside the one-program tick.  Allocation/refcounts live in
        ``repro.serve.engine.PagePool``."""
        cfg = self.cfg
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (cfg.num_layers, num_pages, hkv, page_size, hd)
        tables = jnp.full((batch_size, max_pages_per_slot), num_pages,
                          jnp.int32)
        pos = jnp.zeros((batch_size,), jnp.int32)
        if self.par.kv_cache_int8:
            # int8 pools + per-(token,head) f32 scale pools riding the
            # same page index axis — a page costs hd + 4 bytes per token
            # per head instead of 4*hd, which is where the PagePool
            # capacity multiplier comes from (serve/engine.py).
            sshape = shape[:-1] + (1,)
            return {
                "k_pages": jnp.zeros(shape, jnp.int8),
                "k_scale_pages": jnp.full(sshape, 1e-8, jnp.float32),
                "v_pages": jnp.zeros(shape, jnp.int8),
                "v_scale_pages": jnp.full(sshape, 1e-8, jnp.float32),
                "block_tables": tables,
                "pos": pos,
            }
        return {
            "k_pages": jnp.zeros(shape, _dtype(cfg)),
            "v_pages": jnp.zeros(shape, _dtype(cfg)),
            "block_tables": tables,
            "pos": pos,
        }

    def cache_specs(self):
        kv = (None, "act_cache_batch", "act_kv_heads", "act_kv_seq",
              "act_head_dim")
        if self.par.kv_cache_int8:
            sc = (None, "act_cache_batch", "act_kv_heads", "act_kv_seq",
                  None)
            return {"k": kv, "k_scale": sc, "v": kv, "v_scale": sc,
                    "pos": (None,)}
        return {"k": kv, "v": kv, "pos": (None,)}

    def decode_step(self, params, tokens, cache):
        """tokens: [B] int32 -> (logits [B,V], new cache).

        A cache carrying ``block_tables`` routes through the paged decode
        path: per-layer (k_pages, v_pages) pools ride the scan while the
        table and ``pos`` frontier broadcast — same one-program shape,
        page-gathered attention."""
        cfg, ctx = self.cfg, self.ctx
        int8 = self.par.kv_cache_int8
        paged = "block_tables" in cache
        tables = cache["block_tables"] if paged else None
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens[:, None], axis=0
                     ).astype(_dtype(cfg))
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        # the decode shape of the flash→wo fusion (the cache attention +
        # output projection in one kernel, per-slot pos frontiers): on
        # the Pallas execution path whenever the policy fuses — wo is a
        # single matrix, so unlike qkv/wig it needs no layout plan
        fuse_wo = (self.par.use_pallas_attn and self.policy.fuses()
                   and cfg.num_heads > 0)

        def body(h, layer):
            layer_params, kv = layer
            h, new_kv = block_decode(layer_params, h, cfg, kv, pos, ctx,
                                     int8=int8, policy=self.policy,
                                     fuse_wo=fuse_wo, block_tables=tables)
            return h, new_kv

        if paged and int8:
            kv_in = (cache["k_pages"], cache["k_scale_pages"],
                     cache["v_pages"], cache["v_scale_pages"])
        elif paged:
            kv_in = (cache["k_pages"], cache["v_pages"])
        elif int8:
            kv_in = (cache["k"], cache["k_scale"], cache["v"],
                     cache["v_scale"])
        else:
            kv_in = (cache["k"], cache["v"])
        x, new_kvs = jax.lax.scan(body, x, (params["blocks"], kv_in))
        logits = self._head(params, x)[:, 0]
        if paged and int8:
            new_cache = {"k_pages": new_kvs[0], "k_scale_pages": new_kvs[1],
                         "v_pages": new_kvs[2], "v_scale_pages": new_kvs[3],
                         "block_tables": tables, "pos": pos + 1}
        elif paged:
            new_cache = {"k_pages": new_kvs[0], "v_pages": new_kvs[1],
                         "block_tables": tables, "pos": pos + 1}
        elif int8:
            new_cache = {"k": new_kvs[0], "k_scale": new_kvs[1],
                         "v": new_kvs[2], "v_scale": new_kvs[3],
                         "pos": pos + 1}
        else:
            new_cache = {"k": new_kvs[0], "v": new_kvs[1], "pos": pos + 1}
        return logits, new_cache
