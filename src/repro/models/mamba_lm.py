"""Attention-free SSM LM (mamba2-2.7b family)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.registry import ExecutionPolicy
from repro.models import common, ssd
from repro.models.config import ModelConfig, ParallelConfig
from repro.parallel.sharding import ShardCtx, shard


class MambaLM:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig,
                 ctx: Optional[ShardCtx] = None,
                 policy: Optional[ExecutionPolicy] = None):
        assert cfg.ssm is not None
        self.cfg, self.par, self.ctx = cfg, par, ctx
        self.policy = policy or par.execution_policy()

    def with_policy(self, policy: ExecutionPolicy) -> "MambaLM":
        return type(self)(self.cfg, self.par, self.ctx, policy=policy)

    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def init_params(self, rng):
        cfg = self.cfg
        k_embed, k_blocks, k_norm, k_head = jax.random.split(rng, 4)
        block_keys = jax.random.split(k_blocks, cfg.num_layers)
        blocks = jax.vmap(lambda k: ssd.init_mamba_block(
            k, cfg.d_model, cfg.ssm, self._dtype())[0])(block_keys)
        params = {
            "embed": common.embed_init(k_embed,
                                       (cfg.vocab_size, cfg.d_model)),
            "blocks": blocks,
            "norms": jax.vmap(lambda k: common.init_norm(
                k, cfg.d_model, cfg.norm, self._dtype()))(
                jax.random.split(k_norm, cfg.num_layers)),
            "final_norm": common.init_norm(k_norm, cfg.d_model, cfg.norm,
                                           self._dtype()),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = common.dense_init(
                k_head, (cfg.d_model, cfg.vocab_size), 0, self._dtype())
        return params

    def param_specs(self):
        cfg = self.cfg
        _, bspecs = ssd.init_mamba_block(jax.random.PRNGKey(0), cfg.d_model,
                                         cfg.ssm, jnp.float32)
        bspecs = jax.tree.map(lambda ax: (None,) + ax, bspecs,
                              is_leaf=lambda x: isinstance(x, tuple))
        nspecs = jax.tree.map(lambda ax: (None,) + ax,
                              common.norm_specs(cfg.norm),
                              is_leaf=lambda x: isinstance(x, tuple))
        specs = {"embed": ("vocab", "embed"), "blocks": bspecs,
                 "norms": nspecs,
                 "final_norm": common.norm_specs(cfg.norm)}
        if not cfg.tie_embeddings:
            specs["lm_head"] = ("embed", "vocab")
        return specs

    def _embed(self, params, tokens, batch=None):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype())
        return shard(x, ("act_batch", "act_seq_unsharded", "act_embed"),
                     self.ctx)

    def _head(self, params, x):
        cfg = self.cfg
        x = common.apply_norm(x, params["final_norm"], cfg.norm,
                              cfg.norm_eps, policy=self.policy)
        w = params.get("lm_head")
        if w is None:
            w = params["embed"].T
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        return shard(logits.astype(jnp.float32),
                     ("act_batch", "act_seq_unsharded", "act_vocab"),
                     self.ctx)

    def _scan_blocks(self, params, x, return_state: bool = False):
        cfg, par, ctx = self.cfg, self.par, self.ctx
        policy = self.policy

        def body(h, layer):
            lp, np_ = layer
            hin = common.apply_norm(h, np_, cfg.norm, cfg.norm_eps,
                                    policy=policy)
            if return_state:
                out, (state, conv) = ssd.apply_mamba_block(
                    lp, hin, cfg.ssm, cfg.d_model, cfg.norm_eps, ctx,
                    return_state=True, policy=policy)
                h = h + out
                h = shard(h, ("act_batch", "act_seq", "act_embed"), ctx)
                return h, (state, conv)
            out = ssd.apply_mamba_block(lp, hin, cfg.ssm, cfg.d_model,
                                        cfg.norm_eps, ctx, policy=policy)
            h = h + out
            h = shard(h, ("act_batch", "act_seq", "act_embed"), ctx)
            return h, None

        if par.remat == "full" and not return_state:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, states = jax.lax.scan(body, x,
                                 (params["blocks"], params["norms"]))
        return x, states

    def loss_fn(self, params, batch):
        x = self._embed(params, batch["tokens"])
        x, _ = self._scan_blocks(params, x)
        logits = self._head(params, x)
        loss = common.cross_entropy(logits, batch["labels"], self.ctx)
        return loss, {"ce_loss": loss}

    def prefill(self, params, batch):
        x = self._embed(params, batch["tokens"])
        x, states = self._scan_blocks(params, x, return_state=True)
        logits = self._head(params, x[:, -1:, :])
        b = x.shape[0]
        cache = {"h": states[0], "conv": states[1],
                 "pos": jnp.full((b,), x.shape[1], jnp.int32)}
        return logits[:, 0], cache

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        g = s.n_groups
        hg = nh // g
        return {
            "h": jnp.zeros((cfg.num_layers, batch_size, g, hg, s.state_dim,
                            s.head_dim), jnp.float32),
            "conv": jnp.zeros((cfg.num_layers, batch_size, s.conv_width - 1,
                               ssd.conv_dim(s, cfg.d_model)), self._dtype()),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def cache_specs(self):
        return {
            "h": (None, "act_cache_batch", None, "act_ssm_heads",
                  "act_ssm_state", None),
            "conv": (None, "act_cache_batch", None, "ssm_inner"),
            "pos": (None,),
        }

    def decode_step(self, params, tokens, cache):
        cfg, ctx = self.cfg, self.ctx
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype())

        def body(h, layer):
            lp, np_, state, conv = layer
            hin = common.apply_norm(h, np_, cfg.norm, cfg.norm_eps,
                                    policy=self.policy)
            out, state, conv = ssd.mamba_decode_step(
                lp, hin, cfg.ssm, cfg.d_model, cfg.norm_eps, state, conv,
                ctx, policy=self.policy)
            return h + out, (state, conv)

        x, new = jax.lax.scan(
            body, x, (params["blocks"], params["norms"], cache["h"],
                      cache["conv"]))
        logits = self._head(params, x[:, None, :])[:, 0]
        return logits, {"h": new[0], "conv": new[1],
                        "pos": cache["pos"] + 1}
