"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Chunked SSD algorithm: within a chunk the recurrence is expanded into an
attention-like quadratic form (the 'duality'); across chunks a linear
recurrence carries the (N×P) state.  We scan over chunks so peak memory is
one chunk's quadratic term, and the final carry doubles as the decode/
prefill cache state.

Decode is the pure recurrence: h <- exp(dt·A)·h + dt·B⊗x, y = C·h + D·x.

The UISA connection (DESIGN.md §5): the intra-chunk term is a GEMM-shaped
hot-spot (MXU), the cross-chunk state update is a reduction-shaped one —
the shuffle-vs-barrier tradeoff of kernels/reduction.py applies inside the
chunk reduction.  Attention kernels are inapplicable to this family.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.registry import LIBRARY_POLICY, resolve_policy
from repro.kernels import ssd as kernel_ssd
from repro.models import common
from repro.models.config import ModelConfig, SSMConfig
from repro.parallel.sharding import ShardCtx, shard


class SSMState(NamedTuple):
    """Decode cache for one scanned stack of mamba blocks."""

    h: jax.Array          # [layers, B, G, Hg, N, P] ssm state
    conv: jax.Array       # [layers, B, W-1, conv_dim] conv tap history


def conv_dim(cfg: SSMConfig, d_model: int) -> int:
    d_inner = cfg.expand * d_model
    return d_inner + 2 * cfg.n_groups * cfg.state_dim


def init_mamba_block(key, d_model: int, cfg: SSMConfig, dtype):
    d_inner = cfg.expand * d_model
    nh = d_inner // cfg.head_dim
    cdim = conv_dim(cfg, d_model)
    ks = jax.random.split(key, 6)
    params = {
        "in_proj": common.dense_init(
            ks[0], (d_model, 2 * d_inner + 2 * cfg.n_groups * cfg.state_dim
                    + nh), 0, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, cdim))
                   * (cfg.conv_width ** -0.5)).astype(dtype),
        "conv_b": jnp.zeros((cdim,), dtype),
        "dt_bias": jnp.log(jnp.exp(
            jnp.linspace(cfg.dt_min, cfg.dt_max, nh)) - 1.0
        ).astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": common.dense_init(ks[5], (d_inner, d_model), 0, dtype),
    }
    specs = {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv_width", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "norm_scale": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }
    return params, specs


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [B,L,C]; w: [W,C]; b: [C].

    Returns f32: the silu that always follows must run in f32 on both the
    prefill and decode paths (decode already did; prefill used to cast to
    the storage dtype *before* the silu, so the same token picked up
    numerically different activations per path — the ISSUE 9 precision
    drift).  The caller applies the one cast back to storage dtype after
    the activation.
    """
    width, c = w.shape
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding=[(width - 1, 0)],
        dimension_numbers=("NHC", "HIO", "NHC"), feature_group_count=c)
    return out + b.astype(jnp.float32)


def ssd_scan(x, dt, A, B_mat, C_mat, chunk: int,
             initial_state: Optional[jax.Array] = None,
             ctx: Optional[ShardCtx] = None):
    """Chunked SSD — the unfused jnp chunk path.

    x:     [B, L, H, P]   (H heads of dim P)
    dt:    [B, L, H]      (positive step sizes)
    A:     [H]            (negative)
    B_mat: [B, L, G, N]
    C_mat: [B, L, G, N]
    Returns y [B, L, H, P] and final state [B, G, Hg, N, P] (Hg = H // G).

    The chunk math lives in ``kernels/ssd.py::ssd_scan_reference`` (also
    the lowering registry's library row for ``ssd_scan``); this wrapper
    threads the mesh placement: ``ctx`` pins the carried [B,G,Hg,N,P]
    state to its logical axes inside the scan body, so a sharded prefill
    keeps the carry resident on the heads axis instead of letting GSPMD
    re-derive its placement per chunk step.
    """
    hook = None
    if ctx is not None:
        def hook(state):
            return shard(state, ("act_batch", None, "act_ssm_heads",
                                 "act_ssm_state", None), ctx)
    return kernel_ssd.ssd_scan_reference(x, dt, A, B_mat, C_mat, chunk,
                                         initial_state=initial_state,
                                         state_hook=hook)


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence — the unfused jnp einsum trio.

    state: [B,G,Hg,N,P]; x_t: [B,H,P]; dt_t: [B,H]; B_t/C_t: [B,G,N].
    The math lives in ``kernels/ssd.py::ssd_decode_reference`` (also the
    lowering registry's library row for ``ssd_decode``).
    """
    return kernel_ssd.ssd_decode_reference(state, x_t, dt_t, A, B_t, C_t)


def _split_proj(z_xbc_dt, d_inner: int, gn2: int, nh: int):
    z = z_xbc_dt[..., :d_inner]
    xbc = z_xbc_dt[..., d_inner:2 * d_inner + gn2]
    dt_raw = z_xbc_dt[..., 2 * d_inner + gn2:]
    assert dt_raw.shape[-1] == nh
    return z, xbc, dt_raw


def apply_mamba_block(params, x, cfg: SSMConfig, d_model: int,
                      eps: float, ctx: Optional[ShardCtx],
                      initial_state: Optional[jax.Array] = None,
                      return_state: bool = False, policy=None):
    """Full mamba2 block (train/prefill). x: [B,L,D] -> [B,L,D]."""
    b, l, d = x.shape
    d_inner = cfg.expand * d
    nh = d_inner // cfg.head_dim
    gn2 = 2 * cfg.n_groups * cfg.state_dim

    proj = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = _split_proj(proj, d_inner, gn2, nh)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"])
                      ).astype(x.dtype)                # silu in f32, one cast
    xs = xbc[..., :d_inner]
    B_mat = xbc[..., d_inner:d_inner + gn2 // 2].reshape(
        b, l, cfg.n_groups, cfg.state_dim)
    C_mat = xbc[..., d_inner + gn2 // 2:].reshape(
        b, l, cfg.n_groups, cfg.state_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])         # [B,L,H]
    A = -jnp.exp(params["A_log"])                     # [H]

    xh = xs.reshape(b, l, nh, cfg.head_dim)
    xh = shard(xh, ("act_batch", "act_seq_unsharded", "act_ssm_heads",
                    "act_ssm_state"), ctx)
    pol = resolve_policy(policy=policy, default=LIBRARY_POLICY)
    if pol.fuses():
        # kernel-routed hot spot (same gate as the attention-path fusions
        # in models/common.py): the whole chunk scan runs as one Pallas
        # grid with the carried state in VMEM scratch — the per-chunk
        # intermediates never stage through HBM.  Same (y, final_state)
        # pair, so the decode cache seed is unchanged.
        from repro.kernels import ops as kernel_ops
        y, state = kernel_ops.fused_ssd_scan(
            xh, dt, A, B_mat, C_mat, chunk=cfg.chunk_size,
            initial_state=initial_state, policy=pol.kernel())
    else:
        y, state = ssd_scan(xh, dt, A, B_mat, C_mat, cfg.chunk_size,
                            initial_state=initial_state, ctx=ctx)
    y = y + (params["D"].reshape(nh, 1)
             * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, l, d_inner)
    y = common.rmsnorm(y * jax.nn.silu(z), params["norm_scale"], eps,
                       policy=policy)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(x.dtype))
    if return_state:
        conv_tail = _conv_tail(xbc_pre_conv=proj[..., d_inner:2 * d_inner + gn2],
                               width=cfg.conv_width)
        return out, (state, conv_tail)
    return out


def _conv_tail(xbc_pre_conv, width: int):
    """Last (width-1) pre-conv inputs — the decode conv cache seed."""
    b, l, c = xbc_pre_conv.shape
    if l >= width - 1:
        return xbc_pre_conv[:, l - (width - 1):, :]
    pad = width - 1 - l
    return jnp.pad(xbc_pre_conv, ((0, 0), (pad, 0), (0, 0)))


def mamba_decode_step(params, x_t, cfg: SSMConfig, d_model: int,
                      eps: float, state: jax.Array, conv_buf: jax.Array,
                      ctx: Optional[ShardCtx] = None, policy=None):
    """One-token mamba2 step.

    x_t: [B,D]; state: [B,G,Hg,N,P]; conv_buf: [B,W-1,conv_dim].
    Returns (y [B,D], new_state, new_conv_buf).
    """
    b, d = x_t.shape
    d_inner = cfg.expand * d
    nh = d_inner // cfg.head_dim
    gn2 = 2 * cfg.n_groups * cfg.state_dim

    proj = jnp.einsum("bd,de->be", x_t, params["in_proj"].astype(x_t.dtype))
    z, xbc_new, dt_raw = _split_proj(proj, d_inner, gn2, nh)

    window = jnp.concatenate([conv_buf, xbc_new[:, None, :]], axis=1)
    w = params["conv_w"].astype(jnp.float32)          # [W,C]
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w) \
        + params["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv_out).astype(x_t.dtype)
    new_conv_buf = window[:, 1:, :]

    xs = xbc[..., :d_inner]
    B_t = xbc[..., d_inner:d_inner + gn2 // 2].reshape(
        b, cfg.n_groups, cfg.state_dim)
    C_t = xbc[..., d_inner + gn2 // 2:].reshape(
        b, cfg.n_groups, cfg.state_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    xh = xs.reshape(b, nh, cfg.head_dim)
    pol = resolve_policy(policy=policy, default=LIBRARY_POLICY)
    if pol.fuses():
        # kernel-routed hot spot (same gate as the prefill chunk scan
        # above): the batched recurrence runs as one Pallas grid with each
        # slot's [N,P] state resident in VMEM for the tick — the
        # state-sized dt·B⊗x update tensor never stages through HBM, so
        # the engine's compiled tick stays one program.
        from repro.kernels import ops as kernel_ops
        state, y = kernel_ops.fused_ssd_decode(
            state, xh, dt, A, B_t, C_t, policy=pol.kernel())
    else:
        state, y = ssd_decode_step(state, xh, dt, A, B_t, C_t)
    y = y + (params["D"].reshape(nh, 1)
             * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(b, d_inner)
    y = common.rmsnorm(y * jax.nn.silu(z), params["norm_scale"], eps,
                       policy=policy)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(x_t.dtype))
    return out, state, new_conv_buf
