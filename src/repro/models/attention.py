"""Attention implementations for training / prefill / decode.

Three execution paths:

1. ``chunked_causal`` — pure-jnp online-softmax (flash) attention, memory
   bounded by (chunk_q × chunk_kv) logits.  This is what the multi-pod
   dry-run lowers (compilable for any backend).  Baseline visits every
   (q-chunk, kv-chunk) pair and masks — O(S²) compute.
2. ``exact_causal`` (ParallelConfig.causal_folding) — python-unrolled
   q-chunks, each scanning only its causal kv prefix: exact triangle
   compute, ~2× FLOP reduction at long sequence.  A §Perf lever visible in
   ``cost_analysis``.
3. The Pallas flash kernel (kernels/attention.py) — the TPU-native path,
   numerically identical (tests assert so), selected on real TPU backends.

All paths implement GQA without materializing repeated KV heads: q is
viewed as [B, Hkv, G, S, D] and contracted against [B, Hkv, S, D].
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import ShardCtx, shard

NEG_INF = -1e30


def _pad_axis(x, axis: int, multiple: int):
    pad = (-x.shape[axis]) % multiple
    if pad:
        cfg = [(0, 0)] * x.ndim
        cfg[axis] = (0, pad)
        x = jnp.pad(x, cfg)
    return x, x.shape[axis] // multiple


def _chunk_step(qc, kc, vc, carry, row_ids, col_ids, causal):
    """One online-softmax update.  qc: [B,Hkv,G,cq,D], kc/vc: [B,Hkv,ck,D]."""
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qc.astype(jnp.float32),
                   kc.astype(jnp.float32))
    if causal:
        mask = col_ids[None, :] <= row_ids[:, None]        # (cq, ck)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_cur = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc * corr + jnp.einsum("bkgqc,bkcd->bkgqd", p,
                                  vc.astype(jnp.float32))
    return m_cur, l_cur, acc


def chunked_attention(q, k, v, *, causal: bool = True,
                      kv_offset: int = 0, chunk_q: int = 512,
                      chunk_kv: int = 1024, exact_causal: bool = False,
                      scale: Optional[float] = None,
                      ctx: Optional[ShardCtx] = None):
    """q: [B,H,Sq,D]; k/v: [B,Hkv,Skv,D] -> [B,H,Sq,D]."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    if scale is None:
        scale = d ** -0.5
    q = q * jnp.asarray(scale, q.dtype)

    chunk_q = min(chunk_q, sq)
    chunk_kv = min(chunk_kv, skv)
    qg = q.reshape(b, hkv, g, sq, d)
    qg, nq = _pad_axis(qg, 3, chunk_q)
    kp, nk = _pad_axis(k, 2, chunk_kv)
    vp, _ = _pad_axis(v, 2, chunk_kv)
    sqp, skvp = qg.shape[3], kp.shape[2]
    # padded kv columns must never win the softmax
    kv_valid = jnp.arange(skvp) < skv

    qs = jnp.moveaxis(qg.reshape(b, hkv, g, nq, chunk_q, d), 3, 0)
    ks = jnp.moveaxis(kp.reshape(b, hkv, nk, chunk_kv, d), 2, 0)
    vs = jnp.moveaxis(vp.reshape(b, hkv, nk, chunk_kv, d), 2, 0)
    col_base = jnp.arange(chunk_kv)
    row_base = jnp.arange(chunk_q)

    def init_carry():
        return (jnp.full((b, hkv, g, chunk_q, 1), NEG_INF, jnp.float32),
                jnp.zeros((b, hkv, g, chunk_q, 1), jnp.float32),
                jnp.zeros((b, hkv, g, chunk_q, d), jnp.float32))

    def finish(carry):
        _, l, acc = carry
        l = jnp.where(l == 0.0, 1.0, l)
        return (acc / l).astype(q.dtype)

    def kv_scan(qc, qi: int | jax.Array, n_kv_chunks: int):
        def body(carry, inp):
            ki, kc, vc = inp
            rows = qi * chunk_q + row_base + kv_offset
            cols = ki * chunk_kv + col_base
            cols_ok = cols < skv
            kc = jnp.where(cols_ok[None, None, :, None], kc, 0)
            rows_mask = jnp.where(cols_ok, cols, skv + sqp + kv_offset)
            carry = _chunk_step(qc, kc, vc, carry, rows, rows_mask,
                                causal=True)
            return carry, None
        xs = (jnp.arange(n_kv_chunks), ks[:n_kv_chunks], vs[:n_kv_chunks])
        carry, _ = jax.lax.scan(body, init_carry(), xs)
        return finish(carry)

    if exact_causal and causal and kv_offset == skv - sq:
        # §Perf path: unroll q-chunks in python; chunk i scans only its
        # causal prefix — exact-triangle FLOPs, visible in cost_analysis.
        outs = []
        off_chunks = kv_offset // chunk_kv
        for qi in range(nq):
            last_col = qi * chunk_q + chunk_q - 1 + kv_offset
            n_kv = min(nk, last_col // chunk_kv + 1)
            outs.append(kv_scan(qs[qi], qi, max(n_kv, 1)))
        out = jnp.stack(outs, axis=0)
    else:
        def q_body(_, qin):
            qi, qc = qin
            if causal:
                o = kv_scan(qc, qi, nk)
            else:
                def body(carry, inp):
                    ki, kc, vc = inp
                    cols = ki * chunk_kv + col_base
                    rows = jnp.full((chunk_q,), skvp + sqp, jnp.int32)
                    cols_m = jnp.where(cols < skv, cols, skvp + sqp + 1)
                    # non-causal: mask only padded kv columns
                    carry = _chunk_step(qc, kc, vc, carry, rows, cols_m,
                                        causal=True)
                    return carry, None
                carry, _ = jax.lax.scan(body, init_carry(),
                                        (jnp.arange(nk), ks, vs))
                o = finish(carry)
            return None, o
        _, out = jax.lax.scan(q_body, None, (jnp.arange(nq), qs))

    out = jnp.moveaxis(out, 0, 3)                    # [B,Hkv,G,nq,cq,D]
    out = out.reshape(b, hkv, g, sqp, d)[:, :, :, :sq]
    return out.reshape(b, h, sq, d)


def decode_attention(q, k_cache, v_cache, pos, *,
                     scale: Optional[float] = None,
                     ctx: Optional[ShardCtx] = None):
    """Single-token attention against a cache.

    q: [B,H,1,D]; caches: [B,Hkv,S,D]; pos: [B] int32 — number of valid
    cache entries per sequence (the new token sits at index pos).
    """
    b, h, _, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = h // hkv
    if scale is None:
        scale = d ** -0.5
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None] <= pos[:, None]            # [B,S]
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, 1, d).astype(q.dtype)


def update_cache(cache, new, pos):
    """Insert new [B,Hkv,1,D] at index pos [B] — one-hot masked write
    (GSPMD-friendly for seq-sharded caches; see DESIGN.md §4)."""
    b, hkv, s, d = cache.shape
    onehot = (jnp.arange(s)[None] == pos[:, None])         # [B,S]
    return jnp.where(onehot[:, None, :, None], new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# Paged KV cache: fixed-size pages + per-slot block tables (vLLM-style).
# Pages: [P, Hkv, page_size, D] (one pool per layer; the page index axis is
# shared across layers).  Block tables: [B, max_pages] int32 — entry j is
# the page holding tokens [j*page_size, (j+1)*page_size); entries past a
# slot's reservation hold the sentinel value ``P`` (out of range), so the
# scatter write drops and the gather clamp reads a page whose contents are
# masked anyway.  The serve engine owns allocation/refcounts
# (repro.serve.engine.PagePool); everything here is pure device math.
# ---------------------------------------------------------------------------


def update_paged_cache(pages, new, block_tables, pos):
    """Insert new [B,Hkv,1,D] at logical index pos [B] through the table.

    The write resolves to page ``block_tables[b, pos[b] // page_size]`` at
    row ``pos[b] % page_size``.  A sentinel table entry (== num_pages, the
    engine's reset value for dead/reaped slots) makes the write **drop** —
    a freed slot whose ``pos`` keeps advancing inside the one-program tick
    can never touch a page that was handed to another request.  Indices
    past the table end clamp (jax gather semantics) onto the slot's own
    last entry, which the engine guarantees is never a shared page.
    """
    num_pages, hkv, page_size, d = pages.shape
    page_of = jnp.take_along_axis(
        block_tables, (pos // page_size)[:, None], axis=1)[:, 0]    # [B]
    offset = pos % page_size
    return pages.at[page_of, :, offset].set(
        new[:, :, 0, :].astype(pages.dtype), mode="drop")


def gather_paged_kv(pages, block_tables):
    """[P,Hkv,page_size,D] + [B,max_pages] -> a [B,Hkv,S,D] logical strip.

    Sentinel/dead entries clamp to the last real page; whatever they read
    sits past every consumer's ``pos`` frontier and is masked.  This is
    the library-row materialization — the Pallas decode kernel gathers the
    same pages through its index map without ever building the strip.
    """
    num_pages = pages.shape[0]
    tbl = jnp.minimum(block_tables, num_pages - 1)
    strip = pages[tbl]                     # [B, max_pages, Hkv, ps, D]
    b, maxp, hkv, ps, d = strip.shape
    return strip.transpose(0, 2, 1, 3, 4).reshape(b, hkv, maxp * ps, d)


def paged_decode_attention(q, k_pages, v_pages, block_tables, pos, *,
                           scale: Optional[float] = None,
                           ctx: Optional[ShardCtx] = None):
    """Single-token attention against a paged cache (jnp reference).

    q: [B,H,1,D]; pages: [P,Hkv,page_size,D]; block_tables: [B,max_pages];
    pos: [B].  Numerically identical to :func:`decode_attention` over the
    gathered strip — the masked-softmax math never sees page boundaries.
    """
    k_cache = gather_paged_kv(k_pages, block_tables)
    v_cache = gather_paged_kv(v_pages, block_tables)
    return decode_attention(q, k_cache, v_cache, pos, scale=scale, ctx=ctx)


# ---------------------------------------------------------------------------
# int8 KV cache (beyond-paper serving optimization; ParallelConfig flag)
# ---------------------------------------------------------------------------


def quantize_kv(x):
    """[B,Hkv,S,D] -> (int8 values, f32 scales [B,Hkv,S,1]).

    Per-(token, head) symmetric scaling: attention quality is far more
    sensitive to per-token dynamic range than per-tensor (K norms drift
    with position under RoPE)."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(m / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def update_cache_int8(cache_q, cache_scale, new, pos):
    """Quantized one-hot cache write: (int8 cache, scales, new bf16 slot)."""
    b, hkv, s, d = cache_q.shape
    q_new, s_new = quantize_kv(new)
    onehot = (jnp.arange(s)[None] == pos[:, None])          # [B,S]
    cache_q = jnp.where(onehot[:, None, :, None], q_new, cache_q)
    cache_scale = jnp.where(onehot[:, None, :, None], s_new, cache_scale)
    return cache_q, cache_scale


def update_paged_cache_int8(pages, scale_pages, new, block_tables, pos):
    """Quantized paged write (ISSUE 7): the int8 composition of
    :func:`update_paged_cache`.

    ``pages``: int8 ``[P,Hkv,page_size,D]``; ``scale_pages``: f32
    ``[P,Hkv,page_size,1]`` — per-token scales in a pool of the *same*
    page geometry, so both writes resolve through the same table entry
    and the same sentinel/drop semantics (the value row and its scale can
    never land on different pages).  ``new`` arrives bf16/f32 and is
    quantized per-(token, head) here, at write time."""
    q_new, s_new = quantize_kv(new)
    pages = update_paged_cache(pages, q_new, block_tables, pos)
    scale_pages = update_paged_cache(scale_pages, s_new, block_tables, pos)
    return pages, scale_pages
