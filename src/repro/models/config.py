"""Model / run configuration schema.

One :class:`ModelConfig` describes any of the assigned architecture
families (dense / moe / ssm / hybrid / encdec / vlm).  Parallelism and
step-shape knobs live in :class:`RunConfig` so the same model config can
be lowered for train / prefill / decode under different meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # tokens are routed within fixed-size groups (GShard-style) so the
    # dispatch einsum stays rectangular under SPMD
    group_size: int = 4096
    moe_every_n: int = 1          # 1 => every block is MoE
    shared_experts: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    n_groups: int = 1             # B/C groups (G)
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length (Q)
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    encoder_layers: int = 6
    num_frames: int = 1500        # stub audio frontend output length


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 576        # stub anyres vision frontend output length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6           # shared attention block period (zamba2)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int                # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "silu"             # silu (swiglu) | gelu (plain mlp)
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    pos_emb: str = "rope"         # rope | learned | sinusoidal | none
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    hybrid: Optional[HybridConfig] = None
    max_seq_len: int = 131072
    dtype: str = "bfloat16"       # activations/weights compute dtype
    # sub-quadratic attention available? (long_500k eligibility)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def param_count(self) -> int:
        """Approximate parameter count N (used for 6·N·D MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d                      # embed
        if not self.tie_embeddings:
            total += v * d                 # lm head
        per_layer_attn = d * (self.num_heads * hd) + d * hd * self.num_kv_heads * 2 \
            + (self.num_heads * hd) * d if self.num_heads else 0
        if self.act == "silu":
            per_layer_mlp = 3 * d * self.d_ff
        else:
            per_layer_mlp = 2 * d * self.d_ff
        n_attn_layers = self.num_layers
        n_mlp_layers = self.num_layers
        if self.family == "ssm":
            cfg = self.ssm
            d_in = cfg.expand * d
            conv_dim = d_in + 2 * cfg.n_groups * cfg.state_dim
            nh = d_in // cfg.head_dim
            per_ssm = (d * (2 * d_in + 2 * cfg.n_groups * cfg.state_dim + nh)
                       + conv_dim * cfg.conv_width + 3 * nh + d_in
                       + d_in * d)
            return total + self.num_layers * per_ssm
        if self.family == "hybrid":
            cfg = self.ssm
            d_in = cfg.expand * d
            conv_dim = d_in + 2 * cfg.n_groups * cfg.state_dim
            nh = d_in // cfg.head_dim
            per_ssm = (d * (2 * d_in + 2 * cfg.n_groups * cfg.state_dim + nh)
                       + conv_dim * cfg.conv_width + 3 * nh + d_in
                       + d_in * d)
            shared_attn = per_layer_attn + per_layer_mlp
            return total + self.num_layers * per_ssm + shared_attn
        if self.moe is not None:
            per_layer_mlp = (3 * d * self.d_ff) * self.moe.num_experts \
                + d * self.moe.num_experts  # router
            if self.moe.shared_experts:
                per_layer_mlp += 3 * d * self.d_ff * self.moe.shared_experts
        total += n_attn_layers * per_layer_attn + n_mlp_layers * per_layer_mlp
        if self.family == "encdec":
            # encoder blocks + decoder cross-attention
            total += self.encdec.encoder_layers * (per_layer_attn + per_layer_mlp)
            total += self.num_layers * per_layer_attn  # cross attn
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        all_experts = self.num_layers * 3 * d * self.d_ff * self.moe.num_experts
        active = self.num_layers * 3 * d * self.d_ff * (
            self.moe.top_k + self.moe.shared_experts)
        return full - all_experts + active


@dataclasses.dataclass(frozen=True)
class ParamLayout:
    """Init-time parameter layout — fusion legality decided at rest.

    The fused multi-op lowerings (kernels/fused.py) consume *concatenated*
    weight tensors: ``[wq|wk|wv]`` for the norm→q/k/v prologue and
    ``[wi|wg]`` for the norm→swiglu pair.  Concatenating per call is free
    at train/prefill scale but a net traffic loss at decode (rows = B: a
    weight-sized materialization to save a token-sized round trip), which
    is why PR 4 kept all seq-path fusions off the decode tick.  This plan
    moves the decision to where it is free: parameters are *persisted* in
    the fused layout at init, the hot loop only takes views.

    ``attn_qkv`` stores one ``wqkv = [wq|wk|wv]`` tensor per attention
    sublayer; ``mlp_swiglu`` stores one ``wig = [wi|wg]`` tensor per dense
    (and MoE shared-expert) swiglu MLP.  Either layout is *readable* by
    every consumer through the accessors in ``models/common.py`` —
    views/slices for unfused math, the whole tensor for fused kernels —
    so checkpoints in one layout load into models planned for the other
    (checkpoint/manager.py migrates at the flat-leaf level).
    """

    attn_qkv: bool = False
    mlp_swiglu: bool = False

    @classmethod
    def plan(cls, cfg: "ModelConfig", policy) -> "ParamLayout":
        """The ONE place the layout is decided, driven by the policy the
        model resolved: a fusing policy (``ExecutionPolicy.fuses()``)
        gets the concatenated layout wherever a fused lowering can
        consume it (rmsnorm prologues only — layernorm models keep the
        per-matrix layout)."""
        if not policy.fuses() or cfg.norm != "rmsnorm":
            return cls()
        return cls(attn_qkv=cfg.num_heads > 0,
                   mlp_swiglu=cfg.act == "silu")


#: the per-matrix layout every pre-ISSUE-5 checkpoint carries
LEGACY_LAYOUT = ParamLayout()


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How the model maps onto the mesh (DP/FSDP/TP/EP/SP)."""

    fsdp: bool = True              # shard params/opt-state over 'data'
    seq_shard_acts: bool = True    # saved residuals seq-sharded over 'model'
    # decode cache mesh layout: batch_heads | batch_seq | seq_all
    # (see parallel/sharding.py — batch_seq when kv heads don't divide
    # the model axis; seq_all for batch=1 long-context)
    cache_layout: str = "batch_heads"
    grad_accum: int = 1            # microbatch accumulation steps
    remat: str = "full"            # full | dots | none
    grad_compression: str = "none" # none | bf16 | int8_ef
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    # §Perf lever: fold the causal triangle so masked-out blocks are never
    # computed (see models/attention.py)
    causal_folding: bool = False
    # §Perf lever: sharding-constrain pre-repeat K/V on the kv-head axis.
    # Baseline True (the naive layout); False removes the per-layer
    # involuntary replication all-gathers GSPMD inserts when
    # num_kv_heads < model-axis size (see EXPERIMENTS.md §Perf).
    constrain_kv_pre_repeat: bool = True
    # §Perf lever: constrain attention/MLP partial-sum outputs to the
    # seq-sharded layout *before* the residual add, so GSPMD lowers the
    # TP combine as reduce-scatter (half the wire of all-reduce) instead
    # of all-reduce + dynamic-slice.
    rs_outputs: bool = False
    # Beyond-paper serving lever: store the attention KV cache as int8
    # with per-(token, head) f32 scales — halves the decode memory term
    # (cache reads dominate long-context decode).  TransformerLM only.
    kv_cache_int8: bool = False
    # Route full-sequence attention through the Pallas flash kernel
    # (kernels/attention.py) instead of the jnp chunked path.  This is
    # the TPU execution path; CPU tests run it in interpret mode.  The
    # dry-run keeps the jnp path (compilable for the CPU placeholder
    # backend).
    use_pallas_attn: bool = False
    # Lowering policy for every registry-dispatched hot spot (norms,
    # reduce, attention kernel): an IsaMode value, "auto" (cheapest legal
    # variant for isa_dialect, per structural_cost), or None for the
    # seed-equivalent split — XLA library lowering for model norms, the
    # target-native variant on the Pallas attention path.
    isa_mode: Optional[str] = None
    isa_dialect: Optional[str] = None   # defaults to the framework TARGET
    # Fused-epilogue gate for the norm→projection and residual→norm hot
    # pairs (kernels/fused.py): True forces the fused lowerings, False
    # forces the unfused sequence, None (default) fuses exactly when the
    # policy mode is "auto" — the structural-cost ranking then picks the
    # variant whose hbm_bytes dropped by an activation round trip.
    fuse_epilogues: Optional[bool] = None
    # Weight-precision axis (ISSUE 7): "int8" retargets the hot fused
    # lowerings to their quantized twins (registry precision variants) —
    # int8 weights + per-channel scales dequantized in VMEM, so the
    # weight stream never rides HBM at f32 width.  None/"f32" keeps the
    # f32 rows.  Orthogonal to kv_cache_int8 (the cache axis).
    weight_precision: Optional[str] = None

    def execution_policy(self):
        """Resolve this config's ExecutionPolicy — the ONE place mode
        strings are decided; call sites only thread the result."""
        from repro.core.dialect import TARGET
        from repro.core.registry import ExecutionPolicy
        dialect = self.isa_dialect or TARGET.name
        if self.isa_mode is not None:
            return ExecutionPolicy(mode=self.isa_mode, dialect=dialect,
                                   kernel_mode=self.isa_mode,
                                   fuse=self.fuse_epilogues,
                                   precision=self.weight_precision)
        # Native lowerings are pinned to the framework TARGET; under a
        # foreign dialect the kernel path must degrade to a legal variant
        # ("auto") instead of requesting an unlowerable native kernel.
        kernel_mode = "native" if dialect == TARGET.name else "auto"
        return ExecutionPolicy(mode="library", dialect=dialect,
                               kernel_mode=kernel_mode,
                               fuse=self.fuse_epilogues,
                               precision=self.weight_precision)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                      # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §5)."""
    if shape.name == "long_500k":
        return model.subquadratic
    return True
