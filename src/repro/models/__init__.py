"""Model registry: family string -> model class.

Families (models/config.py): dense / moe / vlm -> TransformerLM,
ssm -> MambaLM, hybrid -> HybridLM, encdec -> EncDecLM.  All expose the
same functional surface: init_params / param_specs / loss_fn / prefill /
init_cache / cache_specs / decode_step.
"""
from __future__ import annotations

from typing import Optional

from repro.models.config import ModelConfig, ParallelConfig
from repro.parallel.sharding import ShardCtx


def build_model(cfg: ModelConfig, par: Optional[ParallelConfig] = None,
                ctx: Optional[ShardCtx] = None):
    from repro.models.encdec import EncDecLM
    from repro.models.hybrid import HybridLM
    from repro.models.mamba_lm import MambaLM
    from repro.models.transformer import TransformerLM

    par = par if par is not None else ParallelConfig()
    family = cfg.family
    if family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg, par, ctx)
    if family == "ssm":
        return MambaLM(cfg, par, ctx)
    if family == "hybrid":
        return HybridLM(cfg, par, ctx)
    if family in ("encdec", "audio"):
        return EncDecLM(cfg, par, ctx)
    raise ValueError(f"unknown model family {family!r}")
