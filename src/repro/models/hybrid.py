"""Hybrid SSM + shared-attention LM (zamba2 family, arXiv:2411.15242).

Backbone of mamba2 blocks with ONE transformer block whose weights are
*shared* across periodic applications (every ``attn_every`` mamba layers).
Zamba2's per-application LoRA deltas and embedding-concat input are
simplified away (noted in DESIGN.md §5); the weight-sharing structure and
cache layout are faithful.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.registry import ExecutionPolicy
from repro.models import common, ssd, transformer
from repro.models.config import ModelConfig, ParallelConfig, ParamLayout
from repro.parallel.sharding import ShardCtx, shard


class HybridLM:
    def __init__(self, cfg: ModelConfig, par: ParallelConfig,
                 ctx: Optional[ShardCtx] = None,
                 policy: Optional[ExecutionPolicy] = None):
        assert cfg.ssm is not None and cfg.hybrid is not None
        self.cfg, self.par, self.ctx = cfg, par, ctx
        self.policy = policy or par.execution_policy()
        # the shared attention block rides the same init-time layout plan
        # as TransformerLM (the SSM blocks have no fusable weight pairs)
        self.param_layout = ParamLayout.plan(cfg, self.policy)
        self.n_apps = cfg.num_layers // cfg.hybrid.attn_every

    def with_policy(self, policy: ExecutionPolicy) -> "HybridLM":
        return type(self)(self.cfg, self.par, self.ctx, policy=policy)

    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def init_params(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 6)
        block_keys = jax.random.split(ks[1], cfg.num_layers)
        blocks = jax.vmap(lambda k: ssd.init_mamba_block(
            k, cfg.d_model, cfg.ssm, self._dtype())[0])(block_keys)
        params = {
            "embed": common.embed_init(ks[0],
                                       (cfg.vocab_size, cfg.d_model)),
            "blocks": blocks,
            "norms": jax.vmap(lambda k: common.init_norm(
                k, cfg.d_model, cfg.norm, self._dtype()))(
                jax.random.split(ks[2], cfg.num_layers)),
            "shared_attn": transformer.init_block(
                ks[3], cfg, self._dtype(), self.param_layout)[0],
            "final_norm": common.init_norm(ks[4], cfg.d_model, cfg.norm,
                                           self._dtype()),
            "lm_head": common.dense_init(
                ks[5], (cfg.d_model, cfg.vocab_size), 0, self._dtype()),
        }
        return params

    def param_specs(self):
        cfg = self.cfg
        _, bspecs = ssd.init_mamba_block(jax.random.PRNGKey(0), cfg.d_model,
                                         cfg.ssm, jnp.float32)
        bspecs = jax.tree.map(lambda ax: (None,) + ax, bspecs,
                              is_leaf=lambda x: isinstance(x, tuple))
        nspecs = jax.tree.map(lambda ax: (None,) + ax,
                              common.norm_specs(cfg.norm),
                              is_leaf=lambda x: isinstance(x, tuple))
        _, attn_specs = transformer.init_block(jax.random.PRNGKey(0), cfg,
                                               jnp.float32,
                                               self.param_layout)
        return {"embed": ("vocab", "embed"), "blocks": bspecs,
                "norms": nspecs, "shared_attn": attn_specs,
                "final_norm": common.norm_specs(cfg.norm),
                "lm_head": ("embed", "vocab")}

    # ---- helpers ----

    def _layer_groups(self):
        """[(start, end)] mamba index ranges; shared attn after each."""
        cfg = self.cfg
        period = cfg.hybrid.attn_every
        groups = [(i * period, (i + 1) * period) for i in range(self.n_apps)]
        rem = (self.n_apps * period, cfg.num_layers)
        return groups, rem

    def _mamba_span(self, params, x, lo: int, hi: int,
                    return_state: bool = False):
        cfg, par, ctx = self.cfg, self.par, self.ctx
        span = (jax.tree.map(lambda p: p[lo:hi], params["blocks"]),
                jax.tree.map(lambda p: p[lo:hi], params["norms"]))

        def body(h, layer):
            lp, np_ = layer
            hin = common.apply_norm(h, np_, cfg.norm, cfg.norm_eps,
                                    policy=self.policy)
            if return_state:
                out, st = ssd.apply_mamba_block(
                    lp, hin, cfg.ssm, cfg.d_model, cfg.norm_eps, ctx,
                    return_state=True, policy=self.policy)
                return h + out, st
            out = ssd.apply_mamba_block(lp, hin, cfg.ssm, cfg.d_model,
                                        cfg.norm_eps, ctx,
                                        policy=self.policy)
            return h + out, None

        if par.remat == "full" and not return_state:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        return jax.lax.scan(body, x, span)

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype())
        return shard(x, ("act_batch", "act_seq_unsharded", "act_embed"),
                     self.ctx)

    def _head(self, params, x):
        cfg = self.cfg
        x = common.apply_norm(x, params["final_norm"], cfg.norm,
                              cfg.norm_eps, policy=self.policy)
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["lm_head"].astype(x.dtype))
        return shard(logits.astype(jnp.float32),
                     ("act_batch", "act_seq_unsharded", "act_vocab"),
                     self.ctx)

    # ---- forward ----

    def _forward(self, params, x, positions, collect_cache: bool = False):
        cfg, par, ctx = self.cfg, self.par, self.ctx
        groups, rem = self._layer_groups()
        ssm_states, convs, attn_kvs = [], [], []
        for lo, hi in groups:
            x, st = self._mamba_span(params, x, lo, hi,
                                     return_state=collect_cache)
            if collect_cache:
                ssm_states.append(st[0])
                convs.append(st[1])
            if collect_cache:
                x, _, kv = transformer.block_seq(
                    params["shared_attn"], x, cfg, par, positions, ctx,
                    return_kv=True, policy=self.policy)
                attn_kvs.append(kv)
            else:
                x, _ = transformer.block_seq(params["shared_attn"], x, cfg,
                                             par, positions, ctx,
                                             policy=self.policy)
        if rem[1] > rem[0]:
            x, st = self._mamba_span(params, x, rem[0], rem[1],
                                     return_state=collect_cache)
            if collect_cache:
                ssm_states.append(st[0])
                convs.append(st[1])
        if not collect_cache:
            return x, None
        cache = {
            "h": jnp.concatenate(ssm_states, axis=0),
            "conv": jnp.concatenate(convs, axis=0),
            "attn_k": jnp.stack([kv[0] for kv in attn_kvs]),
            "attn_v": jnp.stack([kv[1] for kv in attn_kvs]),
        }
        return x, cache

    def loss_fn(self, params, batch):
        x = self._embed(params, batch["tokens"])
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                     (x.shape[0], x.shape[1]))
        x, _ = self._forward(params, x, positions)
        logits = self._head(params, x)
        loss = common.cross_entropy(logits, batch["labels"], self.ctx)
        return loss, {"ce_loss": loss}

    def prefill(self, params, batch):
        x = self._embed(params, batch["tokens"])
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, cache = self._forward(params, x, positions, collect_cache=True)
        logits = self._head(params, x[:, -1:, :])
        cache["pos"] = jnp.full((b,), s, jnp.int32)
        return logits[:, 0], cache

    def init_cache(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        nh = d_inner // s.head_dim
        g = s.n_groups
        hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "h": jnp.zeros((cfg.num_layers, batch_size, g, nh // g,
                            s.state_dim, s.head_dim), jnp.float32),
            "conv": jnp.zeros((cfg.num_layers, batch_size, s.conv_width - 1,
                               ssd.conv_dim(s, cfg.d_model)), self._dtype()),
            "attn_k": jnp.zeros((self.n_apps, batch_size, hkv, cache_len,
                                 hd), self._dtype()),
            "attn_v": jnp.zeros((self.n_apps, batch_size, hkv, cache_len,
                                 hd), self._dtype()),
            "pos": jnp.zeros((batch_size,), jnp.int32),
        }

    def cache_specs(self):
        kv = (None, "act_cache_batch", "act_kv_heads", "act_kv_seq",
              "act_head_dim")
        return {
            "h": (None, "act_cache_batch", None, "act_ssm_heads",
                  "act_ssm_state", None),
            "conv": (None, "act_cache_batch", None, "ssm_inner"),
            "attn_k": kv, "attn_v": kv, "pos": (None,),
        }

    def decode_step(self, params, tokens, cache):
        cfg, ctx = self.cfg, self.ctx
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype())
        groups, rem = self._layer_groups()
        new_h, new_conv, new_k, new_v = [], [], [], []

        def mamba_span_decode(x, lo, hi):
            span = (jax.tree.map(lambda p: p[lo:hi], params["blocks"]),
                    jax.tree.map(lambda p: p[lo:hi], params["norms"]),
                    cache["h"][lo:hi], cache["conv"][lo:hi])

            def body(h, layer):
                lp, np_, st, cv = layer
                hin = common.apply_norm(h, np_, cfg.norm, cfg.norm_eps,
                                        policy=self.policy)
                out, st, cv = ssd.mamba_decode_step(
                    lp, hin, cfg.ssm, cfg.d_model, cfg.norm_eps, st, cv,
                    ctx, policy=self.policy)
                return h + out, (st, cv)
            return jax.lax.scan(body, x, span)

        for app, (lo, hi) in enumerate(groups):
            x, (st, cv) = mamba_span_decode(x, lo, hi)
            new_h.append(st)
            new_conv.append(cv)
            x2, kv = transformer.block_decode(
                params["shared_attn"], x[:, None, :], cfg,
                (cache["attn_k"][app], cache["attn_v"][app]), pos, ctx,
                policy=self.policy)
            x = x2[:, 0, :]
            new_k.append(kv[0])
            new_v.append(kv[1])
        if rem[1] > rem[0]:
            x, (st, cv) = mamba_span_decode(x, rem[0], rem[1])
            new_h.append(st)
            new_conv.append(cv)
        logits = self._head(params, x[:, None, :])[:, 0]
        new_cache = {
            "h": jnp.concatenate(new_h, axis=0),
            "conv": jnp.concatenate(new_conv, axis=0),
            "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
            "pos": pos + 1,
        }
        return logits, new_cache
