"""Deterministic, resumable, host-sharded synthetic LM data pipeline.

Design constraints (1000-node deployability):
- **Deterministic by (seed, step, host)**: any host can regenerate any
  batch from the step index alone — restart/elastic-resize never needs
  data-state files beyond the step counter.
- **Host-sharded**: each host materializes only its slice of the global
  batch (``host_count``/``host_index`` mirror
  ``jax.process_count``/``process_index`` on a real cluster).
- **Prefetched**: a background thread keeps ``prefetch`` batches ready;
  on CPU-only containers this is a faithful (if small) stand-in for the
  tf.data/grain feeds a production deployment would use.

The token stream is a fixed-point hash of (seed, step, position) with a
Zipf-ish skew so losses move like language data rather than uniform noise.
Batches carry the modality-stub tensors (frames / patch_embeds) required
by the encdec / vlm families.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    host_count: int = 1
    host_index: int = 0
    prefetch: int = 2
    # modality stubs
    family: str = "dense"
    num_frames: int = 0
    num_patches: int = 0
    d_model: int = 0


def _hash_tokens(seed: int, step: int, batch: int, seq: int,
                 vocab: int, base_row: int) -> np.ndarray:
    """splitmix64-style counter hash -> Zipf-skewed token ids."""
    with np.errstate(over="ignore"):     # uint64 wraparound is the point
        rows = np.arange(batch, dtype=np.uint64)[:, None] + np.uint64(base_row)
        cols = np.arange(seq, dtype=np.uint64)[None, :]
        x = (rows * np.uint64(0x9E3779B97F4A7C15)
             ^ cols * np.uint64(0xBF58476D1CE4E5B9)
             ^ np.uint64(step) * np.uint64(0x94D049BB133111EB)
             ^ np.uint64(seed))
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    u = (x >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    # Zipf-ish skew: id = floor(V * u^3) concentrates mass on low ids
    ids = np.minimum((vocab * u ** 3).astype(np.int64), vocab - 1)
    return ids.astype(np.int32)


class SyntheticLMDataset:
    """Iterator of host-local batches with save/restore state."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        self._step = 0
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ---- deterministic batch synthesis ----

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        base_row = self.cfg.host_index * self.local_batch
        seq = cfg.seq_len + 1
        toks = _hash_tokens(cfg.seed, step, self.local_batch, seq,
                            cfg.vocab_size, base_row)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.family in ("encdec", "audio") and cfg.num_frames:
            rng = np.random.default_rng(
                (cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
            batch["frames"] = rng.standard_normal(
                (self.local_batch, cfg.num_frames, cfg.d_model),
                dtype=np.float32)
        if cfg.family == "vlm" and cfg.num_patches:
            rng = np.random.default_rng(
                (cfg.seed * 2_000_003 + step) & 0x7FFFFFFF)
            batch["patch_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.num_patches, cfg.d_model),
                dtype=np.float32)
        return batch

    # ---- iterator protocol with background prefetch ----

    def _producer(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.batch_at(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def start(self):
        if self._thread is None:
            self._q = queue.Queue(maxsize=self.cfg.prefetch)
            self._stop.clear()
            self._thread = threading.Thread(target=self._producer,
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None
            self._q = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        if self._thread is not None:
            batch = self._q.get()
        else:
            batch = self.batch_at(self._step)
        self._step += 1
        return batch

    # ---- resumable state ----

    def state(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed}

    def restore(self, state: Dict[str, int]):
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        was_running = self._thread is not None
        self.stop()
        self._step = int(state["step"])
        if was_running:
            self.start()


def make_batch_specs(model_cfg: ModelConfig, seq_len: int,
                     global_batch: int, dtype=jnp.float32):
    """ShapeDtypeStructs for one *global* train batch (dry-run input)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if model_cfg.family in ("encdec", "audio"):
        specs["frames"] = jax.ShapeDtypeStruct(
            (global_batch, model_cfg.encdec.num_frames, model_cfg.d_model),
            jnp.float32)
    if model_cfg.family == "vlm":
        specs["patch_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, model_cfg.vlm.num_patches, model_cfg.d_model),
            jnp.float32)
    return specs
