"""Parameterizable dialects (paper Table III) as queryable constants.

The paper's central mechanism for spanning vendors is that six dimensions
are *parameterizable*: identical concepts, vendor-specific parameters.
Programs must never hardcode them — they query a :class:`Dialect`.

We register the four GPU vendors from the paper plus the TPU v5e dialect
this framework targets (the hardware-adaptation of the same concepts; see
DESIGN.md §2).  All kernel block-shape / occupancy decisions in
``repro.kernels`` are derived from the active dialect, never from literals.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

# Register width w in bytes (paper Table I, "typically 4").
REGISTER_WIDTH_BYTES = 4

#: hbm-equivalent bytes assigned to a collective on a dialect with no
#: multi-device interconnect (apple-g13 unified memory): large enough that
#: a TP-fused lowering can never out-rank a replicated one, finite so the
#: ranking tuple stays well-ordered and JSON-serializable.
NO_INTERCONNECT_BYTES = 1 << 60


@dataclasses.dataclass(frozen=True)
class Interconnect:
    """One vendor's chip-to-chip link profile (the below-the-chip-edge
    half of the dialect: the paper's execution model is grounded in the
    physical constraints of parallel computation — memory *and*
    communication, §II).

    ``link_bandwidth`` is bytes/s per link per direction (ICI for TPU,
    PCIe/NVLink class for the GPU vendors); ``hop_latency_s`` is the α
    term of the α-β model — per-hop launch + synchronization latency,
    which is what makes large rings lose to replication even when the
    per-byte term would break even."""

    link_bandwidth: float          # bytes/s, per link per direction
    hop_latency_s: float           # α: per-hop latency (seconds)
    topology: str = "ring"


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """Modeled cost of one collective under a dialect's interconnect.

    ``wire_bytes`` follows the same ring formulas
    ``roofline/analysis.py::parse_collectives`` applies to real HLO —
    all-reduce ``2S(G-1)/G``, all-gather/reduce-scatter/all-to-all
    ``S(G-1)/G``, permute ``S`` — so predicted-vs-modeled is an equality
    check, not a re-derivation.  ``hbm_equiv_bytes`` converts the α-β
    wire time into the registry's ranking currency (HBM bytes at the
    dialect's HBM bandwidth):

        t = wire/link_bw + hops·α
        hbm_equiv = t · hbm_bw = wire·(hbm_bw/link_bw) + hops·α·hbm_bw

    The α term grows linearly with the group while the sharding saving
    saturates at (G-1)/G — that asymmetry is what gives "auto" a real
    mesh-size crossover between TP-fused and replicated lowerings.
    """

    kind: str                      # all_reduce | all_gather | ...
    payload_bytes: int             # S: logical tensor bytes at the boundary
    group: int                     # G: devices participating
    wire_bytes: int                # ring bytes actually moved per device
    hops: int                      # ring steps (latency-bearing)
    hbm_equiv_bytes: int           # ranking currency (see above)

    def cost_keys(self) -> dict:
        """The structural-cost columns a TP-variant cost dict carries."""
        return {
            "collective": self.kind,
            "collective_group": self.group,
            "collective_payload_bytes": self.payload_bytes,
            "collective_bytes": self.wire_bytes,
            "collective_hops": self.hops,
            "collective_hbm_equiv_bytes": self.hbm_equiv_bytes,
        }


#: ring wire-byte factors, keyed like parse_collectives' op names
_RING_WIRE = {
    "all_reduce": lambda s, g: 2 * s * (g - 1) // g,
    "all_gather": lambda s, g: s * (g - 1) // g,
    "reduce_scatter": lambda s, g: s * (g - 1) // g,
    "all_to_all": lambda s, g: s * (g - 1) // g,
    "permute": lambda s, g: s,
}

_RING_HOPS = {
    "all_reduce": lambda g: 2 * (g - 1),
    "all_gather": lambda g: g - 1,
    "reduce_scatter": lambda g: g - 1,
    "all_to_all": lambda g: g - 1,
    "permute": lambda g: 1,
}


def collective_cost(kind: str, payload_bytes: int, group: int,
                    dialect: "Dialect") -> CollectiveCost:
    """Model one collective's cost on ``dialect``'s interconnect.

    ``group <= 1`` is the degenerate single-device case: every term is
    zero and a TP twin's cost collapses onto its base — the property the
    conformance matrix (which runs without a mesh) relies on."""
    if kind not in _RING_WIRE:
        raise KeyError(f"unknown collective kind {kind!r}; "
                       f"known: {sorted(_RING_WIRE)}")
    if group <= 1:
        return CollectiveCost(kind=kind, payload_bytes=payload_bytes,
                              group=max(group, 1), wire_bytes=0, hops=0,
                              hbm_equiv_bytes=0)
    wire = int(_RING_WIRE[kind](payload_bytes, group))
    hops = int(_RING_HOPS[kind](group))
    link = dialect.interconnect
    if link is None:
        return CollectiveCost(kind=kind, payload_bytes=payload_bytes,
                              group=group, wire_bytes=wire, hops=hops,
                              hbm_equiv_bytes=NO_INTERCONNECT_BYTES)
    hbm_bw = dialect.hbm_bandwidth or TARGET.hbm_bandwidth
    equiv = (wire * hbm_bw / link.link_bandwidth
             + hops * link.hop_latency_s * hbm_bw)
    return CollectiveCost(kind=kind, payload_bytes=payload_bytes,
                          group=group, wire_bytes=wire, hops=hops,
                          hbm_equiv_bytes=int(math.ceil(equiv)))


@dataclasses.dataclass(frozen=True)
class MatrixUnit:
    """Opaque-but-queryable matrix capability (paper Table IV resolution).

    The paper resolves the matrix-unit divergence by making tiles queryable
    rather than prescribed.  ``tile`` is the native (M, N, K) the unit
    consumes; ``dtypes`` the supported input precisions.
    """

    tile: Tuple[int, int, int]
    dtypes: Tuple[str, ...]
    throughput_flops: Optional[float] = None  # peak FLOP/s, if public


@dataclasses.dataclass(frozen=True)
class Dialect:
    """One vendor's parameter set for the universal execution model.

    Fields mirror paper Tables I & III:
      W  wave width (threads per lockstep group); a range for Intel.
      R  max registers per thread (32-bit).
      S  scratchpad bytes visible to one workgroup.
      F  register-file bytes per core (for Eq. 1 occupancy).
      max_workgroup  threads per workgroup.
      named_barriers number of independently addressable barriers.
      native_fp64    hardware double support.
    """

    name: str
    vendor: str
    wave_width: Tuple[int, ...]           # admissible W values
    max_regs_per_thread: int              # R
    scratchpad_bytes: int                 # S
    regfile_bytes_per_core: int           # F
    max_workgroup: int
    named_barriers: int
    native_fp64: bool
    memory_levels: Tuple[str, ...]
    divergence_mechanism: str
    matrix_unit: Optional[MatrixUnit] = None
    has_hw_atomics: bool = True
    has_lane_shuffle: bool = True         # the paper's 11th primitive
    hbm_bandwidth: Optional[float] = None  # bytes/s
    peak_flops_bf16: Optional[float] = None
    #: chip-to-chip link profile (None = no multi-device interconnect:
    #: collectives are modeled as never-profitable on this dialect)
    interconnect: Optional[Interconnect] = None
    # TPU-only: VMEM plays the register-file role in the occupancy tradeoff
    # (DESIGN.md §2, primitive 3).
    notes: str = ""

    @property
    def W(self) -> int:  # noqa: N802 - paper notation
        return self.wave_width[0]

    @property
    def R(self) -> int:  # noqa: N802
        return self.max_regs_per_thread

    @property
    def S(self) -> int:  # noqa: N802
        return self.scratchpad_bytes

    @property
    def F(self) -> int:  # noqa: N802
        return self.regfile_bytes_per_core

    def occupancy(self, regs_per_thread: int, wave_width: Optional[int] = None,
                  reg_width: int = REGISTER_WIDTH_BYTES) -> int:
        """Paper Eq. 1: O = floor(F / (R × W × w)).

        Resident waves per core given a per-thread register demand.  The
        invariant (primitive 3) is the *tradeoff*, not the constants.
        """
        w_width = self.W if wave_width is None else wave_width
        if regs_per_thread <= 0:
            raise ValueError("regs_per_thread must be positive")
        if regs_per_thread > self.R:
            return 0
        return self.F // (regs_per_thread * w_width * reg_width)

    def buffer_occupancy(self, block_bytes: int, n_buffers: int = 2) -> int:
        """TPU re-derivation of Eq. 1 (DESIGN.md §2 primitive 3/5).

        On a single-threaded systolic core, latency is hidden by resident
        DMA *buffers* instead of resident *waves*; the same fixed-SRAM-area
        algebra bounds how many block-sized pipeline stages fit:
        ``O = floor(S / (n_buffers × block_bytes))``.
        """
        if block_bytes <= 0 or n_buffers <= 0:
            raise ValueError("block_bytes and n_buffers must be positive")
        return self.S // (n_buffers * block_bytes)

    def validate_workgroup(self, size: int) -> bool:
        return 0 < size <= self.max_workgroup

    def query(self, key: str):
        """String-keyed query API — 'we do not prescribe W; we query it'."""
        table = {
            "W": self.W,
            "wave_widths": self.wave_width,
            "R": self.R,
            "S": self.S,
            "F": self.F,
            "max_workgroup": self.max_workgroup,
            "named_barriers": self.named_barriers,
            "native_fp64": self.native_fp64,
            "matrix_tile": self.matrix_unit.tile if self.matrix_unit else None,
            "matrix_dtypes": self.matrix_unit.dtypes if self.matrix_unit else (),
            "has_hw_atomics": self.has_hw_atomics,
            "has_lane_shuffle": self.has_lane_shuffle,
            "memory_levels": self.memory_levels,
        }
        if key not in table:
            raise KeyError(f"unknown dialect query {key!r}")
        return table[key]


# ---------------------------------------------------------------------------
# Registry: the four vendors from the paper (Tables II/III) + TPU v5e.
# ---------------------------------------------------------------------------

NVIDIA_SM89 = Dialect(
    name="nvidia-ada-sm89",
    vendor="NVIDIA",
    wave_width=(32,),
    max_regs_per_thread=255,
    scratchpad_bytes=228 * 1024,
    regfile_bytes_per_core=256 * 1024,
    max_workgroup=1024,
    named_barriers=16,
    native_fp64=True,
    memory_levels=("reg", "shared", "L1", "L2", "DRAM"),
    divergence_mechanism="per-thread PC + predicates (hardware)",
    matrix_unit=MatrixUnit(tile=(16, 16, 16), dtypes=("f16", "bf16", "tf32", "i8")),
    hbm_bandwidth=1008e9,                 # GDDR6X (AD102 class)
    interconnect=Interconnect(link_bandwidth=32e9,   # PCIe 4.0 x16 (no
                              hop_latency_s=3e-6),   # NVLink on Ada)
    notes="PTX virtual ISA; per-thread scalar semantics.",
)

AMD_RDNA3 = Dialect(
    name="amd-rdna3",
    vendor="AMD",
    wave_width=(32, 64),
    max_regs_per_thread=256,
    scratchpad_bytes=128 * 1024,
    regfile_bytes_per_core=192 * 1024,
    max_workgroup=1024,
    named_barriers=32,
    native_fp64=True,  # rate varies; capability present
    memory_levels=("reg", "LDS", "L0", "L1", "L2", "VRAM"),
    divergence_mechanism="EXEC mask (compiler-managed)",
    matrix_unit=MatrixUnit(tile=(16, 16, 16), dtypes=("f16", "bf16", "i8")),
    hbm_bandwidth=960e9,                  # GDDR6 (Navi 31 class)
    interconnect=Interconnect(link_bandwidth=32e9,   # PCIe 4.0 x16
                              hop_latency_s=3e-6),
    notes="SALU/VALU split; compiler hoists uniform ops to scalar unit.",
)

INTEL_XE_HPG = Dialect(
    name="intel-xe-hpg",
    vendor="Intel",
    wave_width=(8, 16),
    max_regs_per_thread=128,
    scratchpad_bytes=512 * 1024,
    regfile_bytes_per_core=64 * 1024,
    max_workgroup=1024,
    named_barriers=1,
    native_fp64=False,  # HPC parts only
    memory_levels=("reg", "SLM", "L1", "L2", "DRAM"),
    divergence_mechanism="predicated SIMD (compiler-managed)",
    matrix_unit=MatrixUnit(tile=(8, 16, 16), dtypes=("f16", "bf16", "i8")),
    hbm_bandwidth=560e9,                  # GDDR6 (DG2 class)
    interconnect=Interconnect(link_bandwidth=32e9,   # PCIe 4.0 x16
                              hop_latency_s=3e-6),
    notes="SIMD-register ISA; fixed-function via SEND messages.",
)

APPLE_G13 = Dialect(
    name="apple-g13",
    vendor="Apple",
    wave_width=(32,),
    max_regs_per_thread=128,
    scratchpad_bytes=60 * 1024,          # ~60 KB threadgroup memory
    regfile_bytes_per_core=208 * 1024,
    max_workgroup=1024,
    named_barriers=1,
    native_fp64=False,
    memory_levels=("reg", "threadgroup", "L1", "L2", "L3", "DRAM"),
    divergence_mechanism="hardware execution stack in r0l",
    matrix_unit=None,  # absent capability (paper §VI): queryable as None
    hbm_bandwidth=68e9,                   # unified LPDDR (M1 class)
    interconnect=None,  # absent capability, same discipline as the
    notes="reverse-engineered (flagged confidence); unified memory.",
)  # missing matrix unit: queryable as None, never assumed

# The framework's target dialect.  Same queryable schema, TPU semantics:
#   - 'wave' = 128-lane vreg minor dimension (fetch amortization constraint)
#   - scratchpad S = VMEM; F also = VMEM (it plays the register-file role in
#     the occupancy tradeoff — see Dialect.buffer_occupancy)
#   - no HW atomics, no thread-level zero-cost switch (documented divergences)
#   - matrix unit = 128x128x128 MXU systolic tile, queryable
TPU_V5E = Dialect(
    name="tpu-v5e",
    vendor="Google",
    wave_width=(128,),                    # vreg lanes (8 sublanes x 128 lanes)
    max_regs_per_thread=64,               # vregs per scalar core context (approx.)
    scratchpad_bytes=64 * 1024 * 1024,    # VMEM budget we tile against
    regfile_bytes_per_core=64 * 1024 * 1024,
    max_workgroup=1,                      # single-threaded core: grid supplies parallelism
    named_barriers=32,                    # DMA/barrier semaphores
    native_fp64=False,
    memory_levels=("vreg", "VMEM", "HBM"),
    divergence_mechanism="predication (@pl.when / lane masks)",
    matrix_unit=MatrixUnit(tile=(128, 128, 128), dtypes=("bf16", "f32", "i8"),
                           throughput_flops=197e12),
    has_hw_atomics=False,
    has_lane_shuffle=True,                # intra-vreg lane rotate/permute
    hbm_bandwidth=819e9,
    peak_flops_bf16=197e12,
    # ICI: 50 GB/s per link per direction (launch/mesh.py::ICI_BW keeps
    # the same constant for the roofline) with ~1 µs per ring hop
    interconnect=Interconnect(link_bandwidth=50e9, hop_latency_s=1e-6),
    notes="systolic+VLIW; latency hidden by async DMA buffers, not waves.",
)

# The paper's pre-§VII.C counterfactual: the ten-invariant universal
# profile WITHOUT primitive 11 (and without HW atomics — the conservative
# minimum every vendor satisfies).  Registered so the lowering registry can
# be exercised against a target where the shuffle budget is illegal and the
# scratch-tree lowering is the only legal cross-lane realization.
UISA_UNIVERSAL10 = Dialect(
    name="uisa-universal10",
    vendor="UISA",
    wave_width=(32,),
    max_regs_per_thread=128,
    scratchpad_bytes=48 * 1024,
    regfile_bytes_per_core=64 * 1024,
    max_workgroup=256,
    named_barriers=1,
    native_fp64=False,
    memory_levels=("reg", "scratch", "DRAM"),
    divergence_mechanism="abstract (vendor-managed)",
    matrix_unit=None,
    has_hw_atomics=False,
    has_lane_shuffle=False,
    hbm_bandwidth=256e9,                  # conservative universal floor
    interconnect=Interconnect(link_bandwidth=16e9,   # PCIe-class floor
                              hop_latency_s=5e-6),   # every vendor meets
    notes="hypothetical minimum universal profile (paper §V, before the "
          "§VII.C shuffle finding promoted primitive 11 to mandatory)",
)

DIALECTS: Dict[str, Dialect] = {
    d.name: d for d in (NVIDIA_SM89, AMD_RDNA3, INTEL_XE_HPG, APPLE_G13,
                        TPU_V5E, UISA_UNIVERSAL10)
}

#: the dialect every kernel in this framework is compiled against
TARGET = TPU_V5E


def get_dialect(name: str) -> Dialect:
    try:
        return DIALECTS[name]
    except KeyError:
        raise KeyError(
            f"unknown dialect {name!r}; known: {sorted(DIALECTS)}") from None


def gpu_dialects() -> Tuple[Dialect, ...]:
    """The four vendors analysed by the paper (excludes the TPU target)."""
    return (NVIDIA_SM89, AMD_RDNA3, INTEL_XE_HPG, APPLE_G13)


def align_up(x: int, multiple: int) -> int:
    return ((x + multiple - 1) // multiple) * multiple


def mxu_align(dim: int, dialect: Dialect = TARGET) -> int:
    """Round ``dim`` up to the dialect's matrix-tile edge (query, not assume)."""
    if dialect.matrix_unit is None:
        return dim
    return align_up(dim, dialect.matrix_unit.tile[0])
