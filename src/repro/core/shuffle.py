"""Lane-shuffle primitive API — primitive 11 as a first-class layer.

The paper's §VII.C finding promotes intra-wave shuffle from "nice native
feature" to *mandatory eleventh primitive*: replacing it with
barrier-mediated scratchpad round-trips costs up to 37.5% on
latency-sensitive schedulers.  The seed exercised that insight in exactly
one kernel (`reduction.py`) through a raw ``pltpu.roll`` call; this module
makes the primitive available to *every* kernel under a stable API, so the
``abstract+shuffle`` budget means the same thing everywhere:

- :func:`lane_shuffle_down` / :func:`lane_shuffle_up` — rotate-style
  exchange across the vreg minor dimension (the TPU "wave"), the
  realization of ``__shfl_down_sync`` / ``simd_shuffle_down``.
- :func:`lane_shuffle_xor` — butterfly exchange built from two rotates and
  a lane-id select (``__shfl_xor_sync``).
- :func:`lane_tree_reduce` — the log2(W) rotate tree: after the tree every
  lane holds the full reduction (allreduce semantics), all in registers,
  zero scratch traffic.
- :func:`row_reduce_shuffle` — rowwise reduction of a ``(..., n*W)`` tile:
  fold the row into W-lane vregs (register accumulation), then one rotate
  tree.  This is the cross-lane hot loop used by rmsnorm / attention /
  histogram in ``abstract+shuffle`` mode.
- :func:`scratch_tree_reduce` — the *abstract* (shuffle-free) counterpart:
  the same tree, but every halving stage stores to and reloads from a VMEM
  scratch buffer with program order playing the workgroup barrier.  The
  traffic it generates is exactly the §VII.C mechanism.

Interpret safety: inside a Pallas kernel the rotate lowers to
``pltpu.roll`` (Mosaic's intra-vreg lane rotation, also supported by the
Pallas interpreter); outside a kernel trace — oracles, host-side tests,
``library``-mode paths — the same API falls back to ``jnp.roll``, which is
bit-identical for the rotate semantics.  Callers never branch on context.

Cost accounting: :func:`tree_stages` / :func:`scratch_tree_bytes` are the
shared vocabulary every kernel's ``structural_cost`` uses to report its
scratch-traffic delta, so benchmarks compare like with like.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.dialect import TARGET

#: wave width of the target dialect (queried, never assumed — Table III)
LANES = TARGET.W

Op = Callable[[jax.Array, jax.Array], jax.Array]


def _rotate(x: jax.Array, shift: int, axis: int) -> jax.Array:
    """Circular lane rotation with an interpret-safe fallback.

    ``pltpu.roll`` only traces inside a Pallas kernel; everywhere else the
    mathematically identical ``jnp.roll`` realizes the same exchange.
    """
    axis = axis % x.ndim
    try:
        return pltpu.roll(x, shift, axis)
    except NotImplementedError:
        # "Evaluation rule for 'roll' not implemented": outside a Pallas
        # trace (oracle / host path).  Only this error falls back — a
        # genuine lowering failure inside a kernel must propagate, or the
        # shuffle budget would silently stop exercising primitive 11.
        return jnp.roll(x, shift, axis=axis)


def lane_shuffle_down(x: jax.Array, delta: int, axis: int = -1) -> jax.Array:
    """Lane ``i`` receives the value of lane ``(i + delta) mod W``.

    The rotate (wraparound) flavour of ``__shfl_down_sync``: on a reduce
    tree the wrapped lanes are harmless because rotation is a bijection.
    """
    size = x.shape[axis]
    return _rotate(x, (-delta) % size, axis)


def lane_shuffle_up(x: jax.Array, delta: int, axis: int = -1) -> jax.Array:
    """Lane ``i`` receives the value of lane ``(i - delta) mod W``."""
    size = x.shape[axis]
    return _rotate(x, delta % size, axis)


def lane_shuffle_xor(x: jax.Array, mask: int, axis: int = -1) -> jax.Array:
    """Butterfly exchange: lane ``i`` receives lane ``i ^ mask``.

    Built from two rotates and a lane-id select: for a power-of-two mask,
    lanes with the mask bit set fetch from ``i - mask`` and the rest from
    ``i + mask`` — no wraparound ever crosses a butterfly group.
    """
    size = x.shape[axis]
    if mask <= 0 or mask & (mask - 1) or mask >= size:
        raise ValueError(f"mask must be a power of two < {size}, got {mask}")
    axis = axis % x.ndim
    shape = [1] * x.ndim
    shape[axis] = size
    lane = jax.lax.broadcasted_iota(jnp.int32, tuple(shape), axis)
    up = lane_shuffle_up(x, mask, axis)        # from i - mask
    down = lane_shuffle_down(x, mask, axis)    # from i + mask
    return jnp.where((lane & mask) != 0, up, down)


def lane_tree_reduce(x: jax.Array, op: Op = jnp.add,
                     axis: int = -1) -> jax.Array:
    """log2(W) rotate tree over ``axis``; every lane ends with the full
    reduction (allreduce), entirely in registers — zero scratch traffic.

    ``op`` must be associative and commutative (add / maximum / minimum).
    """
    size = x.shape[axis]
    if size & (size - 1):
        raise ValueError(f"tree reduce needs a power-of-two width, got {size}")
    shift = size // 2
    while shift >= 1:
        x = op(x, lane_shuffle_down(x, shift, axis))
        shift //= 2
    return x


def fold_rows(x: jax.Array, op: Op = jnp.add,
              lanes: int = LANES) -> jax.Array:
    """Fold the last axis of ``x`` (``(..., d)``, d a multiple of
    ``lanes``) down to one ``(..., lanes)`` vreg by register accumulation.

    The row is a sequence of ``d // lanes`` vregs; combining them is plain
    register arithmetic (universal budget) — no lane crossing yet.  Both
    the shuffle and the scratchpad cross-lane stages start from this fold.
    """
    d = x.shape[-1]
    if d % lanes:
        raise ValueError(f"row width {d} not a multiple of {lanes} lanes")
    folded = x.reshape(x.shape[:-1] + (d // lanes, lanes))
    acc = folded[..., 0, :]
    for g in range(1, d // lanes):
        acc = op(acc, folded[..., g, :])
    return acc


def row_reduce_shuffle(x: jax.Array, op: Op = jnp.add,
                       lanes: int = LANES) -> jax.Array:
    """Reduce the last axis of ``x`` (``(..., d)``, d a multiple of
    ``lanes``) to ``(..., 1)`` via register folds + one rotate tree.

    The final cross-lane stage is the shuffle tree (primitive 11).  No
    scratchpad involved — this is the zero-round-trip hot path.
    """
    acc = lane_tree_reduce(fold_rows(x, op, lanes), op, axis=-1)
    return acc[..., :1]


def scratch_tree_reduce(x: jax.Array, scratch_ref, op: Op = jnp.add,
                        axis: int = -1) -> jax.Array:
    """The shuffle-free tree: halving stages through a scratchpad buffer.

    ``scratch_ref`` must match ``x`` in shape; ``x`` is 2D.  Each stage
    stores a partial to VMEM and reloads it — the barrier-mediated
    round-trips whose cost the paper measured at 37.5%.  Returns the
    reduced slice (``(rows, 1)`` for ``axis=-1``, ``(1, cols)`` for
    ``axis=0``).
    """
    if x.ndim != 2:
        raise ValueError(f"scratch tree reduce is 2D-only, got ndim={x.ndim}")
    axis = axis % 2
    width = x.shape[axis]
    if width & (width - 1):
        raise ValueError(f"tree reduce needs a power-of-two width, got {width}")
    scratch_ref[...] = x
    w = width // 2
    while w >= 1:
        if axis == 1:
            lo = scratch_ref[:, :w]           # load | barrier (program order)
            hi = scratch_ref[:, w:2 * w]      # load
            scratch_ref[:, :w] = op(lo, hi)   # store partial
        else:
            lo = scratch_ref[:w, :]
            hi = scratch_ref[w:2 * w, :]
            scratch_ref[:w, :] = op(lo, hi)
        w //= 2
    return scratch_ref[:, :1] if axis == 1 else scratch_ref[:1, :]


# ---------------------------------------------------------------------------
# Cost vocabulary shared by every kernel's structural_cost
# ---------------------------------------------------------------------------


def tree_stages(width: int = LANES) -> int:
    """Halving stages of a ``width``-wide tree (= shuffles, or round-trips)."""
    if width & (width - 1):
        raise ValueError(f"width must be a power of two, got {width}")
    return int(math.log2(width))


def scratch_tree_bytes(width: int, rows: int = 1, itemsize: int = 4) -> int:
    """Scratch traffic of one :func:`scratch_tree_reduce`: stage ``k``
    reads two ``width >> k`` slices and writes one, per row."""
    return rows * sum(3 * (width >> k) * itemsize
                      for k in range(1, tree_stages(width) + 1))
