"""The hardware-invariant primitives (paper Table II + the shuffle refinement).

Each primitive is a typed descriptor carrying: its physical-constraint
rationale (paper §IV.A.1), its per-vendor realization (Table II), its
classification (invariant / parameterizable / divergent), and its TPU
realization in this framework.

Kernels in ``repro.kernels`` declare the primitive set they use via
:class:`KernelContract`; :func:`validate_contract` enforces the paper's
*abstract* discipline — an abstract kernel may only touch the universal set
(primitives 1–10), while ``abstract+shuffle`` adds primitive 11 and
``native`` may use anything, including target-specific features outside the
model.  This is the mechanism behind the paper's Table V methodology.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, Tuple

from repro.core.dialect import Dialect, TARGET


class Classification(enum.Enum):
    INVARIANT = "invariant"            # present in all four vendors
    PARAMETERIZABLE = "parameterizable"  # same concept, queryable parameter
    DIVERGENT = "divergent"            # incompatible approaches; abstraction boundary


class Primitive(enum.Enum):
    """Paper Table II (1–10) plus the §VII.C refinement (11)."""

    LOCKSTEP_GROUP = 1
    MASKED_DIVERGENCE = 2
    REGISTER_OCCUPANCY = 3
    MANAGED_SCRATCHPAD = 4
    ZERO_COST_SWITCH = 5
    HIERARCHICAL_MEMORY = 6
    ATOMIC_RMW = 7
    WORKGROUP_BARRIER = 8
    IDENTITY_REGISTERS = 9
    ASYNC_MEMORY = 10
    LANE_SHUFFLE = 11  # mandatory after the reduction finding

    @property
    def universal(self) -> bool:
        """Member of the original ten-invariant set."""
        return self.value <= 10


#: primitive sets selectable per kernel (the paper's Table V columns)
UNIVERSAL_SET: FrozenSet[Primitive] = frozenset(p for p in Primitive if p.universal)
UNIVERSAL_PLUS_SHUFFLE: FrozenSet[Primitive] = UNIVERSAL_SET | {Primitive.LANE_SHUFFLE}


class IsaMode(enum.Enum):
    """Which primitive budget a kernel variant is allowed to spend."""

    ABSTRACT = "abstract"                  # primitives 1-10 only
    ABSTRACT_SHUFFLE = "abstract+shuffle"  # + primitive 11
    NATIVE = "native"                      # full target feature set
    LIBRARY = "library"                    # XLA-native op (cuBLAS analogue)

    @property
    def allowed(self) -> FrozenSet[Primitive]:
        if self is IsaMode.ABSTRACT:
            return UNIVERSAL_SET
        if self is IsaMode.ABSTRACT_SHUFFLE:
            return UNIVERSAL_PLUS_SHUFFLE
        return frozenset(Primitive)  # native/library: unrestricted


#: target-specific features *outside* the abstract model; using any of these
#: makes a kernel 'native' (the TPU analogues of __shfl_sync/bank padding/
#: #pragma unroll in the paper's native kernels).
NATIVE_FEATURES: FrozenSet[str] = frozenset({
    "mxu_aligned_tiles",       # block shapes chosen for the 128x128 systolic tile
    "multi_buffering",         # explicit >1-deep DMA pipeline (emit_pipeline depth)
    "fused_epilogue",          # fusing normalization/activation into the matmul tile
    "dimension_semantics",     # pltpu arbitrary/parallel grid annotations
    "lane_shuffle_intrinsics", # raw pltpu.roll beyond the shuffle primitive API
})


@dataclasses.dataclass(frozen=True)
class PrimitiveSpec:
    primitive: Primitive
    classification: Classification
    rationale: str                      # physical-constraint argument (§IV.A.1)
    vendor_realization: Dict[str, str]  # Table II row
    tpu_realization: str                # DESIGN.md §2 row
    tpu_direct: bool                    # True if a direct native mapping exists


SPECS: Dict[Primitive, PrimitiveSpec] = {
    Primitive.LOCKSTEP_GROUP: PrimitiveSpec(
        Primitive.LOCKSTEP_GROUP, Classification.INVARIANT,
        "instruction fetch costs 10-100x single-lane arithmetic; one fetch "
        "must be amortized across W lanes",
        {"NVIDIA": "warp (32)", "AMD": "wavefront (32/64)",
         "Intel": "sub-group (8-16)", "Apple": "SIMD-group (32)"},
        "VPU vreg minor dimension: W=128 lanes; MXU 128x128 tile for matrix",
        True),
    Primitive.MASKED_DIVERGENCE: PrimitiveSpec(
        Primitive.MASKED_DIVERGENCE, Classification.DIVERGENT,
        "only mechanism compatible with lockstep execution that preserves "
        "correctness without branch prediction",
        {"NVIDIA": "per-thread PC + predicates", "AMD": "EXEC register",
         "Intel": "predicated SIMD", "Apple": "hardware stack in r0l"},
        "@pl.when predication + jnp.where lane masks (compiler-managed)",
        True),
    Primitive.REGISTER_OCCUPANCY: PrimitiveSpec(
        Primitive.REGISTER_OCCUPANCY, Classification.INVARIANT,
        "fixed SRAM area: O = floor(F/(R*W*w)) (Eq. 1)",
        {"NVIDIA": "255 regs / 256KB per SM", "AMD": "256 VGPRs/wave",
         "Intel": "128 GRF/thread", "Apple": "128 GPRs / 208KB"},
        "VMEM-occupancy: pipeline depth O = floor(VMEM/(n_buffers*block_bytes))",
        True),
    Primitive.MANAGED_SCRATCHPAD: PrimitiveSpec(
        Primitive.MANAGED_SCRATCHPAD, Classification.INVARIANT,
        "parallel access patterns require explicit placement caches cannot "
        "predict",
        {"NVIDIA": "shared memory (228KB)", "AMD": "LDS (64-160KB)",
         "Intel": "SLM (64-512KB)", "Apple": "threadgroup (~60KB)"},
        "VMEM via BlockSpec tiling + pltpu scratch shapes (fully managed)",
        True),
    Primitive.ZERO_COST_SWITCH: PrimitiveSpec(
        Primitive.ZERO_COST_SWITCH, Classification.DIVERGENT,
        "memory latency (100-800 cyc) dominates; SRAM thread state is "
        "cheaper than speculation",
        {"NVIDIA": "all warp state resident", "AMD": "all wave state resident",
         "Intel": "IMT 7-8 threads/EU", "Apple": "24 SIMD-groups resident"},
        "NO thread analogue (single-threaded core); constraint met by async "
        "DMA double/triple buffering — occupancy-by-buffers",
        False),
    Primitive.HIERARCHICAL_MEMORY: PrimitiveSpec(
        Primitive.HIERARCHICAL_MEMORY, Classification.INVARIANT,
        "memory-compute bandwidth gap forces a hierarchy",
        {"NVIDIA": "reg/shmem/L1/L2/DRAM", "AMD": "reg/LDS/L0-2/VRAM",
         "Intel": "reg/SLM/L1-2/DRAM", "Apple": "reg/TG/L1-3/DRAM"},
        "vreg -> VMEM -> HBM, explicit (no transparent cache in between)",
        True),
    Primitive.ATOMIC_RMW: PrimitiveSpec(
        Primitive.ATOMIC_RMW, Classification.DIVERGENT,
        "concurrent accumulation needs a conflict-resolution mechanism",
        {"NVIDIA": "atom/red all scopes", "AMD": "DS/buffer/global atomics",
         "Intel": "SEND atomics", "Apple": "32-bit device atomics"},
        "NO HW atomics: privatize + deterministic reduce (one-hot matmul "
        "accumulation in-kernel, XLA collectives across cores)",
        False),
    Primitive.WORKGROUP_BARRIER: PrimitiveSpec(
        Primitive.WORKGROUP_BARRIER, Classification.INVARIANT,
        "global barriers would require all workgroups simultaneously "
        "resident; workgroup scope is the residency-compatible scope",
        {"NVIDIA": "bar.sync (16 named)", "AMD": "S_BARRIER",
         "Intel": "barrier (WG scope)", "Apple": "threadgroup_barrier"},
        "program order within a core; sequential grid steps / semaphores "
        "across; collectives across chips",
        True),
    Primitive.IDENTITY_REGISTERS: PrimitiveSpec(
        Primitive.IDENTITY_REGISTERS, Classification.INVARIANT,
        "data decomposition requires each execution to know its coordinates",
        {"NVIDIA": "%tid/%ctaid/%laneid", "AMD": "VGPR0 thread_id",
         "Intel": "sr0 local_id", "Apple": "thread_position"},
        "pl.program_id(axis) + jax.lax.axis_index(mesh axis)",
        True),
    Primitive.ASYNC_MEMORY: PrimitiveSpec(
        Primitive.ASYNC_MEMORY, Classification.INVARIANT,
        "overlap of data movement with compute is mandatory once "
        "bandwidth/latency dominate",
        {"NVIDIA": "cp.async/mbarrier", "AMD": "S_WAITCNT counters",
         "Intel": "SEND + scoreboard", "Apple": "device_load + wait"},
        "pltpu.make_async_copy / emit_pipeline + DMA semaphores (direct)",
        True),
    Primitive.LANE_SHUFFLE: PrimitiveSpec(
        Primitive.LANE_SHUFFLE, Classification.INVARIANT,
        "register-speed lane exchange; replacing it with scratchpad "
        "round-trips costs up to 37.5% on latency-sensitive schedulers "
        "(paper §VII.C: the reduction finding)",
        {"NVIDIA": "__shfl_*_sync", "AMD": "DPP/ds_permute",
         "Intel": "sub-group shuffle", "Apple": "simd_shuffle"},
        "intra-vreg lane rotation (pltpu.roll / strided slice-add tree)",
        True),
}


@dataclasses.dataclass(frozen=True)
class KernelContract:
    """Declares which primitives and native features a kernel variant uses."""

    kernel: str
    mode: IsaMode
    primitives: FrozenSet[Primitive]
    native_features: FrozenSet[str] = frozenset()

    def __post_init__(self):
        unknown = self.native_features - NATIVE_FEATURES
        if unknown:
            raise ValueError(f"unknown native features: {sorted(unknown)}")


class ContractViolation(Exception):
    pass


def validate_contract(contract: KernelContract,
                      dialect: Dialect = TARGET) -> None:
    """Enforce the Table V discipline: abstract kernels spend only the
    universal primitive budget and zero native features."""
    illegal = contract.primitives - contract.mode.allowed
    if illegal:
        raise ContractViolation(
            f"{contract.kernel} [{contract.mode.value}] uses primitives "
            f"outside its budget: {sorted(p.name for p in illegal)}")
    if contract.mode in (IsaMode.ABSTRACT, IsaMode.ABSTRACT_SHUFFLE):
        if contract.native_features:
            raise ContractViolation(
                f"{contract.kernel} [{contract.mode.value}] uses native "
                f"features: {sorted(contract.native_features)}")
    if Primitive.LANE_SHUFFLE in contract.primitives and not dialect.has_lane_shuffle:
        raise ContractViolation(
            f"{contract.kernel} requires lane shuffle but dialect "
            f"{dialect.name} lacks it")
    if Primitive.ATOMIC_RMW in contract.primitives and not dialect.has_hw_atomics:
        # Allowed — but only through the privatized-accumulation lowering,
        # which kernels signal by *also* claiming scratchpad + barrier.
        needed = {Primitive.MANAGED_SCRATCHPAD, Primitive.WORKGROUP_BARRIER}
        if not needed <= contract.primitives:
            raise ContractViolation(
                f"{contract.kernel}: dialect {dialect.name} has no HW "
                f"atomics; ATOMIC_RMW must lower to privatize+reduce "
                f"(requires scratchpad+barrier in the contract)")


def invariants() -> Tuple[Primitive, ...]:
    return tuple(p for p in Primitive if SPECS[p].classification
                 is Classification.INVARIANT)


def divergences() -> Tuple[Primitive, ...]:
    return tuple(p for p in Primitive if SPECS[p].classification
                 is Classification.DIVERGENT)
