"""Shared multi-buffered pipeline planning (Eq. 1 occupancy algebra).

Every rowwise kernel in ``repro.kernels`` used to hand-roll the same
staging decisions: a hardcoded ``_BLOCK_ROWS``, ad-hoc padding, and a
per-kernel ``CompilerParams`` switch.  This module centralizes them behind
the paper's own occupancy algebra (Eq. 1, re-derived for buffers in
``Dialect.buffer_occupancy``):

    O = floor(S / (n_buffers × block_bytes))

A :class:`PipelinePlan` picks the largest block that keeps at least
``min_occupancy`` pipeline stages resident (``choose_block_bytes``),
clamped by a per-kernel latency cap, and carries the grid, the padding,
and the ``dimension_semantics`` annotation that only the *native* budget
may spend (``multi_buffering`` + ``dimension_semantics`` are native
features — see ``repro.core.primitives.NATIVE_FEATURES``).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.dialect import Dialect, TARGET
from repro.core.execution_model import choose_block_bytes

#: minimal second-minor granule of a TPU f32 tile (sublanes)
SUBLANES = 8

#: jax renamed TPUCompilerParams -> CompilerParams across releases; the
#: plan is the single place kernels get compiler params from, so the
#: version shim lives here.
CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """Staging decision for a rowwise (grid over row-blocks) kernel."""

    block_rows: int                 # rows per grid step
    row_bytes: int                  # bytes per row of the working set
    n_buffers: int                  # DMA multi-buffer depth
    occupancy: int                  # resident block buffers under Eq. 1
    grid: Tuple[int, ...]           # 1-D grid over row-blocks
    padded_rows: int                # rows after padding to a block multiple
    mode: str                       # isa mode the plan was made for
    semantics: Tuple[str, ...]      # dimension_semantics for native mode

    @property
    def compiler_params(self):
        """Pipeline annotations are a native feature: abstract budgets get
        none (the compiler still runs, but the kernel claims nothing)."""
        if self.mode == "native":
            return CompilerParams(dimension_semantics=self.semantics)
        return None

    @property
    def block_bytes(self) -> int:
        return self.block_rows * self.row_bytes


def plan_row_pipeline(total_rows: int, row_bytes: int, *, mode: str,
                      dialect: Dialect = TARGET, n_buffers: int = 2,
                      max_block_rows: Optional[int] = None,
                      min_occupancy: int = 2, pow2_blocks: bool = False,
                      semantics: Tuple[str, ...] = ("arbitrary",),
                      tuned: Optional[Mapping] = None) -> PipelinePlan:
    """Size a row-block from the dialect scratchpad budget.

    ``max_block_rows`` is the kernel's latency/tail cap (small inputs
    should not pad up to a 16 MB block just because VMEM would fit one).
    ``pow2_blocks`` rounds the block down to a power of two — required by
    kernels whose cross-lane stage tree-reduces over the block rows.

    ``tuned`` is an optional autotuner override (``repro.core.tuning``):
    a mapping with ``block_rows`` / ``n_buffers`` keys.  A tuned block
    supersedes the heuristic *and* the ``max_block_rows`` cap (the cap is
    the untuned guard; table entries are validated against the bounded
    candidate corridor by CI), but the Eq. 1 occupancy invariant and the
    problem-size/pow2 clamps still apply — an entry that would break them
    silently degrades to the heuristic point.
    """
    if total_rows <= 0 or row_bytes <= 0:
        raise ValueError("total_rows and row_bytes must be positive")
    tuned_block = None
    if tuned:
        n_buffers = int(tuned.get("n_buffers", n_buffers))
        if tuned.get("block_rows"):
            tuned_block = max(SUBLANES,
                              int(tuned["block_rows"]) // SUBLANES * SUBLANES)
    budget = choose_block_bytes(total_rows * row_bytes, dialect,
                                n_buffers=n_buffers,
                                min_occupancy=min_occupancy)
    block_rows = max(SUBLANES, (budget // row_bytes) // SUBLANES * SUBLANES)
    if max_block_rows is not None:
        block_rows = min(block_rows, max_block_rows)
    if tuned_block is not None and dialect.buffer_occupancy(
            tuned_block * row_bytes, n_buffers) >= min_occupancy:
        block_rows = tuned_block
    # never pad a small input past one block of its own (rounded) size
    rounded_total = -(-total_rows // SUBLANES) * SUBLANES
    block_rows = min(block_rows, rounded_total)
    if pow2_blocks:
        block_rows = 1 << (block_rows.bit_length() - 1)
    padded_rows = -(-total_rows // block_rows) * block_rows
    return PipelinePlan(
        block_rows=block_rows, row_bytes=row_bytes, n_buffers=n_buffers,
        occupancy=dialect.buffer_occupancy(block_rows * row_bytes, n_buffers),
        grid=(padded_rows // block_rows,), padded_rows=padded_rows,
        mode=mode, semantics=semantics)


def pad_rows(x2d: jax.Array, plan: PipelinePlan,
             constant_value=0) -> jax.Array:
    """Pad a ``(rows, d)`` array up to the plan's block multiple."""
    pad = plan.padded_rows - x2d.shape[0]
    if pad:
        x2d = jnp.pad(x2d, ((0, pad), (0, 0)),
                      constant_values=constant_value)
    return x2d
