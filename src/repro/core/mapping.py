"""Mapping analysis (paper §VI / Fig. 3): abstract model -> vendor backends.

Renders the per-vendor mapping tables (the paper's Fig. 3 as text) and the
TPU adaptation table from DESIGN.md §2, entirely from the structured specs
in :mod:`repro.core.primitives` and :mod:`repro.core.dialect` — so the
report and the enforced contracts can never drift apart.
"""
from __future__ import annotations

from typing import List

from repro.core import dialect as D
from repro.core import primitives as P


def mapping_rows(vendor: str) -> List[tuple]:
    rows = []
    for prim in P.Primitive:
        spec = P.SPECS[prim]
        native = spec.vendor_realization.get(vendor, "n/a")
        rows.append((prim.value, prim.name, spec.classification.value, native))
    return rows


def render_table(headers, rows) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    def fmt(row):
        return " | ".join(str(c).ljust(w) for c, w in zip(row, widths))
    sep = "-+-".join("-" * w for w in widths)
    return "\n".join([fmt(headers), sep] + [fmt(r) for r in rows])


def vendor_mapping_report() -> str:
    """Fig. 3 as text: every primitive's realization on all four vendors."""
    headers = ["#", "primitive", "class"] + [d.vendor for d in D.gpu_dialects()]
    rows = []
    for prim in P.Primitive:
        spec = P.SPECS[prim]
        rows.append([prim.value, prim.name, spec.classification.value[:5]] +
                    [spec.vendor_realization.get(d.vendor, "n/a")
                     for d in D.gpu_dialects()])
    return render_table(headers, rows)


def tpu_adaptation_report() -> str:
    """DESIGN.md §2: primitive -> TPU v5e realization, flagging indirect maps."""
    headers = ["#", "primitive", "direct?", "TPU realization"]
    rows = [[p.value, p.name, "yes" if P.SPECS[p].tpu_direct else "ADAPTED",
             P.SPECS[p].tpu_realization] for p in P.Primitive]
    return render_table(headers, rows)


def dialect_table() -> str:
    """Paper Table III + the TPU column."""
    ds = list(D.gpu_dialects()) + [D.TPU_V5E]
    headers = ["parameter"] + [d.vendor for d in ds]
    rows = [
        ["wave width W"] + ["/".join(map(str, d.wave_width)) for d in ds],
        ["max regs R"] + [d.R for d in ds],
        ["scratchpad S"] + [f"{d.S // 1024}K" for d in ds],
        ["max workgroup"] + [d.max_workgroup for d in ds],
        ["named barriers"] + [d.named_barriers for d in ds],
        ["native FP64"] + ["yes" if d.native_fp64 else "no" for d in ds],
        ["matrix tile"] + [str(d.matrix_unit.tile) if d.matrix_unit else "absent"
                           for d in ds],
        ["HW atomics"] + ["yes" if d.has_hw_atomics else "NO" for d in ds],
        ["lane shuffle"] + ["yes" if d.has_lane_shuffle else "no" for d in ds],
    ]
    return render_table(headers, rows)


def full_report() -> str:
    parts = [
        "== Parameterizable dialects (paper Table III + TPU target) ==",
        dialect_table(),
        "",
        "== Invariant/divergent primitives across vendors (Table II / Fig. 3) ==",
        vendor_mapping_report(),
        "",
        "== TPU v5e adaptation (DESIGN.md section 2) ==",
        tpu_adaptation_report(),
    ]
    return "\n".join(parts)
