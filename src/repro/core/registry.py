"""Dialect-aware lowering registry + execution policy (the Table V dispatch).

The paper's central mechanism is that programs never hardcode vendor
parameters — they query a dialect and the runtime picks the legal lowering.
This module is that mechanism as a subsystem:

- Every kernel variant registers a :class:`Lowering` —
  ``(op, IsaMode, KernelContract, structural_cost, impl)`` — and is
  contract-checked **at registration time** (a variant whose primitive
  budget is out of contract cannot even be installed).
- An :class:`ExecutionPolicy` (dialect, mode preference or ``"auto"``,
  interpret flag) is resolved once per model/run and threaded through the
  layers above the kernels; every norm/attention/reduce hot spot routes
  through :meth:`LoweringRegistry.select` instead of per-call-site mode
  strings.
- ``"auto"`` selects the cheapest registered variant whose contract is
  legal for the active dialect, ranked by the kernel's own
  ``structural_cost`` model (scratch traffic first — the §VII.C currency),
  falling back to the jnp ``library`` reference only when no Pallas
  lowering is legal (e.g. a shuffle-only op on a ``has_lane_shuffle=False``
  dialect).
- Unsupported modes are handled by **declared fallbacks** (e.g. GEMM has
  no shuffle variant: the MXU contraction *is* its cross-lane stage), which
  warn and are recorded in :attr:`LoweringRegistry.fallback_events` — never
  by silent rewrites.

Native lowerings are *pinned* to the dialect they were built against
(their ``native_features`` are that target's feature set), so under a
foreign dialect only the portable budgets compete — the paper's Table V
discipline as runtime behavior.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import inspect
import warnings
from typing import Callable, Dict, Mapping, Optional, Tuple

from repro.core.dialect import Dialect, TARGET, get_dialect
from repro.core.primitives import (ContractViolation, IsaMode,
                                   KernelContract, validate_contract)

#: the mode strings a policy may request, beyond the IsaMode values
AUTO = "auto"
POLICY_MODES = tuple(m.value for m in IsaMode) + (AUTO,)

#: weight-precision knobs a policy may carry.  ``None`` and ``"f32"`` both
#: mean full precision; other values retarget ops that registered a
#: precision variant (ISSUE 7: ``"int8"`` — per-channel-scaled weights
#: dequantized in VMEM).
POLICY_PRECISIONS = (None, "f32", "int8")

#: stable cheapness tiebreak: smaller primitive budget wins a cost tie,
#: the library escape hatch never wins one.
_PORTABILITY = {IsaMode.ABSTRACT: 0, IsaMode.ABSTRACT_SHUFFLE: 1,
                IsaMode.NATIVE: 2, IsaMode.LIBRARY: 3}


class UnsupportedLowering(RuntimeError):
    """Requested a lowering the registry cannot legally provide."""


class LoweringFallbackWarning(UserWarning):
    """A declared fallback (or the auto library escape) was taken."""


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How the layers above the kernels want their hot spots lowered.

    ``mode`` is an :class:`IsaMode` value or ``"auto"``; ``dialect`` names
    the target whose legality rules apply; ``interpret`` overrides the
    Pallas interpret default (None = backend-derived).  ``kernel_mode``
    optionally overrides ``mode`` for explicitly kernel-routed paths (the
    ``use_pallas_attn`` flash hot spot keeps its target-native variant
    while model norms default to the XLA library lowering).

    ``fuse`` gates the multi-op fused lowerings (``rmsnorm_matmul`` /
    ``add_rmsnorm``) at the model hot pairs: ``True`` routes the pairs
    through the fused ops, ``False`` keeps the unfused sequence, and
    ``None`` (default) fuses exactly when ``mode == "auto"`` — the policy
    that ranks lowerings by structural cost is the one that should pick
    the variant whose ``hbm_bytes`` dropped by an activation round trip.

    ``precision`` treats weight precision as one more dialect parameter
    (ISSUE 7): ``"int8"`` retargets every op that registered a precision
    variant (:meth:`LoweringRegistry.register_precision_variant`) to its
    quantized twin at the :meth:`LoweringRegistry.select` dispatch point,
    wherever the dialect keeps that variant legal; ops without a variant
    are untouched (the declared-fallback discipline, not an error).
    """

    mode: str = AUTO
    dialect: str = TARGET.name
    interpret: Optional[bool] = None
    kernel_mode: Optional[str] = None
    fuse: Optional[bool] = None
    precision: Optional[str] = None

    def __post_init__(self):
        for m in (self.mode, self.kernel_mode):
            if m is not None and m not in POLICY_MODES:
                raise ValueError(
                    f"unknown isa mode {m!r}; valid: {POLICY_MODES}")
        if self.precision not in POLICY_PRECISIONS:
            raise ValueError(
                f"unknown precision {self.precision!r}; valid: "
                f"{POLICY_PRECISIONS}")

    def resolved_dialect(self) -> Dialect:
        return get_dialect(self.dialect)

    def kernel(self) -> "ExecutionPolicy":
        """The policy for kernel-routed hot spots (flash attention)."""
        if self.kernel_mode is None or self.kernel_mode == self.mode:
            return self
        return dataclasses.replace(self, mode=self.kernel_mode,
                                   kernel_mode=None)

    def fuses(self) -> bool:
        """Whether model hot pairs route through the fused lowerings."""
        if self.fuse is not None:
            return self.fuse
        return self.mode == AUTO


#: seed-equivalent defaults: bare kernel-API calls keep the target-native
#: variant; model-level norms keep the XLA library lowering.
DEFAULT_POLICY = ExecutionPolicy(mode=IsaMode.NATIVE.value)
LIBRARY_POLICY = ExecutionPolicy(mode=IsaMode.LIBRARY.value)
AUTO_POLICY = ExecutionPolicy(mode=AUTO)

_policy_var: contextvars.ContextVar[Optional[ExecutionPolicy]] = \
    contextvars.ContextVar("uisa_execution_policy", default=None)

#: ambient mesh axes installed by :func:`use_mesh_axes` — the axis-name ->
#: size mapping the collective cost terms resolve their group size from
_mesh_axes_var: contextvars.ContextVar[Optional[Mapping[str, int]]] = \
    contextvars.ContextVar("uisa_mesh_axes", default=None)

#: the tensor-parallel mesh axis the collective twins shard over
TP_AXIS = "model"


@contextlib.contextmanager
def use_mesh_axes(axes: Mapping[str, int]):
    """Install ``axes`` (axis name -> size) as the ambient mesh for the
    dynamic extent.  This is the planner-side mirror of a ``jax.Mesh``
    context: selection and cost modeling read axis sizes from here first,
    so mesh-sensitive ranking can run without constructing devices."""
    token = _mesh_axes_var.set(dict(axes))
    try:
        yield axes
    finally:
        _mesh_axes_var.reset(token)


def ambient_mesh_axes() -> Dict[str, int]:
    """The ambient mesh axis sizes: :func:`use_mesh_axes` first, else the
    active ``jax.Mesh`` context (``with mesh:``), else empty."""
    axes = _mesh_axes_var.get()
    if axes is not None:
        return dict(axes)
    try:  # resolve from an active `with Mesh(...)` context, if any
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return dict(mesh.shape)
    except Exception:
        pass
    return {}


def tp_axis_size(axis: str = TP_AXIS) -> int:
    """Size of the tensor-parallel axis in the ambient mesh (1 = no TP)."""
    return int(ambient_mesh_axes().get(axis, 1))


def current_policy() -> Optional[ExecutionPolicy]:
    """The ambient policy installed by :func:`use_policy`, if any."""
    return _policy_var.get()


@contextlib.contextmanager
def use_policy(policy: ExecutionPolicy):
    """Install ``policy`` as the ambient default for the dynamic extent.

    Read at *trace* time: code already jitted under a different policy
    keeps its traced lowering (policies are resolved once, not per call).
    """
    token = _policy_var.set(policy)
    try:
        yield policy
    finally:
        _policy_var.reset(token)


def resolve_policy(mode=None, policy: Optional[ExecutionPolicy] = None,
                   default: ExecutionPolicy = DEFAULT_POLICY
                   ) -> ExecutionPolicy:
    """One resolution point: explicit mode > explicit policy > ambient >
    ``default``.  A ``mode`` override keeps the rest of the resolved
    policy (dialect, interpret) — the legality check must still run
    against the caller's dialect, not silently revert to the target."""
    base = policy or current_policy() or default
    if mode is not None:
        if isinstance(mode, IsaMode):
            mode = mode.value
        return dataclasses.replace(base, mode=mode, kernel_mode=None)
    return base


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One registered realization of an abstract op."""

    op: str
    mode: IsaMode
    impl: Callable
    contract: KernelContract
    cost: Optional[Callable[..., Mapping]] = None
    #: dialect a native lowering is pinned to (its native_features are that
    #: target's feature set); portable lowerings carry None
    target: Optional[str] = None

    def structural_cost(self, plan_dialect: Optional[str] = None,
                        **shape) -> Mapping:
        """Modeled cost at ``shape``; ``plan_dialect`` names the tuning-
        table slice the model consults (None = ambient, then TARGET)."""
        if self.cost is None:
            return {}
        if plan_dialect is None:
            return self.cost(**shape)
        return self.cost(plan_dialect=plan_dialect, **shape)


@dataclasses.dataclass(frozen=True)
class Fallback:
    op: str
    missing: IsaMode
    to: IsaMode
    reason: str


@dataclasses.dataclass(frozen=True)
class FallbackEvent:
    op: str
    requested: str
    used: str
    reason: str


def cost_key(cost: Mapping, mode: IsaMode) -> Tuple:
    """Cheapness ranking for auto selection.

    Scratch traffic is the §VII.C currency, round trips its latency proxy,
    HBM bytes the bandwidth term; the primitive-budget rank breaks ties in
    favor of the more portable variant (so abstract+shuffle beats native
    when both model to zero scratch).  Collective traffic (ISSUE 10) is
    folded into the bandwidth term pre-converted to HBM-equivalent bytes
    (wire bytes x hbm_bw/link_bw + hop latency x hbm_bw), so a TP-fused
    variant's saved weight streams compete directly against the
    all-reduce it pays."""
    return (cost.get("scratch_bytes_total", 0),
            cost.get("scratch_round_trips_per_block", 0),
            cost.get("hbm_bytes", 0)
            + cost.get("collective_hbm_equiv_bytes", 0),
            _PORTABILITY[mode])


class LoweringRegistry:
    """op -> {IsaMode -> Lowering}, plus declared fallbacks + event log."""

    #: retained fallback events — bounded so a long-lived serving process
    #: whose policy takes a fallback on every retrace cannot grow it
    EVENT_LOG_MAXLEN = 256

    def __init__(self):
        self._variants: Dict[str, Dict[IsaMode, Lowering]] = {}
        self._fallbacks: Dict[Tuple[str, IsaMode], Fallback] = {}
        #: (base op, precision) -> quantized op name (ISSUE 7)
        self._precision_variants: Dict[Tuple[str, str], str] = {}
        #: base op -> TP twin op name (ISSUE 10): the sharded lowering
        #: that pays a collective, competing under auto when the ambient
        #: mesh carries a model axis
        self._collective_variants: Dict[str, str] = {}
        self.fallback_events: "collections.deque[FallbackEvent]" = \
            collections.deque(maxlen=self.EVENT_LOG_MAXLEN)

    # ---- registration (contract-checked) ----

    def register(self, op: str, mode, impl: Callable, *,
                 contract: Optional[KernelContract] = None,
                 cost: Optional[Callable[..., Mapping]] = None,
                 target: Optional[str] = None,
                 override: bool = False) -> Lowering:
        """Install a variant.  Raises :class:`ContractViolation` when the
        declared contract is out of budget, drifted (wrong op/mode), or
        illegal on its own target dialect."""
        mode = IsaMode(mode)
        if contract is None:
            if mode is not IsaMode.LIBRARY:
                raise ContractViolation(
                    f"{op} [{mode.value}]: non-library lowerings must "
                    f"declare a KernelContract")
            # the XLA-native op: no Pallas primitive budget to police
            contract = KernelContract(kernel=op, mode=IsaMode.LIBRARY,
                                      primitives=frozenset())
        if contract.kernel != op or contract.mode is not mode:
            raise ContractViolation(
                f"contract drift: registering {op} [{mode.value}] with a "
                f"contract for {contract.kernel} [{contract.mode.value}]")
        if contract.native_features and target is None:
            target = TARGET.name
        validate_contract(contract,
                          TARGET if target is None else get_dialect(target))
        # the dispatch layer injects plan_dialect= into every impl call
        # (kernels/ops.py::_dispatch) — enforce that signature contract
        # here, where the variant is declared, not at first dispatch
        try:
            params = inspect.signature(impl).parameters
        except (TypeError, ValueError):   # C callables etc.: trust them
            params = None
        if params is not None and "plan_dialect" not in params and not any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in params.values()):
            raise ContractViolation(
                f"{op} [{mode.value}]: impl must accept a plan_dialect "
                f"keyword (the dispatch layer passes the policy's "
                f"dialect as a static staging-plan argument)")
        variants = self._variants.setdefault(op, {})
        if mode in variants and not override:
            raise ValueError(f"{op} [{mode.value}] already registered")
        low = Lowering(op=op, mode=mode, impl=impl, contract=contract,
                       cost=cost, target=target)
        variants[mode] = low
        return low

    def declare_fallback(self, op: str, missing, to, reason: str) -> None:
        """Declare that requesting ``missing`` for ``op`` legally lowers to
        ``to`` — the explicit replacement for silent mode rewrites."""
        missing, to = IsaMode(missing), IsaMode(to)
        self._fallbacks[(op, missing)] = Fallback(op, missing, to, reason)

    def register_precision_variant(self, base_op: str, precision: str,
                                   quant_op: str) -> None:
        """Declare that ``base_op`` under ``ExecutionPolicy(precision=)``
        dispatches to ``quant_op`` — the quantized twin registered as its
        own op (own contracts, own costs, own fallbacks).  Both ops must
        already be registered; the mapping is consulted once, at
        :meth:`select` entry, so every downstream decision (mode legality,
        auto ranking, declared fallbacks) runs against the quantized op's
        own rows."""
        if precision not in POLICY_PRECISIONS or precision in (None, "f32"):
            raise ValueError(f"not a quantized precision: {precision!r}")
        for name in (base_op, quant_op):
            if name not in self._variants:
                raise UnsupportedLowering(
                    f"precision variant maps unknown op {name!r}")
        self._precision_variants[(base_op, precision)] = quant_op

    def precision_variant(self, op: str, precision: Optional[str]
                          ) -> Optional[str]:
        """The quantized twin of ``op`` at ``precision``, if declared."""
        if precision in (None, "f32"):
            return None
        return self._precision_variants.get((op, precision))

    def register_collective_variant(self, base_op: str, tp_op: str) -> None:
        """Declare that ``tp_op`` is the tensor-parallel twin of
        ``base_op`` — a registered op whose structural cost carries a
        ``collective_hbm_equiv_bytes`` term.  Under ``mode="auto"`` with a
        model axis in the ambient mesh, the twin's legal variants join the
        base op's candidate set, so replicated-vs-TP is decided by the
        same cost ranking that decides everything else.  Both ops must
        already be registered; every mode of the twin must declare its
        collective term (validate_contracts.py gates this)."""
        for name in (base_op, tp_op):
            if name not in self._variants:
                raise UnsupportedLowering(
                    f"collective variant maps unknown op {name!r}")
        self._collective_variants[base_op] = tp_op

    def collective_variant(self, op: str) -> Optional[str]:
        """The TP twin of ``op``, if declared."""
        return self._collective_variants.get(op)

    def collective_variants(self) -> Dict[str, str]:
        """All declared base -> TP-twin pairs (drives the CI gate)."""
        return dict(self._collective_variants)

    def unregister(self, op: str, mode=None) -> None:
        if mode is None:
            self._variants.pop(op, None)
            for key in [k for k in self._fallbacks if k[0] == op]:
                del self._fallbacks[key]
            for key in [k for k, v in self._precision_variants.items()
                        if k[0] == op or v == op]:
                del self._precision_variants[key]
            for key in [k for k, v in self._collective_variants.items()
                        if k == op or v == op]:
                del self._collective_variants[key]
        else:
            self._variants.get(op, {}).pop(IsaMode(mode), None)

    # ---- introspection (drives benchmarks and CI validation) ----

    def ops(self) -> Tuple[str, ...]:
        return tuple(sorted(self._variants))

    def modes(self, op: str) -> Tuple[str, ...]:
        """Registered mode strings in canonical (portability) order."""
        modes = sorted(self._variants[op], key=_PORTABILITY.__getitem__)
        return tuple(m.value for m in modes)

    def variant(self, op: str, mode) -> Lowering:
        try:
            return self._variants[op][IsaMode(mode)]
        except KeyError:
            raise UnsupportedLowering(
                f"{op} has no registered {mode!r} lowering") from None

    def contracts(self, op: str) -> Tuple[KernelContract, ...]:
        modes = sorted(self._variants[op], key=_PORTABILITY.__getitem__)
        return tuple(self._variants[op][m].contract for m in modes)

    def structural_cost(self, op: str, mode, **shape) -> Mapping:
        return self.variant(op, mode).structural_cost(**shape)

    def fallback_for(self, op: str, mode) -> Optional[Fallback]:
        return self._fallbacks.get((op, IsaMode(mode)))

    # ---- legality ----

    def legal(self, op: str, mode, dialect: Dialect) -> bool:
        """Table V legality of a registered variant under ``dialect``."""
        low = self._variants[op].get(IsaMode(mode))
        if low is None:
            return False
        if low.target is not None and low.target != dialect.name:
            return False          # native lowerings are target-pinned
        try:
            validate_contract(low.contract, dialect)
            return True
        except ContractViolation:
            return False

    # ---- the dispatch point ----

    def select(self, op: str, policy: Optional[ExecutionPolicy] = None,
               shape: Optional[Mapping] = None) -> Lowering:
        """Resolve policy -> one legal Lowering (the single dispatch
        point every call site above repro/kernels routes through)."""
        policy = policy or current_policy() or DEFAULT_POLICY
        dialect = policy.resolved_dialect()
        # precision retarget (ISSUE 7): a policy carrying precision="int8"
        # dispatches to the quantized twin wherever one is declared — the
        # retargeted op then competes on its own contracts/costs/fallbacks
        quant_op = self.precision_variant(op, policy.precision)
        if quant_op is not None:
            op = quant_op
        try:
            variants = self._variants[op]
        except KeyError:
            raise UnsupportedLowering(f"unknown op {op!r}; registered: "
                                      f"{self.ops()}") from None
        if policy.mode != AUTO:
            mode = IsaMode(policy.mode)
            if mode in variants and self.legal(op, mode, dialect):
                return variants[mode]
            fb = self._fallbacks.get((op, mode))
            if fb is not None and fb.to in variants \
                    and self.legal(op, fb.to, dialect):
                self._record(op, mode.value, fb.to.value, fb.reason)
                return variants[fb.to]
            raise UnsupportedLowering(
                f"{op} [{mode.value}] is not a legal lowering for dialect "
                f"{dialect.name} and declares no fallback")
        # auto: cheapest legal non-library variant by structural cost,
        # ranked with the policy's dialect bound *explicitly* so the
        # dialect-aware cost terms (tuned-table lookups) read the dialect
        # being selected for — the same binding the dispatch layer then
        # threads into the kernel as its static plan_dialect argument
        candidates = [low for m, low in variants.items()
                      if m is not IsaMode.LIBRARY
                      and self.legal(op, m, dialect)]
        # mesh-sensitive ranking (ISSUE 10): with a model axis in the
        # ambient mesh, the declared TP twin's variants compete too — its
        # cost trades sharded weight streams against the collective term,
        # so the same shape picks TP-fused or replicated per mesh size
        tp_op = self._collective_variants.get(op)
        if tp_op is not None and tp_axis_size() > 1:
            candidates += [low for m, low
                           in self._variants.get(tp_op, {}).items()
                           if m is not IsaMode.LIBRARY
                           and self.legal(tp_op, m, dialect)]
        if candidates:
            shape = shape or {}
            return min(candidates,
                       key=lambda lo: cost_key(
                           lo.structural_cost(plan_dialect=dialect.name,
                                              **shape), lo.mode))
        library = variants.get(IsaMode.LIBRARY)
        if library is not None:
            self._record(op, AUTO, IsaMode.LIBRARY.value,
                         f"no portable lowering legal for {dialect.name}")
            return library
        raise UnsupportedLowering(
            f"{op}: no lowering legal for dialect {dialect.name} and no "
            f"library reference registered")

    def _record(self, op: str, requested: str, used: str,
                reason: str) -> None:
        event = FallbackEvent(op, requested, used, reason)
        self.fallback_events.append(event)
        warnings.warn(f"{op}: {requested} -> {used} ({reason})",
                      LoweringFallbackWarning, stacklevel=3)


#: the process-wide registry every kernel module installs its variants in
REGISTRY = LoweringRegistry()
