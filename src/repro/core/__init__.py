"""UISA core: the paper's contribution as a composable layer.

- :mod:`repro.core.dialect` — parameterizable dialects (Table III), queryable.
- :mod:`repro.core.primitives` — the 11 primitives (Table II + §VII.C) and
  the kernel-contract validator behind the native/abstract methodology.
- :mod:`repro.core.execution_model` — thread hierarchy, Eq. 1 occupancy.
- :mod:`repro.core.memory_model` — scoped acquire/release (Fig. 2).
- :mod:`repro.core.mapping` — Fig. 3 mapping reports.
- :mod:`repro.core.shuffle` — primitive 11 as a first-class API (§VII.C).
- :mod:`repro.core.pipeline` — shared multi-buffer staging plans (Eq. 1).
- :mod:`repro.core.registry` — dialect-aware lowering registry + execution
  policy (Table V dispatch as a subsystem).
"""
from repro.core.dialect import (Dialect, DIALECTS, TARGET, TPU_V5E,
                                UISA_UNIVERSAL10, get_dialect, gpu_dialects,
                                mxu_align, align_up)
from repro.core.primitives import (Primitive, IsaMode, KernelContract,
                                   ContractViolation, validate_contract,
                                   UNIVERSAL_SET, UNIVERSAL_PLUS_SHUFFLE,
                                   SPECS, Classification)
from repro.core.execution_model import (LaunchGeometry, LaunchError,
                                        validate_launch, occupancy,
                                        tpu_pipeline_occupancy,
                                        choose_block_bytes, grid_for)
from repro.core.memory_model import (Scope, Ordering, fence, requires_fence,
                                     MANDATORY_HIERARCHY)
from repro.core.shuffle import (lane_shuffle_down, lane_shuffle_up,
                                lane_shuffle_xor, lane_tree_reduce,
                                fold_rows, row_reduce_shuffle,
                                scratch_tree_reduce, tree_stages,
                                scratch_tree_bytes)
from repro.core.pipeline import PipelinePlan, plan_row_pipeline, pad_rows
from repro.core.tuning import (TUNING_TABLE, TuningTable, register_op_space,
                               tuned_attention_blocks, tuned_block,
                               tuned_plan)
from repro.core.registry import (AUTO_POLICY, DEFAULT_POLICY, ExecutionPolicy,
                                 LIBRARY_POLICY, Lowering,
                                 LoweringFallbackWarning, LoweringRegistry,
                                 REGISTRY, UnsupportedLowering,
                                 current_policy, resolve_policy, use_policy)

__all__ = [
    "Dialect", "DIALECTS", "TARGET", "TPU_V5E", "UISA_UNIVERSAL10",
    "get_dialect", "gpu_dialects",
    "mxu_align", "align_up", "Primitive", "IsaMode", "KernelContract",
    "ContractViolation", "validate_contract", "UNIVERSAL_SET",
    "UNIVERSAL_PLUS_SHUFFLE", "SPECS", "Classification", "LaunchGeometry",
    "LaunchError", "validate_launch", "occupancy", "tpu_pipeline_occupancy",
    "choose_block_bytes", "grid_for", "Scope", "Ordering", "fence",
    "requires_fence", "MANDATORY_HIERARCHY", "lane_shuffle_down",
    "lane_shuffle_up", "lane_shuffle_xor", "lane_tree_reduce", "fold_rows",
    "row_reduce_shuffle", "scratch_tree_reduce", "tree_stages",
    "scratch_tree_bytes", "PipelinePlan", "plan_row_pipeline", "pad_rows",
    "AUTO_POLICY", "DEFAULT_POLICY", "ExecutionPolicy", "LIBRARY_POLICY",
    "Lowering", "LoweringFallbackWarning", "LoweringRegistry", "REGISTRY",
    "UnsupportedLowering", "current_policy", "resolve_policy", "use_policy",
]
