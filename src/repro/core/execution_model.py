"""The abstract execution model (paper §V) with TPU lowering hooks.

Thread hierarchy (Fig. 1): Grid -> Workgroup -> Wave -> lane, plus the
optional cluster level.  On the TPU target a "workgroup" lowers to one
Pallas grid step on one core, a "wave" to a 128-lane vector, and the grid to
the Pallas grid x the device mesh.

The model is deliberately *thin* (§VIII.B): it validates launch geometry
against the active dialect and computes occupancies, but never prescribes
how a backend schedules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

from repro.core.dialect import Dialect, TARGET, REGISTER_WIDTH_BYTES


@dataclasses.dataclass(frozen=True)
class LaunchGeometry:
    """Grid x workgroup shape in the abstract model."""

    grid: Tuple[int, ...]
    workgroup: int                      # threads per workgroup
    regs_per_thread: int = 32
    scratchpad_bytes: int = 0
    cluster: Optional[int] = None       # optional 4th level (Fig. 1, dashed)

    @property
    def total_workgroups(self) -> int:
        return math.prod(self.grid)

    @property
    def total_threads(self) -> int:
        return self.total_workgroups * self.workgroup


class LaunchError(Exception):
    pass


def validate_launch(geom: LaunchGeometry, dialect: Dialect = TARGET) -> None:
    """Reject geometries the dialect cannot host (thin checks only)."""
    if any(g <= 0 for g in geom.grid):
        raise LaunchError(f"grid must be positive, got {geom.grid}")
    if dialect.max_workgroup > 1 and not dialect.validate_workgroup(geom.workgroup):
        raise LaunchError(
            f"workgroup {geom.workgroup} exceeds dialect max "
            f"{dialect.max_workgroup}")
    if geom.scratchpad_bytes > dialect.S:
        raise LaunchError(
            f"scratchpad request {geom.scratchpad_bytes} exceeds dialect "
            f"S={dialect.S}")
    if geom.regs_per_thread > dialect.R:
        raise LaunchError(
            f"register request {geom.regs_per_thread} exceeds dialect "
            f"R={dialect.R}")


def occupancy(geom: LaunchGeometry, dialect: Dialect = TARGET) -> int:
    """Resident waves per core under Eq. 1, bounded by scratchpad demand.

    Classic GPU occupancy calculation, driven entirely by dialect queries:
      O_regs  = floor(F / (R*W*w))          (Eq. 1)
      O_scr   = floor(S / scratch_per_wg) * waves_per_wg
    """
    o_regs = dialect.occupancy(geom.regs_per_thread)
    if geom.scratchpad_bytes > 0 and dialect.max_workgroup > 1:
        waves_per_wg = max(1, math.ceil(geom.workgroup / dialect.W))
        o_scr = (dialect.S // geom.scratchpad_bytes) * waves_per_wg
        return max(0, min(o_regs, o_scr))
    return max(0, o_regs)


def tpu_pipeline_occupancy(block_bytes: int, n_buffers: int = 2,
                           dialect: Dialect = TARGET) -> int:
    """The TPU re-derivation of Eq. 1 (see Dialect.buffer_occupancy)."""
    return dialect.buffer_occupancy(block_bytes, n_buffers)


def choose_block_bytes(working_set: int, dialect: Dialect = TARGET,
                       n_buffers: int = 2, min_occupancy: int = 2) -> int:
    """Pick the largest block working-set that keeps >= min_occupancy
    pipeline stages resident — the kernel-side consumer of the occupancy
    tradeoff.  Returns a byte budget, clamped to the dialect scratchpad."""
    budget = dialect.S // (n_buffers * min_occupancy)
    return min(working_set, max(1, budget))


@dataclasses.dataclass(frozen=True)
class WaveView:
    """Lane-level view inside one wave: identity registers (primitive 9)."""

    wave_width: int

    def lane_ids(self):
        """Abstract iota over lanes; backends realize it natively
        (%laneid / VGPR0 / sr0 / thread_position / broadcasted_iota)."""
        import jax.numpy as jnp
        return jnp.arange(self.wave_width, dtype=jnp.int32)


def grid_for(total: int, per_step: int) -> int:
    """Ceil-div grid sizing helper used by kernels."""
    if per_step <= 0:
        raise ValueError("per_step must be positive")
    return -(-total // per_step)
