"""Scoped memory model (paper Fig. 2 + Table IV 'memory order' resolution).

The paper resolves the axiomatic/counter/scoreboard/async divergence with
scoped acquire/release at four scopes: wave, workgroup, device, system.
On the TPU target the scopes lower to:

  wave       -> program order within a vreg expression (vacuous)
  workgroup  -> program order within one core's kernel body / grid-step
                sequencing (Pallas grids are sequential per core unless
                annotated 'parallel')
  device     -> XLA schedule on one chip (DMA semaphores in Pallas)
  system     -> cross-chip collectives / jax.experimental multihost sync

``fence`` is a no-op *value barrier* on CPU/TPU single-core semantics but is
kept in the API so kernels written against the model carry their ordering
intent — the validator uses it to check that abstract kernels never assume
ordering the model does not grant.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Tuple


class Scope(enum.Enum):
    WAVE = "wave"
    WORKGROUP = "workgroup"
    DEVICE = "device"
    SYSTEM = "system"

    @property
    def rank(self) -> int:
        return {"wave": 0, "workgroup": 1, "device": 2, "system": 3}[self.value]


class Ordering(enum.Enum):
    RELAXED = "relaxed"
    ACQUIRE = "acquire"
    RELEASE = "release"
    ACQ_REL = "acq_rel"


@dataclasses.dataclass(frozen=True)
class MemorySpace:
    """One level of the mandatory 3-level hierarchy (+ optional levels)."""

    name: str
    scope: Scope        # widest scope at which this space is coherent
    explicit: bool      # programmer-managed placement (scratchpad) or not


REGISTERS = MemorySpace("registers", Scope.WAVE, explicit=True)
SCRATCHPAD = MemorySpace("scratchpad", Scope.WORKGROUP, explicit=True)
DEVICE_MEMORY = MemorySpace("device", Scope.SYSTEM, explicit=False)

MANDATORY_HIERARCHY: Tuple[MemorySpace, ...] = (
    REGISTERS, SCRATCHPAD, DEVICE_MEMORY)


def fence(scope: Scope, ordering: Ordering = Ordering.ACQ_REL) -> None:
    """Ordering intent marker.  On the TPU/XLA lowering all four scopes are
    satisfied by program order + the collective/DMA semantics already
    implied by the op stream, so this is an (auditable) no-op."""
    assert isinstance(scope, Scope) and isinstance(ordering, Ordering)


def requires_fence(producer_scope: Scope, consumer_scope: Scope) -> bool:
    """True when a release/acquire pair is needed for the handoff: any
    communication at a scope wider than WAVE needs one at >= that scope."""
    widest = max(producer_scope.rank, consumer_scope.rank)
    return widest >= Scope.WORKGROUP.rank
