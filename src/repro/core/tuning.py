"""Cost-model autotuner for pipeline staging parameters (Eq. 1 driven).

``plan_row_pipeline`` picks ONE heuristic point per kernel: the largest
block that keeps ``min_occupancy`` stages resident at ``n_buffers=2``,
clamped by a hand-derived per-kernel latency cap.  Microbenchmark-driven
work (Demystifying the Nvidia Ampere Architecture, arXiv:2208.11174) and
binary-portability systems (HetGPU, arXiv:2506.15993) both show that the
winning block/staging parameters are *target-measured*, not hand-derived —
the gap between "portable" and "as fast as the hardware allows".

This module closes that gap in three steps:

1. **Candidate grids** — :func:`rowwise_candidates`,
   :func:`gemm_candidates`, :func:`attention_candidates` enumerate every
   ``(block, n_buffers)`` point that is *legal* under the dialect's Eq. 1
   occupancy algebra (``Dialect.buffer_occupancy``), exploring up to a
   bounded corridor beyond each kernel's static latency cap.
2. **Structural ranking** — candidates are ordered by the modeled cost the
   paper says decides outcomes (§VII.C): fewest DMA grid steps first, then
   enough resident pipeline stages (capped — beyond ``OCCUPANCY_CAP``
   extra stages hide no additional latency), deeper buffering breaking
   ties.  :func:`measure_candidates` optionally re-ranks the structural
   top-k by live wall clock on the active backend.
3. **Persistence** — winners live in a per-``(op, mode, dialect,
   shape-bucket)`` JSON table (:data:`DEFAULT_TABLE_PATH`, committed,
   loaded at import as :data:`TABLE`).  Kernels consult it through
   :func:`tuned_plan` / :func:`tuned_block` / :func:`tuned_attention_blocks`;
   a missing or illegal entry silently degrades to the heuristic, so the
   table can never make a legal plan illegal.

``scripts/autotune.py`` regenerates the table;
``scripts/validate_contracts.py`` asserts (via :func:`check_table`) that
every committed entry is inside its op's legal candidate grid — stale or
illegal entries fail CI without needing a TPU.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core.dialect import DIALECTS, Dialect, TARGET, get_dialect
from repro.core.pipeline import SUBLANES, plan_row_pipeline

#: resident pipeline stages beyond this hide no additional DMA latency
OCCUPANCY_CAP = 8

#: DMA buffer depths the candidate grids explore
N_BUFFER_CHOICES = (2, 3, 4)

#: how far beyond a kernel's static latency cap the tuner may explore —
#: the cap is a hand-derived tail-latency guard the structural model does
#: not capture, so the corridor is bounded rather than unbounded
CAP_CORRIDOR = 4

DEFAULT_TABLE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                  "tuning_table.json")


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (bucket edge for shape binning)."""
    if x <= 1:
        return 1
    return 1 << (x - 1).bit_length()


# ---------------------------------------------------------------------------
# Shape buckets: tuning generalizes across shapes within a pow2 bucket
# ---------------------------------------------------------------------------


def rowwise_bucket(total_rows: int, row_bytes: int) -> str:
    return f"rows{next_pow2(total_rows)}:rb{next_pow2(row_bytes)}"


def gemm_bucket(m: int, n: int, k: int) -> str:
    return f"m{next_pow2(m)}:n{next_pow2(n)}:k{next_pow2(k)}"


def attention_bucket(sq: int, skv: int, d: int) -> str:
    return f"sq{next_pow2(sq)}:skv{next_pow2(skv)}:d{next_pow2(d)}"


def swiglu_bucket(rows: int, d: int, f: int) -> str:
    """rmsnorm_swiglu: row count, feature width, per-projection width."""
    return f"rows{next_pow2(rows)}:d{next_pow2(d)}:f{next_pow2(f)}"


def attention_matmul_bucket(sq: int, skv: int, d: int, n: int) -> str:
    """flash_attention_matmul: the flash shape plus the wo output width."""
    return (f"sq{next_pow2(sq)}:skv{next_pow2(skv)}:d{next_pow2(d)}"
            f":n{next_pow2(n)}")


def ssd_bucket(seq: int, p: int, n: int) -> str:
    """ssd_scan: sequence length plus the head/state widths that size one
    chunk step's working set (batch and head count only scale the grid)."""
    return f"seq{next_pow2(seq)}:p{next_pow2(p)}:n{next_pow2(n)}"


def ssd_decode_bucket(b: int, p: int, n: int) -> str:
    """ssd_decode: serve-batch width plus the head/state widths that size
    one slot's resident [N,P] state (head count only scales the grid)."""
    return f"b{next_pow2(b)}:p{next_pow2(p)}:n{next_pow2(n)}"


def parse_bucket(bucket: str) -> Dict[str, int]:
    """Inverse of the bucket formatters: field name -> representative
    (pow2 upper-edge) value.  The representative shape is what
    :func:`check_table` validates entries against."""
    out: Dict[str, int] = {}
    for part in bucket.split(":"):
        name = part.rstrip("0123456789")
        if not name or name == part:
            raise ValueError(f"malformed bucket field {part!r} in {bucket!r}")
        out[name] = int(part[len(name):])
    return out


# ---------------------------------------------------------------------------
# Candidate grids (legality = the Eq. 1 occupancy algebra)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RowwiseCandidate:
    """One legal staging point for a rowwise (1-D grid) kernel."""

    block_rows: int
    n_buffers: int
    grid_steps: int
    occupancy: int

    def params(self) -> Dict[str, int]:
        return {"block_rows": self.block_rows, "n_buffers": self.n_buffers}


def _rank_key(c: RowwiseCandidate) -> Tuple:
    """Structural cost order: fewest DMA issues, then enough resident
    stages (capped), deeper buffering and larger blocks breaking ties."""
    return (c.grid_steps, -min(c.occupancy, OCCUPANCY_CAP), -c.n_buffers,
            -c.block_rows)


def rowwise_candidates(total_rows: int, row_bytes: int,
                       dialect: Dialect = TARGET, *,
                       max_block_rows: Optional[int] = None,
                       pow2_blocks: bool = False,
                       min_occupancy: int = 2,
                       n_buffer_choices: Sequence[int] = N_BUFFER_CHOICES
                       ) -> List[RowwiseCandidate]:
    """Every legal ``(block_rows, n_buffers)`` point, structurally ranked.

    Block rows walk the power-of-two ladder from ``SUBLANES`` up to the
    rounded problem size, allowed up to ``CAP_CORRIDOR``× beyond the
    kernel's static ``max_block_rows`` cap (the cap is the *untuned*
    heuristic's guard; a validated table entry may supersede it within the
    corridor).  Legality is ``buffer_occupancy >= min_occupancy`` — the
    same Eq. 1 algebra the heuristic planner uses.
    """
    if total_rows <= 0 or row_bytes <= 0:
        raise ValueError("total_rows and row_bytes must be positive")
    rounded_total = -(-total_rows // SUBLANES) * SUBLANES
    cap = rounded_total
    if max_block_rows is not None:
        cap = min(cap, max_block_rows * CAP_CORRIDOR)
    blocks = []
    b = SUBLANES
    while b <= cap:
        blocks.append(b)
        b *= 2
    if not pow2_blocks and blocks and cap != blocks[-1]:
        # the non-pow2 roof block (largest SUBLANES multiple under the cap)
        roof = (cap // SUBLANES) * SUBLANES
        if roof > blocks[-1]:
            blocks.append(roof)
    out = []
    for br in blocks:
        steps = -(-rounded_total // br)
        for nb in n_buffer_choices:
            occ = dialect.buffer_occupancy(br * row_bytes, nb)
            if occ >= min_occupancy:
                out.append(RowwiseCandidate(br, nb, steps, occ))
    if not out:
        # tiny scratchpad budgets: the floor plan is the only choice — the
        # planner documents that the invariant clamps at one SUBLANES block
        out.append(RowwiseCandidate(
            SUBLANES, 2, -(-rounded_total // SUBLANES),
            dialect.buffer_occupancy(SUBLANES * row_bytes, 2)))
    return sorted(out, key=_rank_key)


def gemm_candidates(m: int, n: int, k: int, dialect: Dialect = TARGET,
                    dtype=jnp.float32) -> List[Dict]:
    """Legal ``(bm, bn, bk)`` tiles ranked by the tiled-GEMM traffic model.

    Working set of one step = A tile + B tile + f32 accumulator; legality
    keeps a double-buffered occupancy of at least 2 under Eq. 1.  Rank is
    the modeled HBM traffic (A re-read ``ceil(n/bn)`` times, B re-read
    ``ceil(m/bm)`` times), ties broken toward matrix-tile alignment and
    deeper k-tiles (pipeline depth).
    """
    itemsize = jnp.dtype(dtype).itemsize
    tile = dialect.matrix_unit.tile[0] if dialect.matrix_unit else 128
    edges = (128, 256, 512, 1024)
    out = []
    for bm in edges:
        for bn in edges:
            for bk in (128, 256, 512):
                working = (bm * bk + bk * bn) * itemsize + bm * bn * 4
                if dialect.buffer_occupancy(working, 2) < 2:
                    continue
                hbm = (m * k * itemsize * -(-n // bn)
                       + k * n * itemsize * -(-m // bm)
                       + m * n * 4)
                aligned = (bm % tile == 0 and bn % tile == 0
                           and bk % tile == 0)
                out.append((hbm, 0 if aligned else 1, -bk,
                            {"block": [bm, bn, bk]}))
    out.sort(key=lambda t: t[:3])
    if not out:
        # tiny scratchpad budgets (uisa-universal10's 48 KB): the minimal
        # MXU-granule tile is the floor plan — the Eq. 1 invariant clamps
        # there rather than leaving the op untunable on the dialect
        return [{"block": [128, 128, 128]}]
    return [params for *_rank, params in out]


def attention_candidates(sq: int, skv: int, d: int,
                         dialect: Dialect = TARGET) -> List[Dict]:
    """Legal ``(block_q, block_kv)`` pairs for the flash kernel.

    Working set of one step = q block + k/v blocks + f32 accumulator +
    the (bq, bkv) score tile; rank prefers fewer grid steps (larger
    blocks), kv depth breaking ties (longer sequential arbitrary axis per
    revisit)."""
    out = []
    for bq in (128, 256, 512):
        for bkv in (128, 256, 512):
            working = (bq * d + 2 * bkv * d + bq * d) * 4 + bq * bkv * 4
            if dialect.buffer_occupancy(working, 2) < 2:
                continue
            steps = -(-sq // bq) * -(-skv // bkv)
            out.append((steps, -bkv, -bq,
                        {"block_q": bq, "block_kv": bkv}))
    out.sort(key=lambda t: t[:3])
    if not out:
        return [{"block_q": 128, "block_kv": 128}]     # Eq. 1 floor plan
    return [params for *_rank, params in out]


def swiglu_candidates(rows: int, d: int, f: int, dialect: Dialect = TARGET,
                      dtype=jnp.float32) -> List[Dict]:
    """Legal ``(bm, bn)`` tiles for the fused norm→swiglu lowering.

    One step's working set: the raw x block (full feature row resident —
    the moment needs it), the wi and wg tiles for the same output column
    block, and the hi/hg/out f32 tiles.  Rank is the modeled HBM traffic
    (x re-read per output-column block, both weight halves re-read per
    row block), larger tiles breaking ties."""
    itemsize = jnp.dtype(dtype).itemsize
    out = []
    for bm in (128, 256, 512, 1024):
        for bn in (128, 256, 512, 1024):
            working = (bm * d + 2 * d * bn) * itemsize + 3 * bm * bn * 4
            if dialect.buffer_occupancy(working, 2) < 2:
                continue
            hbm = (rows * d * itemsize * -(-f // bn)
                   + 2 * d * f * itemsize * -(-rows // bm)
                   + rows * f * itemsize)
            out.append((hbm, -bn, -bm, {"block": [bm, bn]}))
    out.sort(key=lambda t: t[:3])
    if not out:
        return [{"block": [128, 128]}]                 # Eq. 1 floor plan
    return [params for *_rank, params in out]


def attention_matmul_candidates(sq: int, skv: int, d: int, n: int,
                                dialect: Dialect = TARGET) -> List[Dict]:
    """Legal ``(block_q, block_kv)`` pairs for the fused flash→wo lowering.

    The flash working set plus the epilogue's residents: the head's wo
    slice (d × n) and the shared output block (block_q × n) the heads
    accumulate into.  Rank mirrors :func:`attention_candidates`."""
    out = []
    for bq in (128, 256, 512):
        for bkv in (128, 256, 512):
            working = ((bq * d + 2 * bkv * d + bq * d) * 4 + bq * bkv * 4
                       + (d * n + bq * n) * 4)
            if dialect.buffer_occupancy(working, 2) < 2:
                continue
            steps = -(-sq // bq) * -(-skv // bkv)
            out.append((steps, -bkv, -bq,
                        {"block_q": bq, "block_kv": bkv}))
    out.sort(key=lambda t: t[:3])
    if not out:
        return [{"block_q": 128, "block_kv": 128}]     # Eq. 1 floor plan
    return [params for *_rank, params in out]


def ssd_candidates(seq: int, p: int, n: int, dialect: Dialect = TARGET,
                   dtype=jnp.float32) -> List[Dict]:
    """Legal chunk lengths for the fused SSD scan.

    One (batch, head, chunk) step's working set: the x block (Q×P), the
    B/C blocks (2·Q×N), the dt row, the carried [N,P] f32 state, the
    Q×Q score tile, and the y tile.  Rank prefers fewer sequential chunk
    steps (larger chunks), i.e. fewer state-carry iterations, with the
    quadratic Q² tile as the occupancy limiter."""
    itemsize = jnp.dtype(dtype).itemsize
    out = []
    for c in (64, 128, 256):
        working = ((c * p + 2 * c * n + c) * itemsize
                   + (n * p + c * c + c * p) * 4)
        if dialect.buffer_occupancy(working, 2) < 2:
            continue
        steps = -(-seq // c)
        out.append((steps, -c, {"chunk": c}))
    out.sort(key=lambda t: t[:2])
    if not out:
        return [{"chunk": 64}]                         # Eq. 1 floor plan
    return [params for *_rank, params in out]


def ssd_decode_candidates(b: int, p: int, n: int, dialect: Dialect = TARGET,
                          dtype=jnp.float32) -> List[Dict]:
    """Legal batch tiles for the fused SSD decode recurrence.

    One (batch-tile, head) program's working set: ``block_b`` slots' worth
    of incoming state, updated state, x/y rows, B/C rows and dt scalars,
    plus one [N,P] f32 tree-scratch slab.  Rank prefers fewer grid steps
    along the batch axis (larger tiles), i.e. fewer program launches per
    tick, with the doubled state residency as the occupancy limiter."""
    itemsize = jnp.dtype(dtype).itemsize
    del itemsize  # state/intermediates are f32 regardless of storage dtype
    out = []
    for bb in (1, 2, 4, 8):
        working = bb * (2 * n * p + 2 * p + 2 * n + 2) * 4 + n * p * 4
        if dialect.buffer_occupancy(working, 2) < 2:
            continue
        steps = -(-b // bb)
        out.append((steps, -bb, {"block_b": bb}))
    out.sort(key=lambda t: t[:2])
    if not out:
        return [{"block_b": 1}]                        # Eq. 1 floor plan
    return [params for *_rank, params in out]


# ---------------------------------------------------------------------------
# Per-op tuning spaces: kernels register how their parameters are derived,
# so table validation and the autotune CLI share one source of truth.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpace:
    """How one op's tuning candidates are enumerated from a bucket."""

    kind: str                                 # rowwise | gemm | attention
    max_block_rows: Optional[int] = None      # rowwise: static latency cap
    pow2_blocks: bool = False                 # rowwise: tree-reduce granule
    min_occupancy: int = 2


OP_SPACES: Dict[str, OpSpace] = {}


def register_op_space(op: str, kind: str, **kw) -> OpSpace:
    """Kernels call this at import so the tuner knows their constraints."""
    space = OpSpace(kind=kind, **kw)
    OP_SPACES[op] = space
    return space


def candidates_for(op: str, bucket: str,
                   dialect: Dialect = TARGET) -> List[Dict]:
    """The legal candidate params for ``op`` at a bucket's representative
    shape — the grid :func:`check_table` validates entries against."""
    space = OP_SPACES[op]
    rep = parse_bucket(bucket)
    if space.kind == "rowwise":
        cands = rowwise_candidates(
            rep["rows"], rep["rb"], dialect,
            max_block_rows=space.max_block_rows,
            pow2_blocks=space.pow2_blocks,
            min_occupancy=space.min_occupancy)
        return [c.params() for c in cands]
    if space.kind == "gemm":
        return gemm_candidates(rep["m"], rep["n"], rep["k"], dialect)
    if space.kind == "attention":
        return attention_candidates(rep["sq"], rep["skv"], rep["d"], dialect)
    if space.kind == "swiglu":
        return swiglu_candidates(rep["rows"], rep["d"], rep["f"], dialect)
    if space.kind == "attention_matmul":
        return attention_matmul_candidates(rep["sq"], rep["skv"], rep["d"],
                                           rep["n"], dialect)
    if space.kind == "ssd":
        return ssd_candidates(rep["seq"], rep["p"], rep["n"], dialect)
    if space.kind == "ssd_decode":
        return ssd_decode_candidates(rep["b"], rep["p"], rep["n"], dialect)
    raise ValueError(f"unknown tuning space kind {space.kind!r}")


# ---------------------------------------------------------------------------
# The persisted table
# ---------------------------------------------------------------------------


class TuningTable:
    """Per-``(op, mode, dialect, shape-bucket)`` winning parameters."""

    def __init__(self, entries: Optional[Dict[str, Dict]] = None,
                 path: Optional[str] = None):
        self.entries = dict(entries or {})
        self.path = path

    @staticmethod
    def key(op: str, mode: str, dialect: str, bucket: str) -> str:
        return f"{op}|{mode}|{dialect}|{bucket}"

    @classmethod
    def load(cls, path: str = DEFAULT_TABLE_PATH) -> "TuningTable":
        if not os.path.exists(path):
            return cls({}, path)
        with open(path) as f:
            data = json.load(f)
        return cls(data.get("entries", {}), path)

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path or DEFAULT_TABLE_PATH
        data = {"version": 1,
                "entries": {k: self.entries[k]
                            for k in sorted(self.entries)}}
        with open(path, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    def lookup(self, op: str, mode: str, dialect: str,
               bucket: str) -> Optional[Dict]:
        return self.entries.get(self.key(op, mode, dialect, bucket))

    def record(self, op: str, mode: str, dialect: str, bucket: str,
               params: Mapping, source: str = "structural") -> None:
        entry = dict(params)
        entry["source"] = source
        self.entries[self.key(op, mode, dialect, bucket)] = entry


#: the committed table every kernel consults (loaded once at import)
TUNING_TABLE = TuningTable.load()


# ---------------------------------------------------------------------------
# Kernel-facing consultation API (missing/illegal entries degrade silently)
# ---------------------------------------------------------------------------


def active_dialect(dialect=None) -> Dialect:
    """The dialect whose table slice a lookup should consult.

    Explicit (a :class:`Dialect` or its name — the kernels thread their
    static ``plan_dialect`` string here) wins; otherwise the ambient
    :func:`use_policy` context's dialect (how ``auto`` policies on a
    foreign dialect run *its* tuned plans instead of the target's
    heuristics), else the framework TARGET.  The explicit form is the
    load-bearing one since ISSUE 5: the kernel wrappers carry the dialect
    as a *static jit argument*, so a process mixing dialects at identical
    shapes retraces per dialect instead of reusing the first-traced
    staging plan — the ambient read survives only as the compatibility
    fallback for direct kernel-module calls."""
    if dialect is not None:
        return get_dialect(dialect) if isinstance(dialect, str) else dialect
    from repro.core.registry import current_policy
    policy = current_policy()
    return policy.resolved_dialect() if policy is not None else TARGET


def tuned_entry(op: str, mode: str, bucket: str,
                dialect=None,
                table: Optional[TuningTable] = None) -> Optional[Dict]:
    """The raw winning entry for one (op, mode, dialect, bucket), if any."""
    table = TUNING_TABLE if table is None else table
    return table.lookup(op, mode, active_dialect(dialect).name, bucket)


def tuned_plan(op: str, total_rows: int, row_bytes: int, *, mode: str,
               dialect=None,
               table: Optional[TuningTable] = None, **plan_kw):
    """``plan_row_pipeline`` with the table's winner for this bucket.

    The entry's ``block_rows`` / ``n_buffers`` ride in through the plan's
    ``tuned=`` override, which still enforces the occupancy invariant and
    the problem-size clamps — a bad entry degrades to the heuristic."""
    dialect = active_dialect(dialect)
    entry = tuned_entry(op, mode, rowwise_bucket(total_rows, row_bytes),
                        dialect, table)
    return plan_row_pipeline(total_rows, row_bytes, mode=mode,
                             dialect=dialect, tuned=entry, **plan_kw)


def tuned_block(op: str, mode: str, m: int, n: int, k: int,
                dialect=None,
                table: Optional[TuningTable] = None
                ) -> Optional[Tuple[int, int, int]]:
    """The table's ``(bm, bn, bk)`` for a GEMM-shaped op, if recorded."""
    entry = tuned_entry(op, mode, gemm_bucket(m, n, k), dialect, table)
    if entry and "block" in entry:
        bm, bn, bk = entry["block"]
        return int(bm), int(bn), int(bk)
    return None


def tuned_attention_blocks(mode: str, sq: int, skv: int, d: int,
                           dialect=None,
                           table: Optional[TuningTable] = None
                           ) -> Optional[Tuple[int, int]]:
    """The table's ``(block_q, block_kv)`` for the flash kernel, if any."""
    entry = tuned_entry("flash_attention", mode, attention_bucket(sq, skv, d),
                        dialect, table)
    if entry and "block_q" in entry and "block_kv" in entry:
        return int(entry["block_q"]), int(entry["block_kv"])
    return None


# ---------------------------------------------------------------------------
# Autotuning (structural by default; live measurement optional)
# ---------------------------------------------------------------------------


def measure_candidates(build_fn: Callable[[Mapping], Callable],
                       candidates: Sequence[Mapping], *,
                       warmup: int = 1, iters: int = 3,
                       top_k: int = 4) -> Tuple[Dict, List[Tuple[float, Dict]]]:
    """Re-rank the structural top-``k`` by live wall clock.

    ``build_fn(params)`` returns a zero-arg callable that runs the kernel
    with those staging parameters on the live backend (the caller owns
    cache invalidation — see ``scripts/autotune.py``).  Returns the winner
    and the full ``(median_s, params)`` ladder.
    """
    import time

    import jax

    timed = []
    for params in list(candidates)[:top_k]:
        fn = build_fn(params)
        for _ in range(warmup):
            jax.block_until_ready(fn())
        samples = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            samples.append(time.perf_counter() - t0)
        timed.append((float(sorted(samples)[len(samples) // 2]),
                      dict(params)))
    timed.sort(key=lambda t: t[0])
    return timed[0][1], timed


def autotune_entry(table: TuningTable, op: str, mode: str, bucket: str,
                   dialect: Dialect = TARGET,
                   build_fn: Optional[Callable] = None, **measure_kw
                   ) -> Optional[Dict]:
    """Pick and record the winner for one ``(op, mode, dialect, bucket)``.

    Structural ranking decides unless ``build_fn`` is given, in which case
    the structural top-k is re-ranked by measurement."""
    cands = candidates_for(op, bucket, dialect)
    if not cands:
        return None
    if build_fn is not None:
        winner, _ = measure_candidates(build_fn, cands, **measure_kw)
        source = "measured"
    else:
        winner, source = cands[0], "structural"
    table.record(op, mode, dialect.name, bucket, winner, source)
    return winner


# ---------------------------------------------------------------------------
# Canonical shapes: the table rows the autotune CLI regenerates and the CI
# sync gate re-derives — one source of truth shared by both
# (scripts/autotune.py imports these; they match the benchmark matrix's
# full + quick sizings so the committed winners cover exactly the rows
# BENCH_kernels.json reports).
# ---------------------------------------------------------------------------


CANONICAL_SHAPES: Dict[str, List[Dict[str, int]]] = {
    "reduction": [dict(n=1 << 21), dict(n=1 << 15)],
    "rmsnorm": [dict(rows=1024, d=1024), dict(rows=64, d=256)],
    "histogram": [dict(n=1 << 18, num_bins=256),
                  dict(n=1 << 14, num_bins=256)],
    # the third row of the fused/gemm spaces is decode-shaped (ISSUE 5):
    # rows = the decode batch (decode_32k's 128 slots), sq = 1 against a
    # long cache — the shapes the now decode-legal fusions run per tick
    "add_rmsnorm": [dict(rows=1024, d=1024), dict(rows=64, d=256),
                    dict(rows=128, d=1024)],
    "gemm": [dict(m=1024, n=1024, k=1024), dict(m=256, n=256, k=256),
             dict(m=128, n=1024, k=1024)],
    "flash_attention": [dict(sq=1024, skv=1024, d=64),
                        dict(sq=256, skv=256, d=64)],
    "rmsnorm_swiglu": [dict(rows=1024, d=1024, f=1024),
                       dict(rows=64, d=256, f=256),
                       dict(rows=128, d=1024, f=1024)],
    "flash_attention_matmul": [dict(sq=1024, skv=1024, d=64, n=256),
                               dict(sq=256, skv=256, d=64, n=128),
                               dict(sq=1, skv=1024, d=64, n=256),
                               # paged decode frontiers (ISSUE 6): skv is
                               # page-granular capacity — small (few live
                               # pages) and large (deep block tables)
                               dict(sq=1, skv=512, d=64, n=256),
                               dict(sq=1, skv=4096, d=64, n=256)],
    # quantized twins (ISSUE 7): decode-focused — the int8 weight stream
    # matters most at small rows/sq, where weight traffic dominates; the
    # q8 rows tune their own staging (the VMEM working set differs: int8
    # weight tiles + f32 scale rows)
    "rmsnorm_swiglu_q8": [dict(rows=128, d=1024, f=1024),
                          dict(rows=64, d=256, f=256)],
    "flash_attention_matmul_q8": [dict(sq=1, skv=1024, d=64, n=256),
                                  dict(sq=1, skv=512, d=64, n=256)],
    # the fused chunked SSD scan (ISSUE 8): two seq rows landing in two
    # distinct buckets — the long-prefill shape (mamba2 defaults: P=64,
    # N=128) and a short-sequence shape whose smaller state width admits
    # a different chunk winner; matches the bench matrix's ssd rows
    "ssd_scan": [dict(seq=1024, p=64, n=128), dict(seq=256, p=64, n=64)],
    # the batched decode recurrence (ISSUE 9): b is the serve-batch width,
    # p/n the mamba2 head/state widths; the two rows match the bench
    # matrix's full and quick ssd_decode sizings
    "ssd_decode": [dict(b=16, p=64, n=128), dict(b=8, p=32, n=32)],
}


def bucket_for(op: str, shape: Dict[str, int]) -> str:
    """Map an op's natural shape to its tuning-space bucket."""
    kind = OP_SPACES[op].kind
    lanes = TARGET.W
    if kind == "rowwise":
        if op in ("reduction", "histogram"):
            rows = -(-shape["n"] // lanes)
            return rowwise_bucket(rows, lanes * 4)
        if op == "rmsnorm":
            return rowwise_bucket(shape["rows"], shape["d"] * 4)
        if op == "add_rmsnorm":
            return rowwise_bucket(shape["rows"], 2 * shape["d"] * 4)
        raise ValueError(f"no bucket rule for rowwise op {op!r}")
    if kind == "gemm":
        return gemm_bucket(shape["m"], shape["n"], shape["k"])
    if kind == "attention":
        return attention_bucket(shape["sq"], shape["skv"], shape["d"])
    if kind == "swiglu":
        return swiglu_bucket(shape["rows"], shape["d"], shape["f"])
    if kind == "attention_matmul":
        return attention_matmul_bucket(shape["sq"], shape["skv"],
                                       shape["d"], shape["n"])
    if kind == "ssd":
        return ssd_bucket(shape["seq"], shape["p"], shape["n"])
    if kind == "ssd_decode":
        return ssd_decode_bucket(shape["b"], shape["p"], shape["n"])
    raise ValueError(kind)


def expected_structural_entries(registry,
                                dialect: Dialect) -> Dict[str, Dict]:
    """The structural winners the autotune CLI would write for ``dialect``.

    Enumerates every registered tunable op × its dialect-legal non-library
    modes × canonical shapes — the slice :func:`check_table` holds the
    committed table to, so a stale entry on *any* dialect present in the
    table (not just the target) fails CI."""
    expected: Dict[str, Dict] = {}
    for op, shapes in sorted(CANONICAL_SHAPES.items()):
        if op not in registry.ops() or op not in OP_SPACES:
            continue
        for mode in registry.modes(op):
            if mode == "library" or not registry.legal(op, mode, dialect):
                continue          # XLA's tiling / illegal variants: untuned
            for shape in shapes:
                bucket = bucket_for(op, shape)
                cands = candidates_for(op, bucket, dialect)
                if not cands:
                    continue
                key = TuningTable.key(op, mode, dialect.name, bucket)
                expected[key] = cands[0]
    return expected


# ---------------------------------------------------------------------------
# CI sync check: committed entries must live inside the candidate grid
# ---------------------------------------------------------------------------


def check_table(registry, table: Optional[TuningTable] = None) -> List[str]:
    """Validate every table entry against the live registry + candidate
    grids.  Returns failure strings (empty = in sync).  Stale ops/modes/
    dialects and params outside the legal grid all fail, and every dialect
    *present* in the table is held to the full canonical structural slice
    (a stale or missing ``uisa-universal10`` entry fails exactly like a
    ``tpu-v5e`` one) — the check needs no TPU, so CI runs it on every
    push."""
    table = TUNING_TABLE if table is None else table
    failures = []
    for key, entry in table.entries.items():
        parts = key.split("|")
        if len(parts) != 4:
            failures.append(f"{key}: malformed key")
            continue
        op, mode, dialect_name, bucket = parts
        if op not in registry.ops():
            failures.append(f"{key}: op {op!r} not registered")
            continue
        if mode not in registry.modes(op):
            failures.append(f"{key}: mode {mode!r} not registered for {op}")
            continue
        if dialect_name not in DIALECTS:
            failures.append(f"{key}: unknown dialect {dialect_name!r}")
            continue
        if op not in OP_SPACES:
            failures.append(f"{key}: op has no registered tuning space")
            continue
        try:
            cands = candidates_for(op, bucket, get_dialect(dialect_name))
        except (KeyError, ValueError) as e:
            failures.append(f"{key}: bad bucket ({e})")
            continue
        params = {k: v for k, v in entry.items() if k != "source"}
        if params not in cands:
            failures.append(
                f"{key}: params {params} outside the legal candidate grid "
                f"({len(cands)} candidates)")
    # per-dialect slice sync: each dialect present in the table carries the
    # full canonical structural slice, and structural entries must be the
    # *current* winners (measured entries are exempt from winner equality —
    # they intentionally override the structural ranking).
    present = sorted({parts[2] for parts in
                      (key.split("|") for key in table.entries)
                      if len(parts) == 4 and parts[2] in DIALECTS})
    for dialect_name in present:
        expected = expected_structural_entries(registry,
                                               get_dialect(dialect_name))
        for key, winner in expected.items():
            entry = table.entries.get(key)
            if entry is None:
                failures.append(
                    f"{key}: missing from the {dialect_name} slice "
                    f"(stale table — rerun scripts/autotune.py)")
                continue
            if entry.get("source") != "structural":
                continue
            params = {k: v for k, v in entry.items() if k != "source"}
            if params != winner:
                failures.append(
                    f"{key}: stale structural entry {params} != current "
                    f"winner {winner} (rerun scripts/autotune.py)")
    return failures
