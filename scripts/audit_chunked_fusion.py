"""Fusion audit of the non-Pallas ``chunked_attention`` branch (ROADMAP).

``attn_seq`` keeps a pure-jnp chunked-attention path for dry-runs and
SPMD compilation (models/attention.py); unlike the Pallas flash path its
epilogue projection is a separate einsum, and the open ROADMAP question
was how much of that XLA already fuses on its own.  This script lowers
the branch, compiles it, and uses the trip-count-aware HLO parser
(roofline/hlo_parser.py) to count where every ``dot`` landed:

- **dots inside fusion computations** — contraction already fused with
  its neighbors (prologue/epilogue elementwise work rides along);
- **surface dots** — contractions XLA left standalone: each one's
  operands/results are fusion-boundary HBM traffic, the quantity the
  Pallas fused epilogue eliminates by construction.

  PYTHONPATH=src python scripts/audit_chunked_fusion.py
  PYTHONPATH=src python scripts/audit_chunked_fusion.py --seq 512 --json

The result is recorded in EXPERIMENTS.md §Chunked-attention fusion audit.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models import transformer  # noqa: E402
from repro.models.config import ModelConfig, ParallelConfig  # noqa: E402
from repro.roofline.hlo_parser import HloModule  # noqa: E402


def audit_hlo_fusions(text: str) -> dict:
    """Count dot placement across a compiled module's computations.

    A ``dot`` inside a computation reached via ``calls=`` from a
    ``fusion`` op is GSPMD/XLA-fused; a ``dot`` appearing directly in any
    non-fusion computation is a surface contraction whose boundary
    tensors hit HBM."""
    mod = HloModule(text, total_devices=1)
    fusion_comps = set()
    n_fusion_ops = 0
    for comp in mod.comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                n_fusion_ops += 1
                for callee, _mult in mod._callees(op):
                    fusion_comps.add(callee)
    fusion_comps &= mod.comps.keys()
    dot_counts = {name: sum(1 for op in comp.ops if op.opcode == "dot")
                  for name, comp in mod.comps.items()}
    dots_fused = sum(dot_counts[name] for name in fusion_comps)
    dots_surface = sum(n for name, n in dot_counts.items()
                      if name not in fusion_comps)
    fusions_with_dot = sum(1 for name in fusion_comps if dot_counts[name])
    return {
        "fusion_ops": n_fusion_ops,
        "fusions_with_dot": fusions_with_dot,
        "dots_fused": dots_fused,
        "dots_surface": dots_surface,
        "dots_total": dots_fused + dots_surface,
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(name="audit", family="dense", num_layers=1,
                      d_model=args.d_model, num_heads=args.heads,
                      num_kv_heads=args.kv_heads, d_ff=2 * args.d_model,
                      vocab_size=128, dtype="float32")
    # the audited branch: use_pallas_attn=False -> chunked_attention +
    # the separate wo einsum epilogue
    par = ParallelConfig(remat="none", use_pallas_attn=False)
    params, _ = transformer.init_attn(jax.random.PRNGKey(0), cfg,
                                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, args.seq, args.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(args.seq), (1, args.seq))

    def branch(params, x):
        return transformer.attn_seq(params, x, cfg, par, positions,
                                    ctx=None)

    compiled = jax.jit(branch).lower(params, x).compile()
    text = compiled.as_text()
    report = audit_hlo_fusions(text)
    report["backend"] = jax.default_backend()
    report["seq"] = args.seq
    report["unfused_fraction"] = (
        report["dots_surface"] / report["dots_total"]
        if report["dots_total"] else 0.0)
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        print(f"[audit] backend={report['backend']} seq={args.seq}: "
              f"{report['dots_total']} dots, "
              f"{report['dots_fused']} inside "
              f"{report['fusions_with_dot']}/{report['fusion_ops']} "
              f"fusions, {report['dots_surface']} surface "
              f"({report['unfused_fraction']:.0%} unfused)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
