"""Fusion audit of the chunked jnp branches (ROADMAP / ISSUE 8).

Two auditable targets, both pure-jnp chunked scans XLA must fuse on its
own (no Pallas by construction):

- ``--target attention`` (default): ``attn_seq``'s chunked-attention
  branch (models/attention.py) with its separate wo-einsum epilogue;
- ``--target ssd``: the chunked SSD scan (kernels/ssd.py::
  ssd_scan_reference, the ``ssd_scan`` registry's library row) — six
  contractions per chunk step around a carried-state recurrence.

The script lowers the branch, compiles it, and uses the trip-count-aware
HLO parser (roofline/hlo_parser.py) to count where every ``dot`` landed:

- **dots inside fusion computations** — contraction already fused with
  its neighbors (prologue/epilogue elementwise work rides along);
- **surface dots** — contractions XLA left standalone: each one's
  operands/results are fusion-boundary HBM traffic, the quantity the
  Pallas fused lowerings eliminate by construction.

``--fused`` compiles the *fused* Pallas path for the same target and
shape instead (interpret mode off-TPU), closing the before/after loop:
the chunk-scan contractions move inside the one kernel's computation and
off the surface.

  PYTHONPATH=src python scripts/audit_chunked_fusion.py
  PYTHONPATH=src python scripts/audit_chunked_fusion.py --seq 512 --json
  PYTHONPATH=src python scripts/audit_chunked_fusion.py --target ssd
  PYTHONPATH=src python scripts/audit_chunked_fusion.py --target ssd --fused

Results are recorded in EXPERIMENTS.md §Chunked-attention fusion audit
and §Chunked-scan fusion (ssd).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.models import transformer  # noqa: E402
from repro.models.config import ModelConfig, ParallelConfig  # noqa: E402
from repro.roofline.hlo_parser import HloModule  # noqa: E402


def audit_hlo_fusions(text: str) -> dict:
    """Count dot placement across a compiled module's computations.

    A ``dot`` inside a computation reached via ``calls=`` from a
    ``fusion`` op is GSPMD/XLA-fused; a ``dot`` appearing directly in any
    non-fusion computation is a surface contraction whose boundary
    tensors hit HBM."""
    mod = HloModule(text, total_devices=1)
    fusion_comps = set()
    n_fusion_ops = 0
    for comp in mod.comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                n_fusion_ops += 1
                for callee, _mult in mod._callees(op):
                    fusion_comps.add(callee)
    fusion_comps &= mod.comps.keys()
    dot_counts = {name: sum(1 for op in comp.ops if op.opcode == "dot")
                  for name, comp in mod.comps.items()}
    dots_fused = sum(dot_counts[name] for name in fusion_comps)
    dots_surface = sum(n for name, n in dot_counts.items()
                      if name not in fusion_comps)
    fusions_with_dot = sum(1 for name in fusion_comps if dot_counts[name])
    return {
        "fusion_ops": n_fusion_ops,
        "fusions_with_dot": fusions_with_dot,
        "dots_fused": dots_fused,
        "dots_surface": dots_surface,
        "dots_total": dots_fused + dots_surface,
    }


def _attention_branch(args):
    """The chunked-attention jnp branch (PR 5's original target)."""
    cfg = ModelConfig(name="audit", family="dense", num_layers=1,
                      d_model=args.d_model, num_heads=args.heads,
                      num_kv_heads=args.kv_heads, d_ff=2 * args.d_model,
                      vocab_size=128, dtype="float32")
    # the audited branch: use_pallas_attn=False -> chunked_attention +
    # the separate wo einsum epilogue (--fused flips it back on)
    par = ParallelConfig(remat="none", use_pallas_attn=not args.fused)
    params, _ = transformer.init_attn(jax.random.PRNGKey(0), cfg,
                                      jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1, args.seq, args.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(args.seq), (1, args.seq))

    def branch(params, x):
        return transformer.attn_seq(params, x, cfg, par, positions,
                                    ctx=None)

    return branch, (params, x)


def _ssd_branch(args):
    """The chunked SSD scan: the jnp library row (six surface-candidate
    contractions per chunk step), or with --fused the one-grid Pallas
    kernel at the same shape."""
    from repro.kernels import ops as kernel_ops
    from repro.kernels import ssd as kernel_ssd
    h, g, p, n, chunk = 4, 1, args.d_model // 2, args.d_model, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (1, args.seq, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, args.seq, h),
                                           jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
    b_mat = jax.random.normal(ks[3], (1, args.seq, g, n), jnp.float32) * 0.3
    c_mat = jax.random.normal(ks[4], (1, args.seq, g, n), jnp.float32) * 0.3

    if args.fused:
        def branch(x, dt):
            return kernel_ops.fused_ssd_scan(x, dt, a, b_mat, c_mat,
                                             chunk=chunk, mode="native")
    else:
        def branch(x, dt):
            return kernel_ssd.ssd_scan_reference(x, dt, a, b_mat, c_mat,
                                                 chunk)

    return branch, (x, dt)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--target", choices=("attention", "ssd"),
                    default="attention")
    ap.add_argument("--fused", action="store_true",
                    help="compile the fused Pallas path instead of the "
                    "jnp branch (the after-side of the audit delta)")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    build = _ssd_branch if args.target == "ssd" else _attention_branch
    branch, operands = build(args)
    compiled = jax.jit(branch).lower(*operands).compile()
    text = compiled.as_text()
    report = audit_hlo_fusions(text)
    report["target"] = args.target
    report["fused"] = args.fused
    report["backend"] = jax.default_backend()
    report["seq"] = args.seq
    report["unfused_fraction"] = (
        report["dots_surface"] / report["dots_total"]
        if report["dots_total"] else 0.0)
    if args.json:
        print(json.dumps(report, indent=1))
    else:
        print(f"[audit] target={args.target} fused={args.fused} "
              f"backend={report['backend']} seq={args.seq}: "
              f"{report['dots_total']} dots, "
              f"{report['dots_fused']} inside "
              f"{report['fusions_with_dot']}/{report['fusion_ops']} "
              f"fusions, {report['dots_surface']} surface "
              f"({report['unfused_fraction']:.0%} unfused)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
