"""CI contract-drift guard: validate the lowering registry on every dialect.

Imports the registry (which installs every kernel variant,
contract-checked) and asserts, without needing a TPU:

1. every registered contract names its own op and mode (no drift) and
   validates on the dialect it targets;
2. for every (op, mode, dialect) the registry's ``legal`` verdict agrees
   with ``validate_contract`` — native lowerings pinned to their target;
3. an ``ExecutionPolicy("auto")`` resolves a legal lowering for every op
   on every registered dialect, including the no-shuffle universal-10
   profile (library escape only where no portable variant is legal).

  PYTHONPATH=src python scripts/validate_contracts.py
"""
from __future__ import annotations

import os
import sys
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (DIALECTS, ExecutionPolicy, IsaMode,  # noqa: E402
                        LoweringFallbackWarning, REGISTRY, TARGET,
                        validate_contract)
from repro.core.primitives import ContractViolation  # noqa: E402
from repro.kernels.ops import PROBE_SHAPES  # noqa: E402 (installs registry)


def main() -> int:
    failures = []
    checked = 0
    missing = [op for op in REGISTRY.ops() if op not in PROBE_SHAPES]
    if missing:
        failures.append(
            f"ops with no PROBE_SHAPES row (add one in "
            f"repro/kernels/ops.py): {missing}")
    for op in REGISTRY.ops():
        for mode in REGISTRY.modes(op):
            low = REGISTRY.variant(op, mode)
            c = low.contract
            if c.kernel != op or c.mode is not IsaMode(mode):
                failures.append(f"{op}[{mode}]: contract drift "
                                f"({c.kernel}[{c.mode.value}])")
            try:
                validate_contract(
                    c, TARGET if low.target is None
                    else DIALECTS[low.target])
            except ContractViolation as e:
                failures.append(f"{op}[{mode}] invalid on its own "
                                f"target: {e}")
            for dialect in DIALECTS.values():
                checked += 1
                legal = REGISTRY.legal(op, mode, dialect)
                if low.target is not None and low.target != dialect.name:
                    if legal:
                        failures.append(
                            f"{op}[{mode}] target-pinned to {low.target} "
                            f"but reported legal on {dialect.name}")
                    continue
                try:
                    validate_contract(c, dialect)
                    expect = True
                except ContractViolation:
                    expect = False
                if legal != expect:
                    failures.append(
                        f"{op}[{mode}] on {dialect.name}: registry says "
                        f"legal={legal}, validate_contract says {expect}")
    # auto resolvability everywhere
    for dialect in DIALECTS.values():
        pol = ExecutionPolicy(mode="auto", dialect=dialect.name)
        for op in REGISTRY.ops():
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore",
                                          LoweringFallbackWarning)
                    low = REGISTRY.select(op, pol,
                                          shape=PROBE_SHAPES.get(op, {}))
            except Exception as e:            # noqa: BLE001
                failures.append(f"auto({op}, {dialect.name}) failed: {e}")
                continue
            print(f"auto {dialect.name:18s} {op:16s} -> {low.mode.value}")
    if failures:
        print(f"\nFAIL: {len(failures)} contract-drift findings")
        for f in failures:
            print("  -", f)
        return 1
    print(f"\nOK: {len(REGISTRY.ops())} ops x {len(DIALECTS)} dialects "
          f"({checked} contract/legality checks) all consistent")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
