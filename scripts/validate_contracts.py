"""CI contract-drift guard: validate the lowering registry on every dialect.

Imports the registry (which installs every kernel variant,
contract-checked) and asserts, without needing a TPU:

1. every registered contract names its own op and mode (no drift) and
   validates on the dialect it targets;
2. for every (op, mode, dialect) the registry's ``legal`` verdict agrees
   with ``validate_contract`` — native lowerings pinned to their target
   (the fused multi-op lowerings ride through the same sweep);
3. an ``ExecutionPolicy("auto")`` resolves a legal lowering for every op
   on every registered dialect, including the no-shuffle universal-10
   profile (library escape only where no portable variant is legal);
4. every fused lowering's (FUSED_OPS) modeled ``hbm_bytes`` is strictly
   below its unfused pair's sum (the round-trip saving cannot silently
   evaporate), with the ``library`` row equal to the pair by construction;
5. the committed tuning table (core/tuning_table.json) is in sync with
   the candidate grid *on every dialect present in the table*: stale
   ops/modes/dialects, params outside the legal Eq. 1 grid, a missing or
   stale ``uisa-universal10`` entry — all fail the build;
6. every registered lowering with a TP collective twin declares its
   interconnect term: at tp=4 the twin's cost carries the collective
   keys with a positive wire/hbm-equivalent charge and a chip-side hbm
   term no worse than the replicated base, and with no mesh it
   collapses exactly onto the base (ISSUE 10).

  PYTHONPATH=src python scripts/validate_contracts.py
"""
from __future__ import annotations

import os
import sys
import warnings

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.core import (DIALECTS, ExecutionPolicy, IsaMode,  # noqa: E402
                        LoweringFallbackWarning, REGISTRY, TARGET,
                        validate_contract)
from repro.core import tuning  # noqa: E402
from repro.core.primitives import ContractViolation  # noqa: E402
from repro.kernels.fused import FUSED_OPS  # noqa: E402
from repro.kernels.ops import PROBE_SHAPES  # noqa: E402 (installs registry)

def check_fused_costs() -> list:
    """Gate 4: the fused rows' round-trip saving is real and recorded —
    swept over every op kernels/fused.py registers (FUSED_OPS), so a new
    fused lowering cannot ship without the accounting keys."""
    failures = []
    for op in FUSED_OPS:
        if op not in REGISTRY.ops():
            failures.append(f"fused op {op!r} not registered")
            continue
        shape = PROBE_SHAPES[op]
        for mode in REGISTRY.modes(op):
            cost = REGISTRY.structural_cost(op, mode, **shape)
            unfused = cost.get("hbm_bytes_unfused_pair")
            saved = cost.get("hbm_bytes_saved")
            if unfused is None or saved is None:
                failures.append(f"{op}[{mode}]: cost lacks the fused "
                                f"accounting keys")
                continue
            if cost["hbm_bytes"] != unfused - saved:
                failures.append(
                    f"{op}[{mode}]: hbm_bytes {cost['hbm_bytes']} != "
                    f"unfused {unfused} - saved {saved}")
            if mode == "library":
                if saved != 0:
                    failures.append(f"{op}[library]: the unfused pair "
                                    f"cannot claim a saving ({saved})")
            elif saved <= 0:
                failures.append(f"{op}[{mode}]: no recorded round-trip "
                                f"saving")
    return failures


def check_collective_terms() -> list:
    """Gate 6 (ISSUE 10): every registered lowering with a TP collective
    variant declares its collective term.  At tp=4 the twin's cost must
    carry the collective keys (kind, group, wire bytes, hbm-equivalent),
    keep its chip-side hbm term at or below the replicated base (the
    sharded weight stream only subtracts), and preserve the fused-pair
    identity; with no mesh the twin must collapse exactly onto its base
    (zero collective term) so pinned modes never pay a phantom toll."""
    from repro.core.registry import use_mesh_axes
    failures = []
    pairs = REGISTRY.collective_variants()
    if not pairs:
        failures.append("no collective variants registered (the TP "
                        "twins in kernels/collective.py vanished)")
    for base, twin in sorted(pairs.items()):
        shape = PROBE_SHAPES.get(twin)
        if shape is None:
            failures.append(f"{twin}: no PROBE_SHAPES row")
            continue
        for mode in REGISTRY.modes(twin):
            base_cost = REGISTRY.structural_cost(base, mode, **shape)
            with use_mesh_axes({"model": 4}):
                cost = REGISTRY.structural_cost(twin, mode, **shape)
            if not cost.get("collective") \
                    or cost.get("collective_bytes", 0) <= 0 \
                    or cost.get("collective_hbm_equiv_bytes", 0) <= 0:
                failures.append(f"{twin}[{mode}]: no declared collective "
                                f"term at tp=4")
                continue
            if cost.get("collective_group") != 4 \
                    or cost.get("tp_axis") != 4:
                failures.append(f"{twin}[{mode}]: collective group/axis "
                                f"disagree with the mesh (tp=4)")
            if cost["hbm_bytes"] > base_cost["hbm_bytes"]:
                failures.append(
                    f"{twin}[{mode}]: sharded chip term "
                    f"{cost['hbm_bytes']} exceeds the replicated base "
                    f"{base_cost['hbm_bytes']}")
            unfused = cost.get("hbm_bytes_unfused_pair")
            saved = cost.get("hbm_bytes_saved")
            if unfused is not None \
                    and cost["hbm_bytes"] != unfused - saved:
                failures.append(f"{twin}[{mode}]: fused-pair identity "
                                f"broken under sharding")
            flat = REGISTRY.structural_cost(twin, mode, **shape)
            if flat.get("collective_bytes", 0) != 0 \
                    or flat["hbm_bytes"] != base_cost["hbm_bytes"]:
                failures.append(f"{twin}[{mode}]: tp=1 does not collapse "
                                f"onto the base cost")
    return failures


def main() -> int:
    failures = []
    checked = 0
    missing = [op for op in REGISTRY.ops() if op not in PROBE_SHAPES]
    if missing:
        failures.append(
            f"ops with no PROBE_SHAPES row (add one in "
            f"repro/kernels/ops.py): {missing}")
    for op in REGISTRY.ops():
        for mode in REGISTRY.modes(op):
            low = REGISTRY.variant(op, mode)
            c = low.contract
            if c.kernel != op or c.mode is not IsaMode(mode):
                failures.append(f"{op}[{mode}]: contract drift "
                                f"({c.kernel}[{c.mode.value}])")
            try:
                validate_contract(
                    c, TARGET if low.target is None
                    else DIALECTS[low.target])
            except ContractViolation as e:
                failures.append(f"{op}[{mode}] invalid on its own "
                                f"target: {e}")
            for dialect in DIALECTS.values():
                checked += 1
                legal = REGISTRY.legal(op, mode, dialect)
                if low.target is not None and low.target != dialect.name:
                    if legal:
                        failures.append(
                            f"{op}[{mode}] target-pinned to {low.target} "
                            f"but reported legal on {dialect.name}")
                    continue
                try:
                    validate_contract(c, dialect)
                    expect = True
                except ContractViolation:
                    expect = False
                if legal != expect:
                    failures.append(
                        f"{op}[{mode}] on {dialect.name}: registry says "
                        f"legal={legal}, validate_contract says {expect}")
    # auto resolvability everywhere
    for dialect in DIALECTS.values():
        pol = ExecutionPolicy(mode="auto", dialect=dialect.name)
        for op in REGISTRY.ops():
            try:
                with warnings.catch_warnings():
                    warnings.simplefilter("ignore",
                                          LoweringFallbackWarning)
                    low = REGISTRY.select(op, pol,
                                          shape=PROBE_SHAPES.get(op, {}))
            except Exception as e:            # noqa: BLE001
                failures.append(f"auto({op}, {dialect.name}) failed: {e}")
                continue
            print(f"auto {dialect.name:18s} {op:16s} -> {low.mode.value}")
    # gate 4: fused-lowering round-trip accounting
    failures.extend(check_fused_costs())
    # gate 6: TP collective variants declare their interconnect term
    coll_failures = check_collective_terms()
    if coll_failures:
        failures.extend(coll_failures)
    else:
        pairs = REGISTRY.collective_variants()
        print(f"\ncollective terms: {len(pairs)} TP twins "
              f"({', '.join(sorted(pairs.values()))}) all declared")
    # gate 5: committed tuning table in sync with the candidate grid
    table_failures = tuning.check_table(REGISTRY)
    if table_failures:
        failures.extend(f"tuning table: {f}" for f in table_failures)
    else:
        print(f"\ntuning table: {len(tuning.TUNING_TABLE.entries)} entries "
              f"all inside the legal candidate grid")
    if failures:
        print(f"\nFAIL: {len(failures)} contract-drift findings")
        for f in failures:
            print("  -", f)
        return 1
    print(f"\nOK: {len(REGISTRY.ops())} ops x {len(DIALECTS)} dialects "
          f"({checked} contract/legality checks) all consistent")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
