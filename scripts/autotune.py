"""Regenerate the committed pipeline tuning table (core/tuning.py).

Enumerates the legal ``(block, n_buffers)`` candidate grid for every
registered op × mode × canonical shape bucket (the Eq. 1 occupancy
algebra in ``repro.core.tuning``), ranks candidates by structural cost,
and writes the winners to ``src/repro/core/tuning_table.json`` — the
table every kernel consults at trace time.

  PYTHONPATH=src python scripts/autotune.py                 # structural
  PYTHONPATH=src python scripts/autotune.py --measure       # live re-rank
  PYTHONPATH=src python scripts/autotune.py --out /tmp/t.json

Structural mode is deterministic and backend-free, so CI can assert the
committed table is in sync (scripts/validate_contracts.py).  ``--measure``
re-ranks the structural top-k by median wall clock on the live backend —
on a TPU that is the real autotune; off-TPU it measures the Pallas
interpreter and is only useful for exercising the machinery.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import TARGET, tuning  # noqa: E402
from repro.core.registry import REGISTRY  # noqa: E402
from repro.kernels import ops  # noqa: E402 (installs registry + op spaces)

KEY = jax.random.PRNGKey(0)

#: canonical shapes per op — the benchmark matrix's full + quick sizings,
#: so the committed winners cover exactly the rows BENCH_kernels.json
#: reports (new shapes bucket to the nearest entry or fall back to the
#: heuristic plan).
CANONICAL_SHAPES = {
    "reduction": [dict(n=1 << 21), dict(n=1 << 15)],
    "rmsnorm": [dict(rows=1024, d=1024), dict(rows=64, d=256)],
    "histogram": [dict(n=1 << 18, num_bins=256),
                  dict(n=1 << 14, num_bins=256)],
    "add_rmsnorm": [dict(rows=1024, d=1024), dict(rows=64, d=256)],
    "gemm": [dict(m=1024, n=1024, k=1024), dict(m=256, n=256, k=256)],
    "flash_attention": [dict(sq=1024, skv=1024, d=64),
                        dict(sq=256, skv=256, d=64)],
}

LANES = TARGET.W


def bucket_for(op: str, shape: dict) -> str:
    """Map an op's natural shape to its tuning-space bucket."""
    kind = tuning.OP_SPACES[op].kind
    if kind == "rowwise":
        if op == "reduction" or op == "histogram":
            rows = -(-shape["n"] // LANES)
            return tuning.rowwise_bucket(rows, LANES * 4)
        if op == "rmsnorm":
            return tuning.rowwise_bucket(shape["rows"], shape["d"] * 4)
        if op == "add_rmsnorm":
            return tuning.rowwise_bucket(shape["rows"], 2 * shape["d"] * 4)
        raise ValueError(f"no bucket rule for rowwise op {op!r}")
    if kind == "gemm":
        return tuning.gemm_bucket(shape["m"], shape["n"], shape["k"])
    if kind == "attention":
        return tuning.attention_bucket(shape["sq"], shape["skv"],
                                       shape["d"])
    raise ValueError(kind)


def build_runner(op: str, mode: str, shape: dict):
    """A zero-arg callable running (op, mode) at ``shape`` on the live
    backend, for --measure.  Candidate params reach the kernel through
    the live table, so the caller must clear jit caches between points."""
    ks = jax.random.split(KEY, 4)
    if op == "reduction":
        x = jax.random.normal(ks[0], (shape["n"],), jnp.float32)
        return lambda: ops.reduce_sum(x, mode=mode)
    if op == "rmsnorm":
        x = jax.random.normal(ks[0], (shape["rows"], shape["d"]),
                              jnp.float32)
        w = jnp.ones((shape["d"],), jnp.float32)
        return lambda: ops.rmsnorm(x, w, mode=mode)
    if op == "histogram":
        v = jax.random.randint(ks[0], (shape["n"],), 0,
                               shape["num_bins"], jnp.int32)
        return lambda: ops.histogram(v, shape["num_bins"], mode=mode)
    if op == "add_rmsnorm":
        x = jax.random.normal(ks[0], (shape["rows"], shape["d"]),
                              jnp.float32)
        r = jax.random.normal(ks[1], (shape["rows"], shape["d"]),
                              jnp.float32)
        w = jnp.ones((shape["d"],), jnp.float32)
        return lambda: ops.fused_add_rmsnorm(x, r, w, mode=mode)
    if op == "gemm":
        a = jax.random.normal(ks[0], (shape["m"], shape["k"]), jnp.float32)
        b = jax.random.normal(ks[1], (shape["k"], shape["n"]), jnp.float32)
        return lambda: ops.matmul(a, b, mode=mode)
    if op == "flash_attention":
        q = jax.random.normal(ks[0], (1, 2, shape["sq"], shape["d"]),
                              jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, shape["skv"], shape["d"]),
                              jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, shape["skv"], shape["d"]),
                              jnp.float32)
        return lambda: ops.flash_attention(q, k, v, causal=True, mode=mode)
    raise ValueError(op)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=tuning.DEFAULT_TABLE_PATH)
    ap.add_argument("--measure", action="store_true",
                    help="re-rank the structural top-k by live wall clock")
    ap.add_argument("--dialect", default=TARGET.name)
    args = ap.parse_args()

    dialect = tuning.get_dialect(args.dialect)
    table = tuning.TuningTable({}, args.out)
    for op, shapes in sorted(CANONICAL_SHAPES.items()):
        if op not in REGISTRY.ops() or op not in tuning.OP_SPACES:
            print(f"[autotune] skip {op}: not registered/tunable")
            continue
        for mode in REGISTRY.modes(op):
            if mode == "library":
                continue          # XLA's own tiling: not ours to tune
            for shape in shapes:
                bucket = bucket_for(op, shape)
                build_fn = None
                if args.measure:
                    def build_fn(params, op=op, mode=mode, shape=shape,
                                 bucket=bucket):
                        # install the candidate in the live table (the
                        # kernels consult it at trace time) and drop jit
                        # caches so the previous point cannot replay
                        tuning.TUNING_TABLE.record(
                            op, mode, dialect.name, bucket, params,
                            source="candidate")
                        jax.clear_caches()
                        return build_runner(op, mode, shape)
                winner = tuning.autotune_entry(table, op, mode, bucket,
                                               dialect, build_fn=build_fn)
                print(f"[autotune] {op:16s} {mode:17s} {bucket:28s} "
                      f"-> {winner}")
    path = table.save(args.out)
    print(f"[autotune] wrote {len(table.entries)} entries -> {path}")
    failures = tuning.check_table(REGISTRY, table)
    if failures:
        print("[autotune] SELF-CHECK FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print("[autotune] self-check OK (all entries inside the candidate grid)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
