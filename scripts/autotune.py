"""Regenerate the committed pipeline tuning table (core/tuning.py).

Enumerates the legal ``(block, n_buffers)`` candidate grid for every
registered op × dialect-legal mode × canonical shape bucket (the Eq. 1
occupancy algebra in ``repro.core.tuning``), ranks candidates by
structural cost, and writes the winners to
``src/repro/core/tuning_table.json`` — the table every kernel consults at
trace time.

  PYTHONPATH=src python scripts/autotune.py                 # structural
  PYTHONPATH=src python scripts/autotune.py --measure       # live re-rank
  PYTHONPATH=src python scripts/autotune.py --out /tmp/t.json
  PYTHONPATH=src python scripts/autotune.py --dialect uisa-universal10

``--dialect`` takes a comma-separated list and defaults to the target
*plus* the no-shuffle ``uisa-universal10`` profile, so the committed
table carries both slices: ``auto`` policies on the foreign dialect run
its tuned staging plans (48 KB scratchpad ⇒ different grid shapes)
instead of heuristics.  Modes that are not legal on a dialect (the
shuffle tree on universal10, target-pinned native lowerings anywhere
foreign) are skipped, not recorded.

Structural mode is deterministic and backend-free, so CI can assert the
committed table is in sync (scripts/validate_contracts.py re-derives the
winners for every dialect present in the table, and the workflow diffs a
fresh regeneration).  ``--measure`` re-ranks the structural top-k by
median wall clock on the live backend — on a TPU that is the real
autotune; off-TPU it measures the Pallas interpreter and is only useful
for exercising the machinery.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import TARGET, tuning  # noqa: E402
from repro.core.registry import REGISTRY  # noqa: E402
from repro.core.tuning import CANONICAL_SHAPES, bucket_for  # noqa: E402
from repro.kernels import ops  # noqa: E402 (installs registry + op spaces)

KEY = jax.random.PRNGKey(0)

DEFAULT_DIALECTS = f"{TARGET.name},uisa-universal10"


def build_runner(op: str, mode: str, shape: dict, dialect=None):
    """A zero-arg callable running (op, mode) at ``shape`` on the live
    backend, for --measure.  Candidate params reach the kernel through
    the live table, so the caller must clear jit caches between points.

    The run is dispatched under a policy carrying the *tuned* dialect:
    the trace-time table lookups read the ambient dialect, so measuring
    a foreign dialect's candidate must trace under that dialect or every
    candidate would silently time the target slice's plan."""
    from repro.core.registry import ExecutionPolicy
    pol = ExecutionPolicy(
        mode=mode, dialect=(dialect or TARGET).name)
    ks = jax.random.split(KEY, 5)
    if op == "reduction":
        x = jax.random.normal(ks[0], (shape["n"],), jnp.float32)
        return lambda: ops.reduce_sum(x, policy=pol)
    if op == "rmsnorm":
        x = jax.random.normal(ks[0], (shape["rows"], shape["d"]),
                              jnp.float32)
        w = jnp.ones((shape["d"],), jnp.float32)
        return lambda: ops.rmsnorm(x, w, policy=pol)
    if op == "histogram":
        v = jax.random.randint(ks[0], (shape["n"],), 0,
                               shape["num_bins"], jnp.int32)
        return lambda: ops.histogram(v, shape["num_bins"], policy=pol)
    if op == "add_rmsnorm":
        x = jax.random.normal(ks[0], (shape["rows"], shape["d"]),
                              jnp.float32)
        r = jax.random.normal(ks[1], (shape["rows"], shape["d"]),
                              jnp.float32)
        w = jnp.ones((shape["d"],), jnp.float32)
        return lambda: ops.fused_add_rmsnorm(x, r, w, policy=pol)
    if op == "rmsnorm_swiglu":
        x = jax.random.normal(ks[0], (shape["rows"], shape["d"]),
                              jnp.float32)
        w = jnp.ones((shape["d"],), jnp.float32)
        w_cat = jax.random.normal(ks[1], (shape["d"], 2 * shape["f"]),
                                  jnp.float32)
        return lambda: ops.fused_rmsnorm_swiglu(x, w, w_cat, policy=pol)
    if op == "gemm":
        a = jax.random.normal(ks[0], (shape["m"], shape["k"]), jnp.float32)
        b = jax.random.normal(ks[1], (shape["k"], shape["n"]), jnp.float32)
        return lambda: ops.matmul(a, b, policy=pol)
    if op == "flash_attention":
        q = jax.random.normal(ks[0], (1, 2, shape["sq"], shape["d"]),
                              jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, shape["skv"], shape["d"]),
                              jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, shape["skv"], shape["d"]),
                              jnp.float32)
        return lambda: ops.flash_attention(q, k, v, causal=True,
                                           policy=pol)
    if op == "flash_attention_matmul":
        h = 2
        q = jax.random.normal(ks[0], (1, h, shape["sq"], shape["d"]),
                              jnp.float32)
        k = jax.random.normal(ks[1], (1, h, shape["skv"], shape["d"]),
                              jnp.float32)
        v = jax.random.normal(ks[2], (1, h, shape["skv"], shape["d"]),
                              jnp.float32)
        w = jax.random.normal(ks[3], (h * shape["d"], shape["n"]),
                              jnp.float32)
        return lambda: ops.fused_flash_attention_matmul(
            q, k, v, w, causal=True, policy=pol)
    if op == "ssd_scan":
        h, g = 4, 1
        x = jax.random.normal(ks[0], (1, shape["seq"], h, shape["p"]),
                              jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(
            ks[1], (1, shape["seq"], h), jnp.float32))
        a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.5)
        bc = jax.random.normal(ks[3], (2, 1, shape["seq"], g, shape["n"]),
                               jnp.float32) * 0.3
        return lambda: ops.fused_ssd_scan(x, dt, a, bc[0], bc[1],
                                          policy=pol)
    if op == "ssd_decode":
        h, g = 4, 1
        st = jax.random.normal(
            ks[0], (shape["b"], g, h // g, shape["n"], shape["p"]),
            jnp.float32) * 0.5
        x = jax.random.normal(ks[1], (shape["b"], h, shape["p"]),
                              jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(
            ks[2], (shape["b"], h), jnp.float32))
        a = -jnp.exp(jax.random.normal(ks[3], (h,), jnp.float32) * 0.5)
        bc = jax.random.normal(ks[4], (2, shape["b"], g, shape["n"]),
                               jnp.float32) * 0.3
        return lambda: ops.fused_ssd_decode(st, x, dt, a, bc[0], bc[1],
                                            policy=pol)
    raise ValueError(op)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=tuning.DEFAULT_TABLE_PATH)
    ap.add_argument("--measure", action="store_true",
                    help="re-rank the structural top-k by live wall clock")
    ap.add_argument("--dialect", default=DEFAULT_DIALECTS,
                    help="comma-separated dialect names (default: "
                    f"{DEFAULT_DIALECTS})")
    args = ap.parse_args()

    dialects = [tuning.get_dialect(name.strip())
                for name in args.dialect.split(",") if name.strip()]
    table = tuning.TuningTable({}, args.out)
    for dialect in dialects:
        for op, shapes in sorted(CANONICAL_SHAPES.items()):
            if op not in REGISTRY.ops() or op not in tuning.OP_SPACES:
                print(f"[autotune] skip {op}: not registered/tunable")
                continue
            for mode in REGISTRY.modes(op):
                if mode == "library":
                    continue      # XLA's own tiling: not ours to tune
                if not REGISTRY.legal(op, mode, dialect):
                    continue      # illegal variant: nothing to stage
                for shape in shapes:
                    bucket = bucket_for(op, shape)
                    build_fn = None
                    if args.measure:
                        def build_fn(params, op=op, mode=mode, shape=shape,
                                     bucket=bucket, dialect=dialect):
                            # install the candidate in the live table (the
                            # kernels consult it at trace time) and drop
                            # jit caches so the previous point cannot
                            # replay
                            tuning.TUNING_TABLE.record(
                                op, mode, dialect.name, bucket, params,
                                source="candidate")
                            jax.clear_caches()
                            return build_runner(op, mode, shape, dialect)
                    winner = tuning.autotune_entry(table, op, mode, bucket,
                                                   dialect,
                                                   build_fn=build_fn)
                    print(f"[autotune] {dialect.name:18s} {op:22s} "
                          f"{mode:17s} {bucket:32s} -> {winner}")
    path = table.save(args.out)
    print(f"[autotune] wrote {len(table.entries)} entries -> {path}")
    failures = tuning.check_table(REGISTRY, table)
    if failures:
        print("[autotune] SELF-CHECK FAILED:")
        for f in failures:
            print("  -", f)
        return 1
    print("[autotune] self-check OK (all entries inside the candidate grid)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
