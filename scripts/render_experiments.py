"""Regenerate the data tables embedded in EXPERIMENTS.md from the
dry-run artifacts, so prose and numbers cannot drift.

  PYTHONPATH=src python scripts/render_experiments.py > results/tables.md
"""
import glob
import json
import sys

sys.path.insert(0, "src")

from benchmarks.roofline_table import load_cells, render_markdown, summarize  # noqa: E402


def dryrun_section(cells):
    ok = [c for c in cells if c.get("status") == "ok"]
    lines = ["| arch | shape | mesh | kind | compile s | temp GB/dev |"
             " args GB/dev | HLO flops/chip | wire GB/chip | coll ops |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = c.get("memory", {})
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['kind']} "
            f"| {c.get('compile_s', 0):.0f} "
            f"| {(mem.get('temp_size_in_bytes') or 0) / 1e9:.2f} "
            f"| {(mem.get('argument_size_in_bytes') or 0) / 1e9:.2f} "
            f"| {c['flops_per_chip']:.2e} "
            f"| {c['collectives']['total_wire_bytes'] / 1e9:.1f} "
            f"| {c['collectives']['n_ops']} |")
    return "\n".join(lines)


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    cells = load_cells(tag)
    print(f"## §Dry-run table ({tag}, {len(cells)} artifacts)\n")
    print(dryrun_section(cells))
    print(f"\n## §Roofline table ({tag})\n")
    print(render_markdown(cells))
    print("\n## summary\n")
    print("```json")
    print(json.dumps(summarize(cells), indent=1))
    print("```")


if __name__ == "__main__":
    main()
