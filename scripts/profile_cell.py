import sys

from repro.launch.hostdev import ensure_host_devices

# The 512-device host platform is for the collective profiler only; the
# serve-stats mode runs real tiny engines and must keep the default
# single device.  (Either way this must precede jax import — see
# launch/hostdev.py; REPRO_SIM_DEVICES overrides the count.)
_SERVE_STATS = len(sys.argv) > 1 and sys.argv[1] == "serve-stats"
if not _SERVE_STATS:
    ensure_host_devices()

"""Per-op collective profile of one dry-run cell: the §Perf 'profiler'.

Prints the top collectives by loop-trip-multiplied wire bytes, with
shapes — the evidence the hypothesis loop needs.

  PYTHONPATH=src python scripts/profile_cell.py qwen3-32b prefill_32k \\
      single [key=value par overrides...]

A second mode surfaces the paged serve engine's device-resident tick
stats (occupied pages, pool utilization, shared-prefix hits — harvested
in sync(), zero per-tick transfers):

  PYTHONPATH=src python scripts/profile_cell.py serve-stats \\
      [page_size=8 num_pages=24 ticks=12]

With ``--cells N`` (ISSUE 10) serve-stats runs the data-parallel
CellRouter over N cells instead: per-group shared-prefix request waves
are routed by affinity + least-loaded page budget, and the report shows
per-cell occupancy/utilization/shared-prefix hits plus the fleet
aggregate (one stacked harvest for all cells):

  PYTHONPATH=src python scripts/profile_cell.py serve-stats --cells 3
"""
import json
from collections import defaultdict


def parse_overrides(args):
    out = {}
    args = list(args)
    while "--cells" in args:                  # --cells N == cells=N
        i = args.index("--cells")
        out["cells"] = int(args[i + 1])
        del args[i:i + 2]
    for a in args:
        k, v = a.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        out[k] = v
    return out


def serve_stats(overrides):
    """Run a tiny paged engine for a few ticks and print the per-tick
    stats table sync() harvested — the observability surface of the
    paged KV cache (pool occupancy is what replaces per-slot capacity
    as the admission currency)."""
    import jax
    from repro.models import build_model
    from repro.models.config import ModelConfig, ParallelConfig
    from repro.serve import BatchedEngine, Request, ServeConfig

    page_size = overrides.get("page_size", 8)
    ticks = overrides.get("ticks", 12)
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2,
                      d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=128, dtype="float32")
    model = build_model(cfg, ParallelConfig(remat="none"))
    params = model.init_params(jax.random.PRNGKey(0))
    scfg = ServeConfig(batch_slots=4, max_seq_len=64, eos_id=-1,
                       page_size=page_size,
                       num_pages=overrides.get("num_pages", 24))
    if overrides.get("cells", 1) > 1:
        serve_stats_fleet(model, params, scfg, overrides, ticks)
        return
    eng = BatchedEngine(model, params, scfg)

    shared = list(range(2, 2 + 2 * page_size))   # common "system prompt"
    reqs = [Request(rid=i, prompt=shared + [20 + i, 21 + i],
                    max_new_tokens=6) for i in range(4)]
    eng.admit(reqs)
    for _ in range(ticks):
        eng.step()
    eng.sync()

    print(f"serve-stats page_size={page_size} num_pages={eng.num_pages} "
          f"slots={scfg.batch_slots} ticks={eng.tick_count}")
    hdr = ("tick", "live_slots", "frontier_pages", "pool_occupied",
           "pool_util", "shared_hits")
    print(f"{hdr[0]:>5s} {hdr[1]:>10s} {hdr[2]:>14s} {hdr[3]:>13s} "
          f"{hdr[4]:>9s} {hdr[5]:>11s}")
    for row in eng.tick_stats:
        print(f"{row['tick']:5d} {row['live_slots']:10d} "
              f"{row['frontier_pages']:14d} "
              f"{row['pool_occupied_pages']:13d} "
              f"{row['pool_utilization']:9.2f} "
              f"{row['shared_prefix_hits']:11d}")


def serve_stats_fleet(model, params, scfg, overrides, ticks):
    """--cells N: the same tiny workload scaled out over a CellRouter.

    One wave of 4 requests per cell, each wave sharing its own 2-page
    prompt prefix: the wave's opener lands by least-loaded page budget,
    the followers ride prefix affinity onto the opener's cell — so the
    per-cell ``shared_hits`` column is the routing policy made visible.
    Ticks run with zero per-tick transfers; ONE stacked harvest in
    ``sync()`` drains the whole fleet."""
    from repro.serve import Request
    from repro.serve.router import make_cells

    n_cells = overrides["cells"]
    router = make_cells(model, params, scfg, n_cells)
    ps = scfg.page_size
    reqs, rid = [], 0
    for g in range(n_cells):
        shared = [2 + g * ps * 2 + i for i in range(2 * ps)]
        for j in range(4):
            reqs.append(Request(rid=rid, prompt=shared + [20 + j, 30 + g],
                                max_new_tokens=6))
            rid += 1
    admitted = router.admit(reqs)
    for _ in range(ticks):
        router.step()
    router.sync()

    print(f"serve-stats cells={n_cells} page_size={ps} "
          f"num_pages/cell={router.cells[0].num_pages} "
          f"slots/cell={scfg.batch_slots} ticks={router.tick_count} "
          f"admitted={admitted}/{len(reqs)}")
    hdr = ("cell", "ticks", "live", "slots", "occ_pages", "pool_util",
           "shared_hits")
    print(f"{hdr[0]:>4s} {hdr[1]:>5s} {hdr[2]:>4s} {hdr[3]:>5s} "
          f"{hdr[4]:>9s} {hdr[5]:>9s} {hdr[6]:>11s}")
    rows = router.cell_stats()
    for r in rows:
        print(f"{r['cell']:4d} {r['ticks']:5d} {r['live_slots']:4d} "
              f"{r['slots']:5d} {r['occupied_pages']:9d} "
              f"{r['utilization']:9.2f} {r['shared_prefix_hits']:11d}")
    occ = sum(r["occupied_pages"] for r in rows)
    cap = sum(c.num_pages for c in router.cells)
    hits = sum(r["shared_prefix_hits"] for r in rows)
    live = sum(r["live_slots"] for r in rows)
    print(f" agg {router.tick_count:5d} {live:4d} "
          f"{sum(r['slots'] for r in rows):5d} {occ:9d} "
          f"{occ / max(cap, 1):9.2f} {hits:11d}")


def main():
    if _SERVE_STATS:
        serve_stats(parse_overrides(sys.argv[2:]))
        return

    from repro.launch import cells as cells_lib
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.hlo_parser import HloModule

    arch, shape, mesh_kind = sys.argv[1:4]
    overrides = parse_overrides(sys.argv[4:])
    multi = mesh_kind == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    cell = cells_lib.build_cell(arch, shape, mesh,
                                par_overrides=overrides or None)
    with mesh:
        compiled = cell.lower().compile()
    mod = HloModule(compiled.as_text(), chips)
    entry = mod.entry or next(iter(mod.comps))
    recs = mod.comp_collectives(entry)

    # aggregate identical (op, shape, group) records
    agg = defaultdict(lambda: {"count": 0, "wire": 0.0})
    for r in recs:
        k = (r["op"], r["shape"], r["group_size"])
        agg[k]["count"] += r["count"]
        agg[k]["wire"] += r["wire_bytes"]
    top = sorted(agg.items(), key=lambda kv: -kv[1]["wire"])[:25]
    total = sum(v["wire"] for v in agg.values())
    print(f"cell {arch} x {shape} x {mesh_kind} overrides={overrides}")
    print(f"total wire {total / 1e9:.1f} GB/chip, "
          f"{int(sum(v['count'] for v in agg.values()))} ops")
    print(f"{'op':18s} {'shape':34s} {'grp':>4s} {'count':>7s} "
          f"{'wire GB':>9s} {'%':>5s}")
    for (op, shape_s, g), v in top:
        print(f"{op:18s} {shape_s:34s} {g:4d} {v['count']:7.0f} "
              f"{v['wire'] / 1e9:9.2f} {100 * v['wire'] / total:5.1f}")
    print(f"flops/chip {mod.comp_flops(entry):.3e}")


if __name__ == "__main__":
    main()
