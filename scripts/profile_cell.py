import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (must precede jax import — see launch/dryrun.py)

"""Per-op collective profile of one dry-run cell: the §Perf 'profiler'.

Prints the top collectives by loop-trip-multiplied wire bytes, with
shapes — the evidence the hypothesis loop needs.

  PYTHONPATH=src python scripts/profile_cell.py qwen3-32b prefill_32k \\
      single [key=value par overrides...]
"""
import json
import sys
from collections import defaultdict

from repro.launch import cells as cells_lib
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo_parser import HloModule


def parse_overrides(args):
    out = {}
    for a in args:
        k, v = a.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        out[k] = v
    return out


def main():
    arch, shape, mesh_kind = sys.argv[1:4]
    overrides = parse_overrides(sys.argv[4:])
    multi = mesh_kind == "multi"
    chips = 512 if multi else 256
    mesh = make_production_mesh(multi_pod=multi)
    cell = cells_lib.build_cell(arch, shape, mesh,
                                par_overrides=overrides or None)
    with mesh:
        compiled = cell.lower().compile()
    mod = HloModule(compiled.as_text(), chips)
    entry = mod.entry or next(iter(mod.comps))
    recs = mod.comp_collectives(entry)

    # aggregate identical (op, shape, group) records
    agg = defaultdict(lambda: {"count": 0, "wire": 0.0})
    for r in recs:
        k = (r["op"], r["shape"], r["group_size"])
        agg[k]["count"] += r["count"]
        agg[k]["wire"] += r["wire_bytes"]
    top = sorted(agg.items(), key=lambda kv: -kv[1]["wire"])[:25]
    total = sum(v["wire"] for v in agg.values())
    print(f"cell {arch} x {shape} x {mesh_kind} overrides={overrides}")
    print(f"total wire {total / 1e9:.1f} GB/chip, "
          f"{int(sum(v['count'] for v in agg.values()))} ops")
    print(f"{'op':18s} {'shape':34s} {'grp':>4s} {'count':>7s} "
          f"{'wire GB':>9s} {'%':>5s}")
    for (op, shape_s, g), v in top:
        print(f"{op:18s} {shape_s:34s} {g:4d} {v['count']:7.0f} "
              f"{v['wire'] / 1e9:9.2f} {100 * v['wire'] / total:5.1f}")
    print(f"flops/chip {mod.comp_flops(entry):.3e}")


if __name__ == "__main__":
    main()
