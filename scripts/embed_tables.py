"""Splice the generated dry-run / roofline / optimized tables into
EXPERIMENTS.md at the <!-- *_TABLE --> markers.

  PYTHONPATH=src python scripts/embed_tables.py
"""
import re
import sys

sys.path.insert(0, "src")

from benchmarks.roofline_table import load_cells, render_markdown  # noqa: E402


def dryrun_table(cells):
    ok = [c for c in cells if c.get("status") == "ok"]
    lines = ["| arch | shape | mesh | kind | compile s | temp GB/dev |"
             " state GB/dev | HLO flops/chip | wire GB/chip | coll ops |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for c in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = c.get("memory", {})
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['kind']} "
            f"| {c.get('compile_s', 0):.0f} "
            f"| {(mem.get('temp_size_in_bytes') or 0) / 1e9:.2f} "
            f"| {(mem.get('argument_size_in_bytes') or 0) / 1e9:.2f} "
            f"| {c['flops_per_chip']:.2e} "
            f"| {c['collectives']['total_wire_bytes'] / 1e9:.1f} "
            f"| {c['collectives']['n_ops']} |")
    return "\n".join(lines)


def splice(text, marker, table):
    pattern = re.compile(rf"<!-- {marker} -->.*?(?=\n## |\n---|\Z)",
                         re.DOTALL)
    block = f"<!-- {marker} -->\n\n{table}\n"
    if f"<!-- {marker} -->" in text:
        return pattern.sub(block, text, count=1)
    return text


def main():
    with open("EXPERIMENTS.md") as f:
        text = f.read()
    base = load_cells("baseline")
    if base:
        text = splice(text, "DRYRUN_TABLE", dryrun_table(base))
        text = splice(text, "ROOFLINE_TABLE", render_markdown(base))
    opt = load_cells("optimized")
    if opt:
        text = splice(text, "OPTIMIZED_TABLE", render_markdown(opt))
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"embedded: baseline={len(base)} opt={len(opt)} cells")


if __name__ == "__main__":
    main()
