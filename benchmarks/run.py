"""Benchmark harness entrypoint: one benchmark per paper table.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run dialect    # Tables II/III
  PYTHONPATH=src python -m benchmarks.run tablev     # Table V kernels
  PYTHONPATH=src python -m benchmarks.run roofline   # §Roofline table
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks import dialect_audit, roofline_table, tablev


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    results = {}
    if which in ("all", "dialect"):
        results["dialect_audit"] = dialect_audit.run()
        print()
    if which in ("all", "tablev"):
        results["tablev"] = tablev.run()
        print()
    if which in ("all", "roofline"):
        results["roofline"] = roofline_table.run()
    os.makedirs("results/bench", exist_ok=True)
    with open("results/bench/summary.json", "w") as f:
        json.dump(results, f, indent=1, default=str)
    print("\n[benchmarks] wrote results/bench/summary.json")


if __name__ == "__main__":
    main()
